"""Quickstart: train a tiny LM for 40 steps on CPU, watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.data import SyntheticLM
from repro.distribution.sharding import make_elastic_mesh
from repro.distribution.step import init_train_state, jit_train_step
from repro.optim import AdamWConfig


def main():
    cfg = get_config("qwen3-1.7b").reduced()
    mesh = make_elastic_mesh(ParallelConfig())  # single device
    params, opt_state = init_train_state(cfg, mesh)
    opt = AdamWConfig(learning_rate=3e-3, warmup_steps=10, total_steps=40)
    step, _ = jit_train_step(cfg, mesh, opt, global_batch=8)
    data = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=8, seed=0)

    for i in range(40):
        batch = {"tokens": jnp.asarray(data.global_batch_at(i))}
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 5 == 0:
            print(f"step {i:3d}  loss={float(metrics['loss']):.4f}  "
                  f"lr={float(metrics['lr']):.2e}")
    print("done — loss should have dropped by >0.5 nats")


if __name__ == "__main__":
    main()
