"""Intersection-planner walkthrough: visualize the transfer plan for the
paper's Fig. 5 scenario (TP=4 -> TP=8) and for a mixed 3D reshape, then
execute it through the bounded staging buffer and verify bit-exactness.

    PYTHONPATH=src python examples/reshard_demo.py
"""

import numpy as np

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer, verify_completeness
from repro.core.resource_view import TensorSpec, build_tensor_specs
from repro.core.streaming import (
    allocate_destination,
    execute_plan,
    materialize_rank,
)
from repro.models.transformer import block_program


def fig5_tp4_to_tp8():
    print("=== paper Fig. 5: weight W[:, :] under TP=4 -> TP=8 ===")
    spec = TensorSpec("params/w", (8, 64), "float32", ("none", "tp"), "stages", "params")
    plan = plan_transfer([spec], ParallelConfig(tp=4), ParallelConfig(tp=8))
    for t in sorted(plan.tasks, key=lambda t: t.dst_rank):
        cols = t.bounds[1]
        print(f"  src rank {t.src_rank} -> dst rank {t.dst_rank}: "
              f"cols [{cols[0]:2d},{cols[1]:2d})  ({t.nbytes} B)")
    print(f"  total: {len(plan.tasks)} tasks, {plan.network_bytes} network bytes, "
          f"no full-tensor materialization\n")


def mixed_3d_reshape():
    print("=== mixed 3D reshape of a real model's state "
          "(qwen3 reduced, params+optimizer) ===")
    cfg = get_config("qwen3-1.7b").reduced()
    specs = build_tensor_specs(cfg, include_optimizer=True)
    ca, cb = ParallelConfig(dp=2, pp=2, tp=2), ParallelConfig(dp=1, pp=1, tp=4)
    plan = plan_transfer(specs, ca, cb, num_positions=len(block_program(cfg)))
    verify_completeness(specs, plan, cb)
    tx, rx = plan.per_rank_bytes()
    print(f"  {ca.describe()} ({ca.world_size} ranks) -> "
          f"{cb.describe()} ({cb.world_size} ranks)")
    print(f"  tensors: {len(specs)}, tasks: {len(plan.tasks)}, "
          f"layers streamed: {len(plan.layers())}")
    print(f"  network bytes: {plan.network_bytes:,}  "
          f"zero-copy (local) bytes: {plan.local_bytes:,}")
    print(f"  per-dst-rank receive bytes: { {k: f'{v:,}' for k, v in sorted(rx.items())} }")

    rng = np.random.default_rng(0)
    g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
    src = {r: materialize_rank(specs, ca, r, g) for r in range(ca.world_size)}
    dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
    budget = 256 * 1024
    stats = execute_plan(plan, src, dst, staging_bytes=budget)
    stats.assert_bounded(budget)
    for r in range(cb.world_size):
        ref = materialize_rank(specs, cb, r, g)
        for name, arr in ref.shards.items():
            np.testing.assert_array_equal(arr, dst[r].shards[name])
    print(f"  executed: {stats.layers_streamed} layer barriers, "
          f"peak staging {stats.peak_staging_bytes:,} B <= budget {budget:,} B, "
          "bit-exact ✓")


if __name__ == "__main__":
    fig5_tp4_to_tp8()
    mixed_3d_reshape()
