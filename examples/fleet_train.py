"""Two-job fleet demo: one volatile device pool arbitrated across a REAL
live training job and a simulated neighbor (DESIGN.md §17–18).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/fleet_train.py

The ``FleetArbiter`` plans the shared capacity trace with the
marginal-throughput policy (who deserves the devices freed by a shrink or
offered by a grow?), then each job replays its assigned events through
the unmodified ``ElasticScheduler`` — the live job over the wire protocol
against a ``LiveRController`` on 8 host devices, the simulated one
against a closed-form ``SimEndpoint`` on its virtual clock. Per-job
goodput is printed at exit.

``--all-sim`` swaps the live job for a second simulated one and runs the
whole fleet on one shared DES clock through ``FleetArbiter.run`` — the
100-job-scale path, finishing in milliseconds.
"""

import argparse
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

# one shared capacity trace: grow to 16, shrink to 8, settle at 12
TRACE = [(8.0, 16, "resize", 1e9), (16.0, 8, "resize", 1e9),
         (24.0, 12, "resize", 1e9)]
INITIAL = 12


def run_all_sim() -> None:
    from repro.configs.base import ParallelConfig
    from repro.elastic import SimEndpoint, WireEndpoint
    from repro.fleet import FleetArbiter, FleetJob, make_policy
    from repro.sim.des import Simulator

    sim = Simulator()
    jobs = []
    for name, params in (("small", 0.4e9), ("big", 7e9)):
        ep = WireEndpoint(SimEndpoint(name, params=params, global_batch=256,
                                      parallel=ParallelConfig(dp=4), sim=sim))
        jobs.append(FleetJob(name=name, endpoint=ep, params=params,
                             global_batch=256, feasible_worlds=(1, 2, 4, 8, 12)))
    arb = FleetArbiter(jobs, make_policy("marginal"), sim=sim)
    # stretch the trace to hours so reconfig pauses are visible but small
    trace = [(t * 450, w, k, 120.0) for t, w, k, _ in TRACE]
    rep = arb.run(trace, duration_s=4 * 3600.0, initial_capacity=INITIAL)
    print(f"policy={rep.policy}  cluster goodput "
          f"{rep.cluster_goodput * 100:.1f}%  "
          f"({rep.arbitrated_events} arbitrated events)")
    for j in rep.jobs:
        print(f"  {j['name']:8s} world={j['world']:2d} "
              f"goodput={j['goodput'] * 100:6.2f}%  "
              f"samples={j['samples']:.0f}")


def run_mixed() -> None:
    from repro.configs import get_config
    from repro.configs.base import ParallelConfig
    from repro.core.controller import LiveRController
    from repro.core.topology_search import best_target
    from repro.elastic import (
        ControllerEndpoint, DeadlineEstimator, ElasticScheduler, SimEndpoint,
        WireEndpoint,
    )
    from repro.elastic import protocol as P
    from repro.fleet import FleetArbiter, FleetJob, make_policy
    from repro.optim import AdamWConfig

    cfg = get_config("qwen3-1.7b").reduced()
    print(f"live job: {cfg.name} on 8 host devices; sim job: 7B neighbor")
    ctrl = LiveRController(
        cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(learning_rate=1e-3),
        seq_len=32, global_batch=8, overlap="stop_copy", sync_compile=True,
    )
    ctrl.train_steps(4)  # warm-up: compile amortized, estimator seeded

    live_ep = WireEndpoint(ControllerEndpoint(ctrl))
    targets = {w: best_target(cfg, w, 8, 32, max_pp=1) for w in (2, 4, 8)}
    sim_ep = WireEndpoint(SimEndpoint("sim-7b", params=7e9, global_batch=256,
                                      parallel=ParallelConfig(dp=4)))
    jobs = [
        FleetJob(name="live", endpoint=live_ep,
                 params=float(cfg.param_count()), global_batch=8,
                 feasible_worlds=(2, 4, 8), target_fn=lambda w: targets[w]),
        FleetJob(name="sim-7b", endpoint=sim_ep, params=7e9, global_batch=256,
                 feasible_worlds=(1, 2, 4, 8)),
    ]
    arb = FleetArbiter(jobs, make_policy("marginal"), calibrate=False)
    plans = arb.plan_assignments(TRACE, initial_capacity=INITIAL,
                                 default_warning_s=1e9)
    for name, evs in plans.items():
        moves = ", ".join(f"t={e.time_s:.0f}s→{e.target.world_size}dev"
                          for e in evs)
        print(f"  plan[{name}]: {moves or 'hold'}")

    rep = ElasticScheduler(
        live_ep, estimator=DeadlineEstimator(ctrl), sync_prepare=True,
        tail_steps=2,
    ).run(plans["live"])
    srep = ElasticScheduler(sim_ep, tail_steps=2).run(plans["sim-7b"])
    ledger = sim_ep.handle(P.QueryLedger())

    print("\nper-job goodput:")
    print(f"  live    goodput={rep.goodput * 100:6.2f}%  steps={rep.steps}  "
          f"world={ctrl.world.parallel.describe()}  "
          f"outcomes={[o.outcome for o in rep.outcomes]}")
    print(f"  sim-7b  goodput={ledger.goodput * 100:6.2f}%  "
          f"steps={ledger.steps}  "
          f"outcomes={[o.outcome for o in srep.outcomes]}")
    print(f"control-plane traffic: live={live_ep.commands} cmds "
          f"({live_ep.bytes_tx + live_ep.bytes_rx} wire bytes), "
          f"sim={sim_ep.commands} cmds")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--all-sim", action="store_true",
                    help="both jobs simulated on one shared DES clock")
    args = ap.parse_args()
    if args.all_sim:
        run_all_sim()
    else:
        run_mixed()


if __name__ == "__main__":
    main()
