"""End-to-end elastic training driver: a ~100M-parameter model trained for a
few hundred steps with TWO live reconfigurations and one fail-stop fallback
injected mid-run — the full LiveR lifecycle on host devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/elastic_train.py [--steps 200]

``--trace SECONDS`` switches from the fixed schedule to the deadline-aware
``ElasticScheduler`` replaying a seeded spot-market event stream
(``sim.volatility.spot_trace``): resize warnings are coalesced/retargeted
and fall back down the lattice (stream -> stop-copy -> checkpoint) as their
windows demand.

Watch for:
  * [event]/[switch] lines — training continues while the shadow world
    prepares; the pause at the switch is milliseconds;
  * goodput printed at the end (≈99%+ for the fixed schedule);
  * the loss curve crossing reconfigurations without a blip (paper Fig. 9).
"""

import argparse
import dataclasses
import os
import sys
import tempfile

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.controller import LiveRController
from repro.optim import AdamWConfig


def run_trace(ctrl, trace_seconds: float) -> None:
    """Replay a seeded spot trace through the deadline scheduler."""
    from repro.elastic import ElasticScheduler, events_from_trace
    from repro.sim.volatility import spot_trace

    # ~10 events at native spacing 30x the live spacing, then compressed
    # 30x so events land roughly every ``trace_seconds`` of wall clock.
    # Warning windows are widened to ~90s live (2700 native): CPU-host
    # prepare times are minutes-scale relative to the compressed clock, and
    # the point of the demo is to watch the lattice pick LIVE rungs, not to
    # drown every event in the checkpoint fallback.
    trace = spot_trace(
        trace_seconds * 30 * 10, trace_seconds * 30,
        world_choices=(4, 8), seed=5, warning_s=2700.0,
    )
    events = events_from_trace(
        trace, ctrl.cfg, ctrl.global_batch, ctrl.seq_len,
        compress=30.0, max_pp=1,
    )
    print(f"replaying {len(events)} events, one every ~{trace_seconds:.0f}s")
    sched = ElasticScheduler(
        ctrl,
        on_event=lambda o: print(
            f"[event {o.index}] {o.kind} -> {o.target}: "
            f"decision={o.decision or '-'} outcome={o.outcome or 'pending'}"
        ),
    )
    rep = sched.run(events)
    print(
        f"\ntrace done: {rep.steps} steps, goodput {rep.goodput*100:.2f}%, "
        f"pause {rep.pause_seconds:.2f}s"
    )
    for o in rep.outcomes:
        print(
            f"  ev{o.index} {o.kind:9s} {o.target:14s} "
            f"{o.decision:10s} -> {o.outcome:10s} "
            f"pause={o.pause_s*1e3:.0f}ms reused={o.reused_layers}"
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument(
        "--trace", type=float, default=0.0, metavar="SECONDS",
        help="replay a spot trace with ~SECONDS between events through the "
        "deadline scheduler instead of the fixed schedule",
    )
    args = ap.parse_args()

    # ~100M params: qwen3 geometry at width 512
    cfg = dataclasses.replace(
        get_config("qwen3-1.7b"),
        name="qwen3-100m",
        num_layers=8,
        d_model=512,
        num_heads=8,
        num_kv_heads=4,
        d_ff=2048,
        vocab_size=32000,
        dtype="float32",
    )
    from repro.models.model import analytic_param_count

    print(f"model: {cfg.name} ({analytic_param_count(cfg)/1e6:.0f}M params)")

    ckpt_dir = tempfile.mkdtemp(prefix="liver_ckpt_")
    opt = AdamWConfig(learning_rate=6e-4, warmup_steps=20, total_steps=args.steps)
    ctrl = LiveRController(
        cfg,
        ParallelConfig(dp=2, tp=2),
        opt,
        seq_len=128,
        global_batch=8,
        ckpt_dir=ckpt_dir,
        ckpt_interval=40,
    )

    if args.trace:
        ctrl.train_steps(4)  # warm-up: compile amortized, estimator seeded
        # last-resort rung only: fail-stops recover from surviving peers
        # first (DESIGN.md §15); the checkpoint covers uncovered losses
        ctrl.checkpoint_now()
        run_trace(ctrl, args.trace)
        return

    schedule = {
        args.steps // 4: ("resize", ParallelConfig(dp=2, tp=4)),  # scale out
        args.steps // 2: ("resize", ParallelConfig(dp=1, tp=4)),  # scale in
        3 * args.steps // 4: ("failstop", ParallelConfig(dp=2, tp=2)),
    }
    losses = []
    while ctrl.step < args.steps:
        ev = schedule.pop(ctrl.step, None)
        if ev:
            kind, target = ev
            if kind == "resize":
                print(f"[event] step {ctrl.step}: live resize -> {target.describe()}")
                ctrl.request_resize(target)
            else:
                print(f"[event] step {ctrl.step}: fail-stop! falling back to checkpoint")
                rec = ctrl.fail_stop_recover(target)
                print(f"        recovered to step {ctrl.step} in {rec.total_pause_s:.1f}s")
        n_before = len(ctrl.records)
        losses += ctrl.train_steps(1)
        if len(ctrl.records) > n_before and ctrl.records[-1].mode == "live":
            r = ctrl.records[-1]
            print(f"[switch] {r.src} -> {r.dst}: pause {r.total_pause_s*1e3:.0f}ms "
                  f"(prepare {r.prepare_s:.1f}s fully overlapped)")
        if ctrl.step % 20 == 0:
            print(f"  step {ctrl.step:4d} loss={losses[-1]:.4f} "
                  f"world={ctrl.world.parallel.describe()}")

    print(f"\nfinal loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    print(f"goodput {ctrl.ledger.goodput*100:.2f}%  "
          f"total pause {ctrl.ledger.pause_seconds:.2f}s  "
          f"events: {[r.mode for r in ctrl.records]}")


if __name__ == "__main__":
    main()
