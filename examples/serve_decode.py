"""Batched serving example: prefill a prompt batch, decode autoregressively
with the KV/SSD caches — across three architecture families.

    PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M


def serve(arch: str, batch_size=2, prompt_len=32, gen=8):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    horizon = prompt_len + gen
    batch = {
        "tokens": jax.random.randint(
            jax.random.key(1), (batch_size, prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (batch_size, 16, cfg.d_model), jnp.float32
        )
    logits, cache, cross = M.prefill(cfg, params, batch, max_seq=horizon)
    decode = jax.jit(
        (lambda p, c, t, pos, x: M.decode_step(cfg, p, c, t, pos, x))
        if cfg.family == "encdec"
        else (lambda p, c, t, pos, x: M.decode_step(cfg, p, c, t, pos))
    )
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [cur]
    t0 = time.perf_counter()
    for i in range(gen):
        logits, cache = decode(params, cache, cur, jnp.int32(prompt_len + i), cross)
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(cur)
    jax.block_until_ready(cur)
    toks = jnp.concatenate(out, axis=1)
    print(f"{arch:26s} [{cfg.family:6s}] generated {toks.shape[1]} tokens/request "
          f"in {time.perf_counter()-t0:.2f}s -> {[int(t) for t in toks[0][:8]]}")


if __name__ == "__main__":
    for arch in ("qwen3-1.7b", "mixtral-8x7b", "mamba2-2.7b"):
        serve(arch)
