"""Batched serving example: prefill a prompt batch, decode autoregressively
with the KV/SSD caches — across three architecture families, via the shared
``repro.serve.driver`` harness.

    PYTHONPATH=src python examples/serve_decode.py
"""

from repro.configs import get_config
from repro.serve.driver import serve_once


def serve(arch: str, batch_size=2, prompt_len=32, gen=8):
    cfg = get_config(arch).reduced()
    out = serve_once(cfg, batch=batch_size, prompt_len=prompt_len, gen=gen)
    toks = out["tokens"]
    dt = out["prefill_s"] + out["decode_s"]
    print(f"{arch:26s} [{cfg.family:6s}] generated {toks.shape[1]} tokens/request "
          f"in {dt:.2f}s -> {[int(t) for t in toks[0][:8]]}")


if __name__ == "__main__":
    for arch in ("qwen3-1.7b", "mixtral-8x7b", "mamba2-2.7b"):
        serve(arch)
