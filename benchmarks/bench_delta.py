"""Delta-aware plan IR benchmark (ISSUE 6 acceptance gate).

Runs a resize matrix chosen to span the three cell classes of the
classified plan IR (DESIGN.md §13):

  * ``tp_preserving``  dp2tp2 -> dp1tp2 — every surviving shard is
    byte-identical: the whole plan classifies **resident** and the delta
    executor moves zero bytes (aliasing pass-throughs only);
  * ``dp_only``        dp1tp2 -> dp2tp2 — surviving ranks resident, the
    grown replica group fed by **remote** broadcasts;
  * ``mixed``          dp2tp2 -> dp1tp4 — tp width changes, so cells
    split **local**/**remote** and nothing is resident.

For each scenario it reports the plan's kind-byte breakdown, the layers
skipped (``reused_layers``), and bytes physically moved by the live
executor under delta streaming vs the ``delta=False`` full-copy baseline
(resident cells demoted to moves). For the tp-preserving scenario it also
times the end-to-end commit at two model sizes — resident skipping makes
that latency near-constant in model size instead of linear.

Emits the usual ``name,us,derived`` CSV rows and writes
``results/BENCH_delta.json``. ``--smoke`` shrinks sizes for CI;
``--check`` exits nonzero unless a resident-heavy scenario reports
``reused_layers > 0`` AND delta streaming moved strictly fewer bytes than
the full-copy baseline on at least one scenario.
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import emit, run_with_devices, write_results

_SNIPPET = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer
from repro.core.resource_view import TensorSpec
from repro.distribution.sharding import make_elastic_mesh
from repro.reshard import LiveExecutor, ReshardEngine

L, ROWS, COLS, ITERS = __L__, __ROWS__, __COLS__, __ITERS__
ROLE_AXIS = {"pp": "pipe", "tp": "model", "dp": "data", "none": None}

def make_specs(layers, rows, cols):
    return [
        TensorSpec("params/blocks/pos0/w", (layers, rows, cols), "float32",
                   ("pp", "none", "tp"), "stages", "params"),
        TensorSpec("params/embed/tok", (rows * 4, cols), "float32",
                   ("tp", "none"), "first", "params"),
    ]

def sharding_for(s, mesh):
    return NamedSharding(mesh, P(*[ROLE_AXIS[r] for r in s.roles]))

def run_live(specs, plan, ca, cb, delta):
    mesh_a, mesh_b = make_elastic_mesh(ca), make_elastic_mesh(cb)
    rng = np.random.default_rng(0)
    src = {s.name: jax.device_put(
        jnp.asarray(rng.normal(size=s.shape).astype(s.dtype)),
        sharding_for(s, mesh_a)) for s in specs}
    targets = {s.name: sharding_for(s, mesh_b) for s in specs}
    ex = LiveExecutor({s.name: s for s in specs}, src, targets, 1 << 20)
    eng = ReshardEngine(plan, ex, staging_bytes=1 << 20, delta=delta)
    stats = eng.run(); ex.block_until_ready()  # warm executables + carries
    ts = []
    for _ in range(ITERS):
        ex.reset_round()
        t0 = time.perf_counter()
        stats = eng.run()
        ex.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return stats, min(ts), ex

SCENARIOS = [
    ("tp_preserving", ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)),
    ("dp_only",       ParallelConfig(dp=1, tp=2), ParallelConfig(dp=2, tp=2)),
    ("mixed",         ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=4)),
]
records = []
for name, ca, cb in SCENARIOS:
    specs = make_specs(L, ROWS, COLS)
    plan = plan_transfer(specs, ca, cb, num_positions=1)
    d_stats, d_s, d_ex = run_live(specs, plan, ca, cb, delta=True)
    b_stats, b_s, _ = run_live(specs, plan, ca, cb, delta=False)
    records.append({
        "scenario": name,
        "src": str(ca), "dst": str(cb),
        "kind_bytes": plan.kind_bytes(),
        "layers_total": len(plan.layers()),
        "reused_layers": len(plan.resident_layers()),
        "resident_passthroughs": d_ex.resident_passthroughs,
        "delta_moved_bytes": d_stats.executed_bytes,
        "delta_skipped_bytes": d_stats.resident_bytes,
        "delta_commit_ms": d_s * 1e3,
        "baseline_moved_bytes": b_stats.executed_bytes,
        "baseline_commit_ms": b_s * 1e3,
    })

# commit latency vs model size on the resident-heavy transition: with
# every layer skipped, latency must not scale with the byte count
ca, cb = ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)
size_lat = []
for scale in (1, 4):
    specs = make_specs(L, ROWS * scale, COLS)
    plan = plan_transfer(specs, ca, cb, num_positions=1)
    _, s, _ = run_live(specs, plan, ca, cb, delta=True)
    size_lat.append({
        "rows": ROWS * scale,
        "plan_bytes": sum(t.nbytes for t in plan.tasks),
        "commit_ms": s * 1e3,
    })

print("JSON " + json.dumps({
    "config": {"layers": L, "rows": ROWS, "cols": COLS, "iters": ITERS},
    "scenarios": records,
    "size_sweep_tp_preserving": size_lat,
}))
"""


def main(argv=()) -> None:
    smoke = "--smoke" in argv
    check = "--check" in argv
    L, rows, cols, iters = (4, 16, 32, 2) if smoke else (8, 64, 128, 5)
    code = (
        _SNIPPET.replace("__L__", str(L))
        .replace("__ROWS__", str(rows))
        .replace("__COLS__", str(cols))
        .replace("__ITERS__", str(iters))
    )
    out = run_with_devices(code, n_devices=8)
    payload = None
    for line in out.splitlines():
        if line.startswith("JSON "):
            payload = json.loads(line[5:])
    assert payload is not None, f"no JSON payload in bench output:\n{out[-2000:]}"

    reuse_ok = any(r["reused_layers"] > 0 for r in payload["scenarios"])
    bytes_ok = any(
        r["delta_moved_bytes"] < r["baseline_moved_bytes"]
        for r in payload["scenarios"]
    )
    payload["reuse_ok"] = reuse_ok
    payload["bytes_ok"] = bytes_ok

    path = write_results("delta", payload, mode="smoke" if smoke else "full")

    for r in payload["scenarios"]:
        kb = r["kind_bytes"]
        emit(
            f"delta/{r['scenario']}", r["delta_commit_ms"] * 1e3,
            f"resident={kb['resident']}B;local={kb['local']}B;"
            f"remote={kb['remote']}B;reused_layers={r['reused_layers']}/"
            f"{r['layers_total']};moved={r['delta_moved_bytes']}B"
            f"(baseline={r['baseline_moved_bytes']}B);"
            f"baseline_ms={r['baseline_commit_ms']:.1f}",
        )
    sweep = payload["size_sweep_tp_preserving"]
    ratio = sweep[-1]["commit_ms"] / max(sweep[0]["commit_ms"], 1e-9)
    byte_ratio = sweep[-1]["plan_bytes"] / max(sweep[0]["plan_bytes"], 1)
    emit(
        "delta/size_sweep", sweep[-1]["commit_ms"] * 1e3,
        f"latency_ratio={ratio:.2f}x_for_{byte_ratio:.0f}x_bytes",
    )
    emit("delta/json", 0.0, path)
    if check and not (reuse_ok and bytes_ok):
        raise SystemExit(
            f"delta gates failed: reuse_ok={reuse_ok} bytes_ok={bytes_ok}"
        )


if __name__ == "__main__":
    main(sys.argv[1:])
