"""Paper Fig. 6c: latency breakdown of a live reconfiguration event —
Transfer-and-Combine grows with model size; Switch stays sub-second.
Simulated breakdown + host-measured breakdown from real controller runs."""

from __future__ import annotations

from benchmarks.common import Timed, emit, run_with_devices
from repro.sim.cluster import PAPER_TESTBED
from repro.sim.liver_sim import SystemKind, reconfig_downtime


def main() -> None:
    for name, params in [("gpt-7b", 7e9), ("gpt-14b", 14e9), ("gpt-30b", 30e9)]:
        with Timed() as t:
            lv = reconfig_downtime(SystemKind.LIVER, PAPER_TESTBED, params, 32, 32)
        emit(
            f"fig6c/{name}", t.us,
            ";".join(f"{k}={v:.2f}s" for k, v in lv.phases.items())
            + " (paper: transfer 2-4s @14B, switch <0.5s)",
        )

    # host-measured commit-pause breakdown: stop-copy vs overlapped
    # streaming of the SAME reshape (dp2xtp2 -> dp1xtp4). Overlapped mode
    # pre-copies layers at iteration boundaries, re-syncs the dirty set
    # under the final grad computation, and pays only the residual tail +
    # grad-reshard + update + swap inside the pause.
    out = run_with_devices(
        """
        import time
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        for mode in ("stop_copy", "stream"):
            ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                                   seq_len=32, global_batch=8,
                                   overlap=mode, stream_k=2)
            ctrl.train_steps(2)
            ctrl.request_resize(ParallelConfig(dp=1, tp=4))
            t0 = time.time()
            while not ctrl.records and time.time() - t0 < 420:
                ctrl.train_steps(1)
            r = ctrl.records[0]
            print(f"HOST mode={mode} drain={r.drain_s*1e3:.1f}ms "
                  f"transfer={r.transfer_s*1e3:.1f}ms update={r.update_s*1e3:.1f}ms "
                  f"switch={r.switch_s*1e3:.2f}ms total_pause={r.total_pause_s*1e3:.1f}ms "
                  f"precopy={r.precopy_s*1e3:.1f}ms resync={r.resync_s*1e3:.1f}ms "
                  f"dispatch={r.stream_dispatch_s*1e3:.1f}ms "
                  f"stream_drain={r.stream_drain_s*1e3:.1f}ms "
                  f"generic_cells={r.generic_cells} "
                  f"dirty={r.dirty_layers}/{r.layers_total} "
                  f"prepare_overlapped={r.prepare_s:.1f}s moved={r.moved_bytes/1e6:.1f}MB")
            print(f"PAUSE {mode} {r.total_pause_s:.6f}")
        """,
    )
    pauses = {}
    for l in out.splitlines():
        if l.startswith("HOST mode="):
            mode = l.split("mode=")[1].split()[0]
            emit(f"fig6c/host_measured_{mode}", 0.0,
                 l.replace("HOST ", "").replace(" ", ";"))
        if l.startswith("PAUSE"):
            _, mode, val = l.split()
            pauses[mode] = float(val)
    if len(pauses) == 2:
        smaller = pauses["stream"] < pauses["stop_copy"]
        emit(
            "fig6c/overlap_vs_stopcopy", 0.0,
            f"stop_copy={pauses['stop_copy']*1e3:.1f}ms;"
            f"stream={pauses['stream']*1e3:.1f}ms;"
            f"commit_pause_smaller_under_overlap={smaller}",
        )


if __name__ == "__main__":
    main()
