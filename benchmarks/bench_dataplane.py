"""Reshard data-plane microbench (ISSUE 3 acceptance gate).

Measures, on the scattered-row (dirty re-sync) workload:

  * kernel-level pack/scatter throughput (``ops.pack_rows`` + the jitted
    fused overwrite-scatter), and
  * per-round streaming latency through ``ReshardEngine``/``LiveExecutor``
    — the fused pack -> staged put -> overwrite-scatter path vs the legacy
    per-run dynamic-update-slice chain (``LiveExecutor(fused=False)``) —
  * plus double-buffered ``OverlapSession`` round latency with its
    dispatch-vs-drain attribution,
  * plus the compressed wire format (DESIGN.md §14): int8-quantized
    streamed rounds vs lossless over an emulated fixed-bandwidth
    interconnect (host memcpys are ~free in this container, so wire cost
    is modeled as ``wire_bytes / bw`` — the documented deviation), with a
    per-row quantization-error parity check.

Emits the usual ``name,us,derived`` CSV rows and writes
``results/BENCH_dataplane.json`` so the perf trajectory is recorded run
over run. ``--smoke`` shrinks sizes for CI; ``--check`` exits nonzero
unless the fused path is strictly faster than the per-run DUS path AND
the quantized stream achieves >= 2x the lossless effective bandwidth
(logical bytes / wall second) at parity-passing accuracy.
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import emit, run_with_devices, write_results

_SNIPPET = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.intersection import TransferPlan, TransferTask
from repro.core.resource_view import TensorSpec
from repro.reshard import LiveExecutor, OverlapSession, ReshardEngine

R, C, ITERS, L = __R__, __C__, __ITERS__, __L__
name = "params/w"
spec = TensorSpec(name, (R, C), "float32", ("none", "none"), "all", "params")
mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
sh = NamedSharding(mesh, P(None, "model"))
rng = np.random.default_rng(0)
leaf = jax.device_put(jnp.asarray(rng.normal(size=(R, C)).astype(np.float32)), sh)

def row_task(r, layer, tensor=name, collection="params"):
    return TransferTask(tensor=tensor, collection=collection, src_rank=0,
                        dst_rank=1, bounds=((r, r + 1), (0, C)),
                        src_offset=(r, 0), dst_offset=(r, 0),
                        nbytes=C * 4, layer=layer)

# dirty re-sync workload: every other row of the tensor, one layer
rows = list(range(0, R, 2))
plan = TransferPlan(tasks=[row_task(r, 0) for r in rows],
                    cfg_src=None, cfg_dst=None)
budget = len(rows) * C * 4  # whole scatter in one staging batch
round_bytes = len(rows) * C * 4

# --- kernel-level throughput ----------------------------------------------
from repro.kernels import ops
starts = jnp.asarray(rows, jnp.int32)
buf = ops.pack_rows(leaf, starts, 1); buf.block_until_ready()  # warm
t0 = time.perf_counter()
for _ in range(ITERS):
    ops.pack_rows(leaf, starts, 1).block_until_ready()
pack_s = (time.perf_counter() - t0) / ITERS

scat = jax.jit(lambda d, b, s: ops.scatter_rows(d, b, s, 1))
dst0 = jnp.zeros((R, C), jnp.float32)
scat(dst0, buf, starts).block_until_ready()  # warm
t0 = time.perf_counter()
for _ in range(ITERS):
    scat(dst0, buf, starts).block_until_ready()
scatter_s = (time.perf_counter() - t0) / ITERS

# --- per-round streaming latency: fused vs per-run DUS --------------------
def time_path(fused):
    ex = LiveExecutor({name: spec}, {name: leaf}, {name: sh}, budget, fused=fused)
    eng = ReshardEngine(plan, ex, staging_bytes=budget)
    eng.run(); ex.block_until_ready()  # warm caches + carry
    ts = []
    for _ in range(ITERS):
        ex.reset_round()
        t0 = time.perf_counter()
        s = eng.run()
        ex.block_until_ready()
        ts.append(time.perf_counter() - t0)
    got = np.asarray(jax.device_get(ex.results()[name]))
    exp = np.zeros((R, C), np.float32); exp[rows] = np.asarray(leaf)[rows]
    np.testing.assert_array_equal(got, exp)  # both paths move the same bytes
    return min(ts), s

legacy_s, _ = time_path(False)
fused_s, fstats = time_path(True)

# --- double-buffered OverlapSession rounds --------------------------------
band = R // L
lplan = TransferPlan(
    tasks=[row_task(l * band + o, l) for l in range(L)
           for o in range(0, band, 2)],
    cfg_src=None, cfg_dst=None)
sess = OverlapSession([spec], lplan, {}, {name: sh}, budget, stream_k=2)
t0 = time.perf_counter()
rounds = 0
while not sess.done_precopy:
    sess.stream_next({name: leaf}, step=0)
    rounds += 1
sess.drain()
precopy_s = time.perf_counter() - t0
t0 = time.perf_counter()
sess.resync({name: leaf}, step=1)
resync_s = time.perf_counter() - t0

# --- compressed wire format: quantized vs lossless streamed rounds --------
# Host "transfers" here are memcpys, so payload size cannot show up in wall
# time on its own; an emulated fixed-bandwidth wire (LiveExecutor blocks
# wire_bytes / bw per crossing) makes effective bandwidth = logical bytes /
# wall second measurable. Documented deviation, DESIGN.md §14.
from repro.reshard.wire import WirePolicy

WIRE_BW = round_bytes * 8.0  # lossless round sleeps ~125 ms on the wire
mname = "mu/w"
mspec = TensorSpec(mname, (R, C), "float32", ("none", "none"), "all", "mu")
mleaf = jax.device_put(jnp.asarray(rng.normal(size=(R, C)).astype(np.float32)), sh)
mplan = TransferPlan(tasks=[row_task(r, 0, mname, "mu") for r in rows],
                     cfg_src=None, cfg_dst=None)

def time_wire(policy):
    ex = LiveExecutor({mname: mspec}, {mname: mleaf}, {mname: sh}, budget,
                      wire_policy=policy, wire_bw_bytes_s=WIRE_BW)
    eng = ReshardEngine(mplan, ex, staging_bytes=budget, wire_policy=policy)
    eng.run(); ex.block_until_ready()  # warm caches + carry
    ts = []
    for _ in range(ITERS):
        ex.reset_round()
        t0 = time.perf_counter()
        s = eng.run()
        ex.block_until_ready()
        ts.append(time.perf_counter() - t0)
    return min(ts), s, np.asarray(jax.device_get(ex.results()[mname]))

lossless_t, lstats, lgot = time_wire(None)
quant_t, qstats, qgot = time_wire(WirePolicy())

msrc = np.asarray(jax.device_get(mleaf))
mexp = np.zeros((R, C), np.float32); mexp[rows] = msrc[rows]
lossless_exact = bool(np.array_equal(lgot, mexp))
# int8 round-trip parity: per-row error <= half a quantization step
scales = np.maximum(np.abs(msrc[rows]).max(axis=1), 1e-12) * (1.0 / 127.0)
err = np.abs(qgot[rows] - msrc[rows])
untouched = np.ones(R, bool); untouched[rows] = False
quant_parity = bool(
    (err <= scales[:, None] * 0.5001 + 1e-12).all()
    and not np.any(qgot[untouched])
)
eff_l = round_bytes / lossless_t
eff_q = round_bytes / quant_t

print("JSON " + json.dumps({
    "config": {"R": R, "C": C, "iters": ITERS, "scattered_rows": len(rows),
               "round_bytes": round_bytes},
    "kernel": {
        "pack_ms": pack_s * 1e3,
        "pack_gbps": round_bytes / pack_s / 1e9,
        "scatter_ms": scatter_s * 1e3,
        "scatter_gbps": round_bytes / scatter_s / 1e9,
    },
    "round_scattered": {
        "legacy_dus_ms": legacy_s * 1e3,
        "fused_ms": fused_s * 1e3,
        "speedup": legacy_s / fused_s,
        "gbps_fused": round_bytes / fused_s / 1e9,
        "generic_cells": fstats.generic_cells,
    },
    "overlap": {
        "rounds": rounds,
        "precopy_ms": precopy_s * 1e3,
        "dispatch_ms": sess.report.dispatch_seconds * 1e3,
        "drain_ms": sess.report.drain_seconds * 1e3,
        "resync_ms": resync_s * 1e3,
    },
    "compression": {
        "wire_bw_bytes_s": WIRE_BW,
        "lossless_ms": lossless_t * 1e3,
        "quant_ms": quant_t * 1e3,
        "logical_bytes": qstats.logical_bytes,
        "wire_bytes": qstats.wire_bytes,
        "wire_shrink": qstats.logical_bytes / max(qstats.wire_bytes, 1),
        "eff_bw_lossless_bps": eff_l,
        "eff_bw_quant_bps": eff_q,
        "eff_bw_ratio": eff_q / eff_l,
        "lossless_exact": lossless_exact,
        "quant_parity": quant_parity,
    },
}))
"""


def main(argv=()) -> None:
    smoke = "--smoke" in argv
    check = "--check" in argv
    R, C, iters, L = (512, 256, 2, 4) if smoke else (4096, 1024, 5, 8)
    code = (
        _SNIPPET.replace("__R__", str(R))
        .replace("__C__", str(C))
        .replace("__ITERS__", str(iters))
        .replace("__L__", str(L))
    )
    out = run_with_devices(code, n_devices=8)
    payload = None
    for line in out.splitlines():
        if line.startswith("JSON "):
            payload = json.loads(line[5:])
    assert payload is not None, f"no JSON payload in bench output:\n{out[-2000:]}"
    payload["fused_faster"] = (
        payload["round_scattered"]["fused_ms"]
        < payload["round_scattered"]["legacy_dus_ms"]
    )
    c = payload["compression"]
    payload["compression_2x"] = (
        c["eff_bw_ratio"] >= 2.0 and c["quant_parity"] and c["lossless_exact"]
    )

    path = write_results(
        "dataplane", payload, mode="smoke" if smoke else "full"
    )

    k, r, o = payload["kernel"], payload["round_scattered"], payload["overlap"]
    emit("dataplane/pack", k["pack_ms"] * 1e3, f"{k['pack_gbps']:.2f}GB/s")
    emit("dataplane/scatter", k["scatter_ms"] * 1e3, f"{k['scatter_gbps']:.2f}GB/s")
    emit(
        "dataplane/round_scattered", r["fused_ms"] * 1e3,
        f"legacy_dus={r['legacy_dus_ms']:.1f}ms;fused={r['fused_ms']:.1f}ms;"
        f"speedup={r['speedup']:.2f}x;generic_cells={r['generic_cells']};"
        f"fused_faster={payload['fused_faster']}",
    )
    emit(
        "dataplane/overlap_rounds", o["precopy_ms"] * 1e3,
        f"rounds={o['rounds']};dispatch={o['dispatch_ms']:.1f}ms;"
        f"drain={o['drain_ms']:.1f}ms;resync={o['resync_ms']:.1f}ms",
    )
    emit(
        "dataplane/compressed_round", c["quant_ms"] * 1e3,
        f"lossless={c['lossless_ms']:.1f}ms;quant={c['quant_ms']:.1f}ms;"
        f"eff_bw_ratio={c['eff_bw_ratio']:.2f}x;"
        f"wire_shrink={c['wire_shrink']:.2f}x;"
        f"parity={c['quant_parity']};lossless_exact={c['lossless_exact']}",
    )
    emit("dataplane/json", 0.0, path)
    if check and not payload["fused_faster"]:
        raise SystemExit(
            f"fused path not faster: {r['fused_ms']:.1f}ms vs "
            f"legacy {r['legacy_dus_ms']:.1f}ms"
        )
    if check and not payload["compression_2x"]:
        raise SystemExit(
            f"compressed wire below 2x effective bandwidth: "
            f"ratio={c['eff_bw_ratio']:.2f}x parity={c['quant_parity']} "
            f"lossless_exact={c['lossless_exact']}"
        )


if __name__ == "__main__":
    main(sys.argv[1:])
