"""Paper Fig. 6b: storage-bandwidth sensitivity (GPT-14B). Checkpoint
systems degrade sharply at low bandwidth; LiveR is storage-independent."""

from __future__ import annotations

from benchmarks.common import Timed, emit
from repro.sim.cluster import PAPER_TESTBED
from repro.sim.liver_sim import SystemKind, reconfig_downtime


def main() -> None:
    for bw in (0.25, 0.5, 1.0, 2.0):
        with Timed() as t:
            mk = reconfig_downtime(
                SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 14e9, 32, 32,
                storage_bw_override=bw,
            )
            lv = reconfig_downtime(
                SystemKind.LIVER, PAPER_TESTBED, 14e9, 32, 32,
                storage_bw_override=bw,
            )
        emit(
            f"fig6b/bw_{bw}gbps", t.us,
            f"megatron_load={mk.phases['ckpt_load']:.1f}s;"
            f"megatron_total={mk.total:.1f}s;liver={lv.total:.2f}s"
            + (";(paper: >300s load at 0.25 — our Table-1-exact calibration"
               " gives 140s; trend 8x identical)" if bw == 0.25 else ""),
        )


if __name__ == "__main__":
    main()
