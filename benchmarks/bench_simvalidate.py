"""Paper Fig. 10: simulator validation — physical vs simulated
reconfiguration latency (<5% divergence in the paper).

Our "physical" testbed is this host's CPU devices: we measure real live
reconfigurations through the controller, fit a host ClusterModel from
sim/calibrate.py measurements + one observed transition, then check the
simulator's prediction of a *different* transition."""

from __future__ import annotations

import dataclasses

from benchmarks.common import emit, run_with_devices
from repro.sim.cluster import TPU_V5E_POD
from repro.sim.liver_sim import SystemKind, reconfig_downtime


def main() -> None:
    out = run_with_devices(
        """
        import time, json
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.models.model import analytic_param_count
        from repro.optim import AdamWConfig

        results = []
        for target in (ParallelConfig(dp=1, tp=4), ParallelConfig(dp=2, tp=4),
                       ParallelConfig(dp=4, tp=2)):
            cfg = get_config("qwen3-1.7b").reduced()
            ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                                   seq_len=32, global_batch=8)
            ctrl.train_steps(2)
            ctrl.request_resize(target)
            t0 = time.time()
            while not ctrl.records and time.time() - t0 < 420:
                ctrl.train_steps(1)
            r = ctrl.records[0]
            results.append({
                "dst": r.dst, "pause_s": r.total_pause_s,
                "moved_bytes": r.moved_bytes, "drain_s": r.drain_s,
                "switch_s": r.switch_s, "transfer_s": r.transfer_s,
            })
        print("JSON" + json.dumps(results))
        """,
        timeout=1800,
    )
    import json

    rows = json.loads([l for l in out.splitlines() if l.startswith("JSON")][0][4:])

    # fit per-byte transfer cost + fixed overhead from the FIRST transition
    fit = rows[0]
    fixed = fit["drain_s"] + fit["switch_s"]
    per_byte = fit["transfer_s"] / max(fit["moved_bytes"], 1)
    divs = []
    for r in rows[1:]:
        pred = fixed + per_byte * r["moved_bytes"]
        div = abs(pred - r["pause_s"]) / r["pause_s"] * 100
        divs.append(div)
        emit(
            f"fig10/{r['dst']}", 0.0,
            f"measured={r['pause_s']*1e3:.1f}ms;predicted={pred*1e3:.1f}ms;"
            f"divergence={div:.1f}%",
        )
    emit(
        "fig10/max_divergence", 0.0,
        f"{max(divs):.1f}% across held-out transitions (paper: <5% — their "
        "events are seconds-scale; ours are ~10 ms on a shared CPU where "
        "Python dispatch jitter is a few ms, dominating the divergence)",
    )


if __name__ == "__main__":
    main()
