"""Shared benchmark helpers: CSV emission + multi-device subprocess runner."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
RESULTS = os.path.join(REPO, "results")


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{r.stderr[-3000:]}")
    return r.stdout


class Timed:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
        return False
