"""Shared benchmark helpers: CSV emission, the ``BENCH_*.json`` artifact
envelope, and the multi-device subprocess runner.

Output contract (documented for trajectory tooling in results/README.md):
``emit`` prints one ``name,us_per_call,derived`` CSV row per metric;
``write_results`` persists a benchmark's structured payload under
``results/BENCH_<name>.json`` with a standard envelope so artifacts are
self-describing across runs.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
RESULTS = os.path.join(REPO, "results")

# bump when the envelope fields below change shape
RESULTS_SCHEMA = 1


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def write_results(name: str, payload: dict, mode: str | None = None) -> str:
    """Persist ``results/BENCH_<name>.json`` with the standard envelope
    (schema in results/README.md) and return the path. ``mode`` tags the
    run variant (e.g. "smoke" vs "full")."""
    doc = {
        "bench": name,
        "schema": RESULTS_SCHEMA,
        "unix_time": time.time(),
    }
    if mode is not None:
        doc["mode"] = mode
    doc.update(payload)
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=str)
    return path


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=timeout,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{r.stderr[-3000:]}")
    return r.stdout


class Timed:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
        return False
