"""Paper Fig. 11: 70B-parameter model on 1024 GPUs — simulated cold restart
vs LiveR (paper: ~565s vs ~11s, 50x). Plus the same projection for the
TPU-v5e multi-pod target and the preparation-vs-warning-window check
(paper §7)."""

from __future__ import annotations

from benchmarks.common import Timed, emit
from repro.sim.cluster import PAPER_TESTBED, TPU_V5E_POD
from repro.sim.liver_sim import SystemKind, reconfig_downtime


def main() -> None:
    with Timed() as t:
        mk = reconfig_downtime(SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 70e9, 1024, 1024)
        lv = reconfig_downtime(SystemKind.LIVER, PAPER_TESTBED, 70e9, 1024, 1024)
    emit(
        "fig11/70b_1024gpu_a800", t.us,
        f"restart={mk.total:.0f}s;liver={lv.total:.1f}s;"
        f"improvement={mk.total/lv.total:.0f}x (paper: ~565s vs ~11s = 50x)",
    )

    with Timed() as t:
        mk2 = reconfig_downtime(SystemKind.MEGATRON_CKPT, TPU_V5E_POD, 70e9, 512, 512)
        lv2 = reconfig_downtime(SystemKind.LIVER, TPU_V5E_POD, 70e9, 512, 512)
    emit(
        "fig11/70b_512chip_v5e_target", t.us,
        f"restart={mk2.total:.0f}s;liver={lv2.total:.2f}s;"
        f"improvement={mk2.total/lv2.total:.0f}x",
    )

    # preparation vs 120 s spot warning (paper §7: 90-150 s at 1024 GPUs)
    prep = PAPER_TESTBED.prepare_s(1024)
    emit(
        "fig11/prepare_vs_warning", 0.0,
        f"prepare={prep:.0f}s vs 120s spot notice "
        f"({'fits' if prep < 120 else 'needs proactive trigger'}; "
        "paper: 90-150s, proactive triggering recommended)",
    )


if __name__ == "__main__":
    main()
