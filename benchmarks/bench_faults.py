"""Fault-injection matrix for peer recovery (DESIGN.md §15).

Kills device subsets at every phase of the reconfiguration lifecycle —
idle boundary, mid-stream (pre-copy layers outstanding), mid-commit
(split-step switch armed) — crossed with both redundancy schemes:

* **dp-donor**: dp=2 world loses a whole replica's devices; surviving DP
  peers donate the dead ranks' shards over the recovery stream.
* **dp1-parity**: dp=1 world loses a tp-shard owner whose bytes exist
  nowhere else; the idle-boundary XOR parity word reconstructs them.

Every cell of the matrix must end ``peer_recover``/``committed`` with the
step preserved (no rollback) and training live afterwards. Results land in
``results/BENCH_faults.json``; ``--check`` exits nonzero when any cell
demoted to the checkpoint rung, rolled the step back, or failed to train
after recovery.
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import emit, run_with_devices, write_results

_SNIPPET = """
import json, time
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.controller import LiveRController
from repro.elastic import FaultInjector
from repro.optim import AdamWConfig

SMOKE = __SMOKE__
cfg = get_config("qwen3-1.7b").reduced()
PHASES = ("idle", "mid_stream", "mid_commit")

SCHEMES = {
    # scheme -> (start topology, parity_every, resize during which to kill,
    #            post-failure target, lost ranks)
    "dp_donor": (ParallelConfig(dp=2, tp=2), 0, ParallelConfig(dp=4, tp=2),
                 ParallelConfig(dp=1, tp=2), (2, 3)),
    "dp1_parity": (ParallelConfig(dp=1, tp=2), 1, ParallelConfig(dp=1, tp=4),
                   ParallelConfig(dp=1, tp=1), (1,)),
}

cells = []
for scheme, (src, parity_every, mid, target, lost) in SCHEMES.items():
    for phase in PHASES:
        ctrl = LiveRController(
            cfg, src, AdamWConfig(learning_rate=1e-3),
            seq_len=16, global_batch=4, ckpt_dir=None,
            parity_every=parity_every,
            overlap="stream", stream_k=1, sync_compile=True,
        )
        ctrl.train_steps(3)
        inj = FaultInjector(ctrl)
        t0 = time.perf_counter()
        rep = inj.inject(phase, target, lost_ranks=lost, resize_target=mid)
        wall = time.perf_counter() - t0
        ctrl.train_steps(2)  # liveness after recovery
        cells.append({
            "scheme": scheme, "phase": rep.phase,
            "lost_ranks": list(rep.lost_ranks),
            "mode": rep.mode, "outcome": rep.outcome,
            "demoted": rep.demoted,
            "step_before": rep.step_before, "step_after": rep.step_after,
            "donors": rep.donors, "parity_bytes": rep.parity_bytes,
            "pause_s": rep.pause_s, "wall_s": wall,
            "post_world": ctrl.world.parallel.describe(),
            "post_step": ctrl.step,
        })
print("JSON " + json.dumps({"cells": cells}))
"""


def main(argv=()) -> None:
    smoke = "--smoke" in argv
    check = "--check" in argv
    code = _SNIPPET.replace("__SMOKE__", repr(smoke))
    out = run_with_devices(code, n_devices=8, timeout=1800)
    payload = None
    for line in out.splitlines():
        if line.startswith("JSON "):
            payload = json.loads(line[5:])
    assert payload is not None, f"no JSON payload in bench output:\n{out[-2000:]}"

    path = write_results("faults", payload, mode="smoke" if smoke else "full")

    cells = payload["cells"]
    for c in cells:
        emit(
            f"faults/{c['scheme']}/{c['phase']}", c["pause_s"] * 1e6,
            f"mode={c['mode']};outcome={c['outcome']};donors={c['donors']};"
            f"parity_bytes={c['parity_bytes']};"
            f"step={c['step_before']}->{c['step_after']}",
        )
    emit("faults/json", 0.0, path)

    if check:
        bad = [
            c for c in cells
            if c["mode"] != "peer_recover" or c["outcome"] != "committed"
        ]
        if bad:
            raise SystemExit(f"cells demoted or failed: {bad}")
        rolled = [c for c in cells if c["step_after"] != c["step_before"]]
        if rolled:
            raise SystemExit(f"cells rolled the step back: {rolled}")
        schemes = {c["scheme"] for c in cells}
        phases = {c["phase"] for c in cells}
        if len(cells) < len(schemes) * 3 or phases != {
            "idle", "mid_stream", "mid_commit"
        }:
            raise SystemExit(f"matrix incomplete: {sorted(phases)}")
        parity_cells = [c for c in cells if c["scheme"] == "dp1_parity"]
        if not any(c["parity_bytes"] > 0 for c in parity_cells):
            raise SystemExit("dp1_parity cells never used the parity word")


if __name__ == "__main__":
    main(sys.argv[1:])
