"""Paper Figs. 7 & 8: training efficiency under volatility regimes and the
24-hour / 47-event wasted-GPU-hours comparison."""

from __future__ import annotations

from benchmarks.common import Timed, emit
from repro.sim.cluster import PAPER_TESTBED
from repro.sim.liver_sim import SystemKind, volatility_run
from repro.sim.volatility import REGIMES, make_trace, paper_24h_trace

PAPER_FIG7 = {
    "low": {"liver": 99.0, "ucp": 95.5, "megatron_ckpt": 95.2},
    "medium": {"liver": 99.0, "ucp": 85.6, "megatron_ckpt": 79.8},
    "high": {"liver": 99.1, "ucp": 61.3, "megatron_ckpt": 58.2},
}


def main() -> None:
    for regime, interval in REGIMES.items():
        tr = make_trace(8 * 3600, interval, seed=2)
        vals = {}
        with Timed() as t:
            for k in SystemKind:
                vals[k.value] = volatility_run(
                    k, PAPER_TESTBED, 14e9, tr, 8 * 3600, 32
                ).goodput * 100
        emit(
            f"fig7/{regime}", t.us,
            ";".join(
                f"{k}={v:.1f}%(paper {PAPER_FIG7[regime][k]:.1f}%)"
                for k, v in vals.items()
            ),
        )

    tr = paper_24h_trace()
    with Timed() as t:
        rows = {
            k.value: volatility_run(k, PAPER_TESTBED, 14e9, tr, 24 * 3600, 32)
            for k in SystemKind
        }
    m, u, l = rows["megatron_ckpt"], rows["ucp"], rows["liver"]
    emit(
        "fig8/wasted_gpu_hours", t.us,
        f"megatron={m.wasted_gpu_hours:.1f};ucp={u.wasted_gpu_hours:.1f};"
        f"liver={l.wasted_gpu_hours:.1f} (paper: 80+ vs 4.1)",
    )
    emit(
        "fig8/pause_minutes", 0.0,
        f"megatron={m.reconfig_pause_s/60:.0f};ucp={u.reconfig_pause_s/60:.0f};"
        f"liver={l.reconfig_pause_s/60:.1f} (paper: >130 / 100+ / 7; "
        f"improvement {u.reconfig_pause_s/max(l.reconfig_pause_s,1e-9):.1f}x vs best baseline, paper 14.2x)",
    )
    emit(
        "fig8/goodput", 0.0,
        f"megatron={m.goodput*100:.1f}%;ucp={u.goodput*100:.1f}%;"
        f"liver={l.goodput*100:.2f}% (paper: 91 / 93 / 99.5)",
    )


if __name__ == "__main__":
    main()
