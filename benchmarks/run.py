"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig6a fig8 # subset by tag

Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import sys
import traceback

BENCHES = [
    ("table1", "benchmarks.bench_restart_breakdown"),
    ("fig6a", "benchmarks.bench_reconfig"),
    ("fig6b", "benchmarks.bench_storage"),
    ("fig6c", "benchmarks.bench_breakdown"),
    ("fig6d", "benchmarks.bench_interference"),
    ("fig7_8", "benchmarks.bench_volatility"),
    ("fig9", "benchmarks.bench_parity"),
    ("fig10", "benchmarks.bench_simvalidate"),
    ("fig11", "benchmarks.bench_scale"),
    ("plan", "benchmarks.bench_plan"),
    ("movefrac", "benchmarks.bench_move_fraction"),
    ("roofline", "benchmarks.bench_roofline"),
    ("dataplane", "benchmarks.bench_dataplane"),
    ("delta", "benchmarks.bench_delta"),
    ("goodput", "benchmarks.bench_goodput"),
    ("faults", "benchmarks.bench_faults"),
    ("serve", "benchmarks.bench_serve_goodput"),
    ("fleet", "benchmarks.bench_fleet"),
]


def main() -> None:
    args = sys.argv[1:]
    if "--help" in args or "-h" in args:
        print(__doc__.strip())
        print("\nTags:")
        for tag, module in BENCHES:
            print(f"  {tag:10s} {module}")
        return
    tags = set(args)
    unknown = tags - {tag for tag, _ in BENCHES}
    if unknown:
        sys.exit(f"unknown tags {sorted(unknown)}; run with --help for the list")
    print("name,us_per_call,derived")
    failures = []
    for tag, module in BENCHES:
        if tags and tag not in tags:
            continue
        try:
            mod = __import__(module, fromlist=["main"])
            mod.main()
        except Exception as e:  # keep the suite going
            failures.append((tag, e))
            print(f"{tag}/ERROR,0,{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
