"""Paper Fig. 6d: steady-state interference of Shadow World construction —
iteration times with vs without a concurrent background build (paper:
0.28% mean delta, no spikes). Host-measured with real compiles."""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices


def main() -> None:
    out = run_with_devices(
        """
        import time, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                               seq_len=64, global_batch=8)
        ctrl.train_steps(10)  # warmup
        base = ctrl.train_steps(30)
        base_t = np.array(ctrl.iteration_times[-30:])

        ctrl.request_resize(ParallelConfig(dp=2, tp=4))
        during = []
        while ctrl._builder is not None and not ctrl._builder.ready:
            t0 = time.perf_counter()
            ctrl.train_steps(1)
            during.append(ctrl.iteration_times[-1])
            if len(during) >= 400: break
        during_t = np.array(during[:len(during)]) if during else base_t
        delta = (during_t.mean() - base_t.mean()) / base_t.mean() * 100
        spike = during_t.max() / np.median(base_t)
        print(f"IFX base_ms={base_t.mean()*1e3:.2f} during_ms={during_t.mean()*1e3:.2f} "
              f"delta_pct={delta:.2f} steps_during={len(during)} max_spike_x={spike:.2f}")
        """,
        timeout=1500,
    )
    line = [l for l in out.splitlines() if l.startswith("IFX")][0]
    emit(
        "fig6d/steady_state_interference", 0.0,
        line.replace("IFX ", "").replace(" ", ";")
        + " (paper: 0.28% delta; NOTE single-CPU host shares cores between "
        "compile thread and step — a TPU pod does not)",
    )


if __name__ == "__main__":
    main()
