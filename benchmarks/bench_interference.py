"""Paper Fig. 6d: steady-state interference of Shadow World construction —
iteration times with vs without a concurrent background build (paper:
0.28% mean delta, no spikes). Host-measured with real compiles. A second
phase measures the same interference for a *speculative* warm-pool build
(``prefetch_world``, DESIGN.md §12) — identical build machinery, so the
expectation is the same profile."""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices


def main() -> None:
    out = run_with_devices(
        """
        import time, numpy as np
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.world_pool import WorldPool
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                               seq_len=64, global_batch=8,
                               world_pool=WorldPool(capacity=2))
        ctrl.train_steps(10)  # warmup
        base = ctrl.train_steps(30)
        base_t = np.array(ctrl.iteration_times[-30:])

        def measure(still_building):
            xs = []
            while still_building():
                ctrl.train_steps(1)
                xs.append(ctrl.iteration_times[-1])
                if len(xs) >= 400: break
            return np.array(xs) if xs else base_t

        ctrl.request_resize(ParallelConfig(dp=2, tp=4))
        during_t = measure(
            lambda: ctrl._builder is not None and not ctrl._builder.ready)
        delta = (during_t.mean() - base_t.mean()) / base_t.mean() * 100
        spike = during_t.max() / np.median(base_t)

        # let the resize commit so the controller is idle, then measure a
        # speculative pool build of the config we just left (pool already
        # holds it from the retire -> evict it to force a real build)
        while not ctrl.records:
            ctrl.train_steps(1)
        target = ParallelConfig(dp=2, tp=2)
        ctrl.world_pool.evict(ctrl.pool_key(target))
        assert ctrl.prefetch_world(target), "speculative build did not start"
        spec_t = measure(lambda: bool(ctrl._spec_builders))
        sdelta = (spec_t.mean() - base_t.mean()) / base_t.mean() * 100
        sspike = spec_t.max() / np.median(base_t)

        print(f"IFX base_ms={base_t.mean()*1e3:.2f} during_ms={during_t.mean()*1e3:.2f} "
              f"delta_pct={delta:.2f} steps_during={len(during_t)} max_spike_x={spike:.2f} "
              f"spec_ms={spec_t.mean()*1e3:.2f} spec_delta_pct={sdelta:.2f} "
              f"spec_spike_x={sspike:.2f} pool_puts={ctrl.world_pool.stats.puts}")
        """,
        timeout=1500,
    )
    line = [l for l in out.splitlines() if l.startswith("IFX")][0]
    emit(
        "fig6d/steady_state_interference", 0.0,
        line.replace("IFX ", "").replace(" ", ";")
        + " (paper: 0.28% delta; NOTE single-CPU host shares cores between "
        "compile thread and step — a TPU pod does not; spec_* = warm-pool "
        "speculative build, same expectation)",
    )


if __name__ == "__main__":
    main()
