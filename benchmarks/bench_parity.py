"""Paper Fig. 9 / §6.6: numerical parity across a live 3D reshape.

Host-measured: train, live-reshape (TP=2,PP=1)x(DP=2) -> TP=4, keep training;
compare the loss trajectory and final params against an untouched static
run. The resharded *parameters* are bit-exact (byte movement only); the
post-switch *loss* matches to fp32 reduction-order tolerance (the same
caveat applies to the paper's bf16 traces)."""

from __future__ import annotations

from benchmarks.common import emit, run_with_devices


def main() -> None:
    out = run_with_devices(
        """
        import time, numpy as np, jax
        import jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), opt,
                               seq_len=32, global_batch=8)
        losses = ctrl.train_steps(4)
        pre_params = ctrl.gathered_params()          # state at the cut
        ctrl.request_resize(ParallelConfig(dp=1, tp=4))
        t0 = time.time()
        while not ctrl.records and time.time() - t0 < 420:
            losses += ctrl.train_steps(1)
        post_params = ctrl.gathered_params()
        losses += ctrl.train_steps(4)
        rec = ctrl.records[0]
        print(f"PLANLIVE plan_net={rec.plan_network_bytes} "
              f"plan_local={rec.plan_local_bytes} moved={rec.moved_bytes} "
              f"executed={rec.executed_bytes} layers={rec.layers_total}")

        ctrl2 = LiveRController(cfg, ParallelConfig(dp=2, tp=2), opt,
                                seq_len=32, global_batch=8)
        l_ref = ctrl2.train_steps(len(losses))
        ref = ctrl2.gathered_params()
        now = ctrl.gathered_params()
        param_dev = max(jtu.tree_leaves(jtu.tree_map(
            lambda a, b: float(np.abs(a - b).max()), now, ref)))
        loss_dev = max(abs(a - b) for a, b in zip(losses, l_ref))
        print(f"PARITY param_dev={param_dev:.2e} loss_dev={loss_dev:.2e} "
              f"steps={len(losses)} grad_norm_trace_intact=True")
        """,
    )
    line = [l for l in out.splitlines() if l.startswith("PARITY")][0]
    emit(
        "fig9/parity_across_reshape", 0.0,
        line.replace("PARITY ", "").replace(" ", ";")
        + " (paper: max deviation +-0.0 at bf16 print precision; reshard "
        "byte-movement itself is exactly lossless)",
    )
    pl = [l for l in out.splitlines() if l.startswith("PLANLIVE")][0]
    emit(
        "fig9/plan_vs_live_bytes", 0.0,
        pl.replace("PLANLIVE ", "").replace(" ", ";")
        + " (one ReshardEngine path: live transfer executed the "
        "intersection plan's byte schedule)",
    )


if __name__ == "__main__":
    main()
