"""Paper App. A.2.3: transfer-planning speed — 'for a 175B-parameter model
with 96 layers and 1024 ranks, the entire plan is generated in under 1
second'. Measures our planner at increasing rank counts on a 175B-like
tensor set (layer-coarse tasks, as the paper's planner emits)."""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer
from repro.core.resource_view import TensorSpec


def _specs_175b(layers=96, d=12288, ff=49152, vocab=50304):
    """Llama/GPT-175B-shaped logical tensors, layer-stacked."""
    mk = lambda n, shape, roles: TensorSpec(
        f"params/blocks/pos0/{n}", shape, "float32", roles, "stages", "params"
    )
    return [
        mk("wq", (layers, d, d), ("pp", "none", "tp")),
        mk("wk", (layers, d, d), ("pp", "none", "tp")),
        mk("wv", (layers, d, d), ("pp", "none", "tp")),
        mk("wo", (layers, d, d), ("pp", "tp", "none")),
        mk("wi", (layers, d, ff), ("pp", "none", "tp")),
        mk("wo2", (layers, ff, d), ("pp", "tp", "none")),
        TensorSpec("params/embed/tok", (vocab, d), "float32", ("tp", "none"),
                   "first", "params"),
        TensorSpec("params/lm_head/w", (d, vocab), "float32", ("none", "tp"),
                   "last", "params"),
    ]


def main() -> None:
    specs = _specs_175b()
    for (ca, cb) in [
        (ParallelConfig(dp=2, pp=8, tp=8), ParallelConfig(dp=4, pp=4, tp=8)),   # 128->128
        (ParallelConfig(dp=4, pp=8, tp=8), ParallelConfig(dp=8, pp=4, tp=8)),   # 256->256
        (ParallelConfig(dp=8, pp=16, tp=8), ParallelConfig(dp=16, pp=8, tp=8)),  # 1024->1024
    ]:
        t0 = time.perf_counter()
        plan = plan_transfer(specs, ca, cb, layer_granular=False)
        dt = time.perf_counter() - t0
        emit(
            f"plan/{ca.world_size}ranks", dt * 1e6,
            f"{len(plan.tasks)} tasks;{plan.network_bytes/1e9:.1f}GB net;"
            f"{dt:.3f}s (paper: <1s at 1024 ranks)",
        )


if __name__ == "__main__":
    main()
