"""Measured live goodput under an elasticity-event trace (ISSUE 4 gate).

Replays a spot-style trace through :class:`ElasticScheduler` driving the
REAL ``LiveRController`` on 8 host devices — every commit, retarget,
coalesce, deadline fallback and fail-stop recovery actually executes on
live JAX state — and reports the measured goodput (from the controller's
``GoodputLedger``: real pauses over real wall clock) next to the analytic
``sim.liver_sim.volatility_run`` prediction for the same event sequence,
the number the paper's Figs. 7–8 are built from.

The controller runs with a speculative warm :class:`WorldPool` and the
scheduler's prefetch policy (DESIGN.md §12): retired/abandoned/prefetched
worlds serve later resizes warm, skipping lower+compile. The payload's
``measured.warm_cold`` section breaks prepare time down by warm vs cold.

The controller runs with the compressed wire format (DESIGN.md §14:
optimizer moments cross the wire int8-quantized); in smoke mode an
emulated fixed-bandwidth interconnect plus one calibrated warning window
make the lattice promote one event to the overlap rung *only because* of
compression — its ``decision_lossless`` counterfactual lands on a lower
rung.

``--smoke`` replays a fixed 8-event trace exercising every rung of the
fallback lattice (compression-promoted stream commit, retarget, coalesce,
zero-window peer recovery, unannounced fail-stop recovered from peer
replicas, stream commit, tp-preserving shrink that classifies fully
resident); ``--check`` exits nonzero unless the scheduler replayed >= 5
events with zero ``aborted`` outcomes AND zero ``fell_back`` outcomes (no
event may touch the demoted checkpoint rung, DESIGN.md §15), the
fail-stop's recovery pause lands within 5x of the worst streamed resize
commit pause, at least one resize was served warm from the pool, warm
prepare beat cold by >= 5x, at least one record reports
``reused_layers > 0`` (the delta plan IR skipped in-place layers), every
record satisfies the cell-level reuse identity (``reuse_identity_ok``),
and at least one committed stream event was rung-promoted by the
compressed wire. The full mode
replays a seeded ``spot_trace`` with live deadline decisions. Results
land in ``results/BENCH_goodput.json``.
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import emit, run_with_devices, write_results

_SNIPPET = """
import json, statistics, tempfile
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.controller import LiveRController
from repro.core.events import FailStopEvent, ResizeEvent
from repro.core.reshard import plan_state_transfer
from repro.core.world_pool import WorldPool
from repro.reshard.wire import WirePolicy, wire_nbytes
from repro.elastic import (
    DeadlineEstimator, ElasticScheduler, PrefetchPolicy, events_from_trace,
)
from repro.optim import AdamWConfig
from repro.sim.cluster import PAPER_TESTBED
from repro.sim.liver_sim import SystemKind, volatility_run
from repro.sim.volatility import spot_trace

SMOKE = __SMOKE__
cfg = get_config("qwen3-1.7b").reduced()
ctrl = LiveRController(
    cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(learning_rate=1e-3),
    seq_len=32, global_batch=8, ckpt_dir=tempfile.mkdtemp(prefix="goodput_"),
    ckpt_interval=2, overlap="stream", stream_k=2, sync_compile=SMOKE,
    world_pool=WorldPool(capacity=4),
    # compressed wire format (DESIGN.md §14): optimizer moments cross the
    # wire int8-quantized, params stay lossless
    wire_policy=WirePolicy(),
)
# warm-up: compile amortized, a durable checkpoint on disk (last-resort
# rung only — the gate below requires it stays untouched), and
# iteration_times seeded for the deadline estimator
ctrl.train_steps(4)

# planned resizes with no deadline pressure at all: the window arithmetic
# is inf-safe end to end and serializes as the string "inf"
BIG = float("inf")
SAFETY = 1.25  # ElasticScheduler default
if SMOKE:
    # calibrate an emulated wire + a finite warning window so that ONE
    # event (the FIRST in the trace, decided on empty history: default
    # bandwidth and the gen-0 timings seed, hence deterministic) sits
    # between the compressed and lossless stream estimates: the lattice
    # promotes it to the overlap rung only because moments cross the wire
    # quantized. The gap is sized to dominate estimate drift (prepare-warm
    # flips, step jitter) between trace build time and decision time.
    T_PROMOTE = ParallelConfig(dp=4, tp=2)
    sizing = DeadlineEstimator(ctrl)
    prep_cold = sizing.prepare_estimate(warm=False)
    _, plan0 = plan_state_transfer(
        cfg, ParallelConfig(dp=2, tp=2), T_PROMOTE,
        source_policy=ctrl.source_policy,
    )
    logical0 = plan0.network_bytes
    wire0 = sum(wire_nbytes(ctrl.wire_policy, t) for t in plan0.tasks
                if getattr(t, "kind", "remote") == "remote")
    gap_s = max(8.0, 3.0 * (prep_cold - 1.0))  # lossless-vs-wire transfer gap
    WIRE_BW = max((logical0 - wire0) / gap_s, 1.0)
    # the small bandwidth drives the DECISION side only (the estimator's
    # default until history exists); transfers themselves run at host
    # speed so the replay fits CI — the physical wire emulation is
    # bench_dataplane's job
    estimator = DeadlineEstimator(ctrl, default_bw_bytes_s=WIRE_BW)
    est0 = estimator.estimate(T_PROMOTE)
    W_PROMOTE = SAFETY * (est0.stream_total_s + 0.5 * gap_s)
else:
    estimator = DeadlineEstimator(ctrl)
if SMOKE:
    # fixed trace covering the whole fallback lattice, deterministic
    # decisions (windows at the extremes, plus the one calibrated
    # promotion window), deterministic replay (sync_prepare):
    # compression-promoted stream commit, mid-prepare retarget, coalesce,
    # zero-window peer recovery (no checkpoint), unannounced fail-stop
    # recovered from surviving DP replicas, stream commit, and a final
    # tp-preserving shrink whose plan classifies fully resident (delta
    # IR: layer reuse, near-zero bytes moved)
    events = [
        # the calibrated window: wide enough for the wire-priced stream
        # estimate, too tight for its lossless counterfactual -> the
        # compressed wire promotes this event a rung (decision=stream,
        # decision_lossless below it). First in the trace so the deadline
        # estimate is decided on empty history — later events queue behind
        # live transfers, which would eat a finite window.
        ResizeEvent(time_s=0.3, target=T_PROMOTE, warning_s=W_PROMOTE),
        # the rest of the lattice trace starts after the promoted event
        # has room to commit (its prepare + stream run live); gaps between
        # these events mirror the original 7-event trace
        ResizeEvent(time_s=12.5, target=ParallelConfig(dp=2, tp=4), warning_s=BIG),
        ResizeEvent(time_s=12.6, target=ParallelConfig(dp=1, tp=4), warning_s=BIG),
        ResizeEvent(time_s=12.7, target=ParallelConfig(dp=1, tp=4), warning_s=BIG),
        # the window-0 events sit one transfer-compile time after the
        # preceding topology commit: the stream-ahead prewarm (§15) needs
        # that long to warm the (new world -> pooled world) executables on
        # host devices, and anything tighter measures XLA compile
        # contention instead of the recovery path. Real event streams are
        # minutes apart (ANALYTIC_SPACING) — this stays far conservative.
        ResizeEvent(time_s=26.0, target=ParallelConfig(dp=2, tp=2), warning_s=0.0),
        FailStopEvent(time_s=34.0, target=ParallelConfig(dp=1, tp=2)),
        ResizeEvent(time_s=40.0, target=ParallelConfig(dp=2, tp=2), warning_s=BIG),
        ResizeEvent(time_s=46.0, target=ParallelConfig(dp=1, tp=2), warning_s=BIG),
    ]
    time_scale, sync_prepare = 1.0, True
else:
    # seeded spot trace, live deadline decisions over measured estimates
    trace = spot_trace(40 * 60, 5 * 60, world_choices=(4, 8), seed=11,
                       warning_s=120.0, failstop_every=5)
    events = events_from_trace(trace, cfg, global_batch=8, seq_len=32,
                               compress=20.0, max_pp=1)
    time_scale, sync_prepare = 1.0, False
ANALYTIC_SPACING = 600.0 if SMOKE else 20.0  # undo replay compression

sched = ElasticScheduler(
    ctrl, time_scale=time_scale, sync_prepare=sync_prepare,
    estimator=estimator, max_steps=20_000,
    # max_pp matches the trace's own target bound (events_from_trace
    # max_pp=1 below) so prefetched pool keys can actually hit
    prefetch=PrefetchPolicy(ctrl, k=1, max_pp=1),
)
report = sched.run(events)

# analytic prediction for the same event sequence (LiveR row of Fig. 7),
# computed at production spacing: the live replay compresses inter-event
# gaps to fit CI, so the sim re-expands them (x ANALYTIC_SPACING) — its
# downtime constants are calibrated for real clusters, not a compressed
# clock
resizes = [
    (e.time_s * ANALYTIC_SPACING, e.target.world_size) for e in events
]
duration = max(report.duration_s, max(t for t, _ in resizes) + 600.0)
initial_world = 4  # dp2 x tp2 starting topology above
analytic = volatility_run(
    SystemKind.LIVER, PAPER_TESTBED, float(cfg.param_count()),
    resizes, duration, initial_world,
)

doc = report.to_dict()
# warm-vs-cold prepare breakdown: every record whose Prepare completed,
# keyed on whether the warm pool (or residual shadow work) served it.
# Speculative joins measure only the residual wait of an in-flight
# prefetch — neither warm nor cold — and are reported separately.
warm = [r.prepare_s for r in ctrl.records if r.warm_hit and r.prepare_s > 0]
cold = [r.prepare_s for r in ctrl.records
        if not r.warm_hit and r.prepare_source == "cold" and r.prepare_s > 0]
joins = [r.prepare_s for r in ctrl.records
         if r.prepare_source == "speculative_join" and r.prepare_s > 0]
doc["measured"] = {
    "goodput": report.goodput,
    "pause_seconds": report.pause_seconds,
    "train_gpu_seconds": ctrl.ledger.gpu_seconds("train"),
    # goodput denominator attribution: gpu-seconds per interval kind
    "ledger": {
        k: ctrl.ledger.gpu_seconds(k)
        for k in ("train", "pause", "reshard_overlap")
    },
    "steps": report.steps,
    "reconfig_records": [
        {"src": r.src, "dst": r.dst, "mode": r.mode, "outcome": r.outcome,
         "pause_s": r.total_pause_s, "reused_layers": r.reused_layers,
         "resident_layers": r.resident_layers,
         "skipped_bytes": r.skipped_bytes,
         "resident_cells": getattr(r, "resident_cells", 0),
         "wire_bytes": getattr(r, "wire_bytes", 0),
         "logical_bytes": getattr(r, "logical_bytes", 0),
         "operating_point": getattr(r, "operating_point", None),
         "moved_bytes": r.plan_network_bytes + r.plan_local_bytes,
         "donors": getattr(r, "donors", 0),
         "lost_devices": getattr(r, "lost_devices", 0),
         "parity_bytes": getattr(r, "parity_bytes", 0),
         "warm_hit": r.warm_hit, "prepare_s": r.prepare_s,
         "prepare_source": r.prepare_source}
        for r in ctrl.records
    ],
    "warm_cold": {
        "warm_hits": len(warm),
        "cold_prepares": len(cold),
        "speculative_joins": len(joins),
        "warm_prepare_s": statistics.median(warm) if warm else None,
        "cold_prepare_s": statistics.median(cold) if cold else None,
        "speedup": (statistics.median(cold) / statistics.median(warm))
        if warm and cold else None,
        "prefetch_started": sched.prefetch.started if sched.prefetch else 0,
    },
    "pool": ctrl.world_pool.stats.to_dict(),
    "wire": {
        "wire_bw_bytes_s": ctrl.wire_bw_bytes_s,
        "logical_bytes": sum(getattr(r, "logical_bytes", 0)
                             for r in ctrl.records),
        "wire_bytes": sum(getattr(r, "wire_bytes", 0) for r in ctrl.records),
    },
}
doc["analytic"] = {
    "system": "liver",
    "goodput": analytic.goodput,
    "reconfig_pause_s": analytic.reconfig_pause_s,
    "events": analytic.events,
}
print("JSON " + json.dumps(doc))
"""


def main(argv=()) -> None:
    smoke = "--smoke" in argv
    check = "--check" in argv
    code = _SNIPPET.replace("__SMOKE__", repr(smoke))
    out = run_with_devices(code, n_devices=8, timeout=1800)
    payload = None
    for line in out.splitlines():
        if line.startswith("JSON "):
            payload = json.loads(line[5:])
    assert payload is not None, f"no JSON payload in bench output:\n{out[-2000:]}"

    path = write_results("goodput", payload, mode="smoke" if smoke else "full")

    counts = payload["outcome_counts"]
    meas, ana = payload["measured"], payload["analytic"]
    emit(
        "goodput/events", 0.0,
        ";".join(f"{k}={v}" for k, v in counts.items())
        + f";total={len(payload['events'])}",
    )
    emit(
        "goodput/measured_vs_analytic", 0.0,
        f"measured={meas['goodput']*100:.1f}%;"
        f"analytic={ana['goodput']*100:.1f}% (paper fig7 liver: ~99%)",
    )
    emit(
        "goodput/pause", meas["pause_seconds"] * 1e6,
        f"measured_pause={meas['pause_seconds']:.2f}s over "
        f"{payload['steps']} steps",
    )
    wc = meas["warm_cold"]
    emit(
        "goodput/warm_cold_prepare",
        (wc["warm_prepare_s"] or 0.0) * 1e6,
        f"warm_hits={wc['warm_hits']};cold={wc['cold_prepares']};"
        f"warm_median_s={wc['warm_prepare_s']};"
        f"cold_median_s={wc['cold_prepare_s']};speedup={wc['speedup']}",
    )
    wire = meas["wire"]
    promoted = [
        e for e in payload["events"]
        if e["outcome"] == "committed" and e["decision"] == "stream"
        and e.get("decision_lossless") not in ("", "stream", None)
    ]
    emit(
        "goodput/wire", 0.0,
        f"logical={wire['logical_bytes']};wire={wire['wire_bytes']};"
        f"rung_promoted={len(promoted)}",
    )
    emit("goodput/json", 0.0, path)

    if check:
        n_events = len(payload["events"])
        if n_events < 5:
            raise SystemExit(f"trace too short: {n_events} events < 5")
        if counts["aborted"] != 0:
            raise SystemExit(f"{counts['aborted']} aborted events")
        # peer-recovery gate (DESIGN.md §15): the checkpoint rung is
        # last-resort only — nothing in the smoke trace may land on it
        if counts.get("fell_back", 0) != 0:
            raise SystemExit(
                f"{counts['fell_back']} events fell back to the checkpoint "
                "rung: peer recovery should have covered them"
            )
        if counts["committed"] < 1:
            raise SystemExit("no event committed through the live path")
        # fail-stop pause gate: recovering from peers must cost the same
        # order as a streamed resize commit, not a disk restore
        failstops = [e for e in payload["events"] if e["kind"] == "fail_stop"]
        streamed = [
            e["pause_s"] for e in payload["events"]
            if e["outcome"] == "committed" and e["decision"] == "stream"
        ]
        if failstops and streamed:
            worst_stream = max(streamed)
            for e in failstops:
                if e["pause_s"] > 5.0 * worst_stream:
                    raise SystemExit(
                        f"fail-stop pause {e['pause_s']:.3f}s exceeds 5x the "
                        f"worst streamed commit pause {worst_stream:.3f}s"
                    )
        if not (0.0 < meas["goodput"] <= 1.0):
            raise SystemExit(f"implausible measured goodput {meas['goodput']}")
        # warm pool gate: at least one resize must be served warm, and a
        # warm Prepare (no lower+compile) must beat a cold one by >= 5x.
        # No cold samples at all (every event warm/joined) is a PASS on the
        # speedup clause — the pool performing perfectly must not fail CI.
        if wc["warm_hits"] < 1:
            raise SystemExit("no warm-hit resize: the world pool never served")
        if wc["speedup"] is not None and wc["speedup"] < 5.0:
            raise SystemExit(
                f"warm prepare not >=5x faster than cold: {wc}"
            )
        # delta plan IR gate: the tp-preserving shrink in the trace must
        # classify its layers resident and skip them
        recs = meas["reconfig_records"]
        if not any(r["reused_layers"] > 0 for r in recs):
            raise SystemExit(
                "no record reused layers: delta classification never fired"
            )
        # reuse-accounting identity (cell-level) on every emitted record:
        # skipped bytes iff resident cells — the regression that once put
        # skipped_bytes=12800 next to resident_layers=0 in this very file
        from repro.core.records import reuse_identity_ok

        bad = [r for r in recs if not reuse_identity_ok(r)]
        if bad:
            raise SystemExit(f"reuse identity violated on records: {bad}")
        # compressed-wire rung gate: at least one committed stream event
        # whose lossless counterfactual sits on a lower rung — the
        # calibrated 0.6 window only fits because moments cross quantized
        if not promoted:
            raise SystemExit(
                "no rung-promoted event: compressed wire never changed a "
                "lattice decision"
            )


if __name__ == "__main__":
    main(sys.argv[1:])
