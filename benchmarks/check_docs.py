"""Docs CI job (ISSUE 4): the README's commands must parse and its artifact
references must resolve.

Checks, in order:
  1. ``compileall`` over examples/, benchmarks/ and src/ — every code block
     in the README points at one of these trees;
  2. ``--help`` smoke of the launchers the quickstart names (they must not
     crash at import/argparse time);
  3. every ``results/BENCH_*.json`` referenced anywhere in README.md either
     exists on disk or is covered by .gitignore (benchmark artifacts are
     regenerated per run, never committed — a reference that is neither
     present nor ignored is a stale doc).
"""

from __future__ import annotations

import compileall
import fnmatch
import os
import re
import subprocess
import sys

from benchmarks.common import REPO, SRC


def check_compile() -> None:
    for tree in ("examples", "benchmarks", "src"):
        path = os.path.join(REPO, tree)
        ok = compileall.compile_dir(path, quiet=1, force=False)
        if not ok:
            raise SystemExit(f"compileall failed under {tree}/")
    print("compileall OK: examples/ benchmarks/ src/")


def check_help_smoke() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--help"],
        capture_output=True, text=True, env=env, timeout=300,
    )
    if r.returncode != 0 or "--overlap" not in r.stdout:
        raise SystemExit(
            f"launch/train.py --help smoke failed (rc={r.returncode}):\n"
            f"{r.stderr[-2000:]}"
        )
    print("launch/train.py --help OK")


def _gitignore_patterns() -> list[str]:
    path = os.path.join(REPO, ".gitignore")
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line and not line.startswith("#"):
                out.append(line.rstrip("/"))
    return out


def _ignored(rel: str, patterns: list[str]) -> bool:
    parts = rel.split("/")
    for pat in patterns:
        if fnmatch.fnmatch(rel, pat) or any(
            fnmatch.fnmatch(p, pat) for p in parts
        ):
            return True
        # directory pattern: any prefix of the path
        for i in range(1, len(parts)):
            if fnmatch.fnmatch("/".join(parts[:i]), pat):
                return True
    return False


def check_artifact_references() -> None:
    readme = os.path.join(REPO, "README.md")
    if not os.path.exists(readme):
        raise SystemExit("README.md missing")
    with open(readme) as f:
        text = f.read()
    refs = sorted(set(re.findall(r"results/BENCH_\w+\.json", text)))
    if not refs:
        raise SystemExit("README.md references no BENCH artifacts")
    patterns = _gitignore_patterns()
    bad = [
        r
        for r in refs
        if not os.path.exists(os.path.join(REPO, r)) and not _ignored(r, patterns)
    ]
    if bad:
        raise SystemExit(f"README references unresolvable artifacts: {bad}")
    # and each referenced artifact must have a generating bench module
    missing = [
        r
        for r in refs
        if not os.path.exists(
            os.path.join(
                REPO, "benchmarks",
                "bench_" + r.split("BENCH_")[1].split(".")[0] + ".py",
            )
        )
    ]
    if missing:
        raise SystemExit(f"README artifacts with no generating bench: {missing}")
    print(f"artifact references OK: {refs}")


def main(argv=()) -> None:
    check_compile()
    check_help_smoke()
    check_artifact_references()
    print("DOCS_OK")


if __name__ == "__main__":
    main(sys.argv[1:])
