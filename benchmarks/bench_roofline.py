"""Roofline deliverable (g): the per-(arch x shape x mesh) table from the
dry-run artifacts in results/dryrun/ — three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, roofline fraction."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS, emit


def load_all(out_dir=None):
    out_dir = out_dir or os.path.join(RESULTS, "dryrun")
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def main() -> None:
    rows = load_all()
    if not rows:
        emit("roofline/missing", 0.0, "run: python -m repro.launch.dryrun --sweep")
        return
    ok = [r for r in rows if r.get("status") == "ok"]
    skipped = [r for r in rows if r.get("status") == "skipped"]
    errors = [r for r in rows if r.get("status") == "error"]
    emit(
        "roofline/cells", 0.0,
        f"ok={len(ok)};skipped={len(skipped)};errors={len(errors)}",
    )
    for r in sorted(ok, key=lambda r: (r["mesh"], r["arch"], r["shape"])):
        emit(
            f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}",
            r.get("compile_s", 0.0) * 1e6,
            f"compute={r['compute_s']:.3g}s;memory={r['memory_s']:.3g}s;"
            f"collective={r['collective_s']:.3g}s;bottleneck={r['bottleneck']};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f}",
        )
    for r in skipped:
        emit(
            f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}", 0.0,
            f"SKIPPED: {r['reason']}",
        )


if __name__ == "__main__":
    main()
