"""Serving goodput across live resizes (DESIGN.md §16).

Replays an elasticity trace with >= 2 resize events against the
continuous-batching serve loop and measures what the paper's story means
for inference: tokens/s served, dropped requests, p99 inter-token stall,
and per-resize pause/bytes — next to an uninterrupted same-seed oracle
run whose tokens the resized run must reproduce bit-for-bit.

The two runs share one ``WorldPool``: the oracle's retired serving world
is the warm start of the resized run (serving worlds are pool citizens),
and the tp-preserving first resize must adopt the live KV cache in place
(``cache_resident_layers > 0``, zero executed bytes).

Results land in ``results/BENCH_serve_goodput.json``; ``--check`` exits
nonzero when a request is dropped, token parity breaks, a resize fails to
commit, or the tp-preserving resize moved cache bytes.
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import emit, run_with_devices, write_results

_SNIPPET = """
import dataclasses, json
import numpy as np
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.events import ResizeEvent
from repro.core.world_pool import WorldPool
from repro.serve import LiveServeController, ServeSession

SMOKE = __SMOKE__
cfg = get_config("qwen3-1.7b").reduced()
pc = lambda dp, tp: ParallelConfig(dp=dp, pp=1, tp=tp, ep=1)
N_SLOTS, PLEN = 4, 16
GEN = 10 if SMOKE else 24
N_REQ = 6 if SMOKE else 12
MAX_SEQ = PLEN + GEN + 6
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, PLEN) for _ in range(N_REQ)]

# one pool for the whole benchmark: worlds retired by one session warm the
# next (the oracle's dp2tp2 world becomes the resized run's initial world)
pool = WorldPool(capacity=4)

def run(trace):
    ctrl = LiveServeController(cfg, pc(2, 2), N_SLOTS, PLEN, MAX_SEQ,
                               pool=pool, sync_prepare=True, seed=0)
    warm_init = bool(ctrl.active.timings.get("warm_hit", False))
    sess = ServeSession(ctrl, step_time_s=1.0)
    for p in prompts:
        sess.submit(p, GEN)
    results, m = sess.run(trace)
    recs = list(ctrl.records)
    ctrl.shutdown()
    return results, m, recs, warm_init

# oracle: no resizes, cold dp2tp2 build
res_a, m_a, _, warm_a = run([])
# resized: tp-preserving shrink mid-generation, then a tp-change
trace = [ResizeEvent(time_s=3.0, target=pc(1, 2)),
         ResizeEvent(time_s=6.0, target=pc(1, 1))]
res_b, m_b, recs, warm_b = run(trace)

parity = (set(res_a) == set(res_b)
          and all(res_a[r] == res_b[r] for r in res_a))

def mrow(m):
    return {"tokens": m.tokens_emitted, "wall_s": m.wall_s,
            "goodput_tok_s": m.goodput_tok_s, "p99_stall_s": m.p99_stall_s,
            "max_stall_s": m.max_stall_s, "dropped": m.dropped,
            "waves": m.waves, "commits": m.commits,
            "requests_served": m.requests_served}

doc = {
    "arch": "qwen3-1.7b", "n_requests": N_REQ, "n_slots": N_SLOTS,
    "prompt_len": PLEN, "gen": GEN,
    "trace": [[e.time_s, e.target.describe()] for e in trace],
    "oracle": mrow(m_a), "resized": mrow(m_b),
    "token_parity": parity,
    "oracle_init_warm": warm_a, "resized_init_warm": warm_b,
    "records": [dataclasses.asdict(r) for r in recs],
}
print("JSON " + json.dumps(doc))
"""


def main(argv=()) -> None:
    smoke = "--smoke" in argv
    check = "--check" in argv
    code = _SNIPPET.replace("__SMOKE__", repr(smoke))
    out = run_with_devices(code, n_devices=8, timeout=1800)
    payload = None
    for line in out.splitlines():
        if line.startswith("JSON "):
            payload = json.loads(line[5:])
    assert payload is not None, f"no JSON payload in bench output:\n{out[-2000:]}"

    path = write_results("serve_goodput", payload, mode="smoke" if smoke else "full")

    for tag in ("oracle", "resized"):
        m = payload[tag]
        emit(
            f"serve_goodput/{tag}", m["p99_stall_s"] * 1e6,
            f"goodput={m['goodput_tok_s']:.1f}tok/s;tokens={m['tokens']};"
            f"dropped={m['dropped']};commits={m['commits']};"
            f"max_stall_s={m['max_stall_s']:.3f}",
        )
    for r in payload["records"]:
        emit(
            f"serve_goodput/resize/{r['src']}->{r['dst']}", r["pause_s"] * 1e6,
            f"cut_step={r['cut_step']};executed={r['executed_bytes']};"
            f"net={r['plan_network_bytes']};"
            f"cache_resident_layers={r['cache_resident_layers']};"
            f"reused_layers={r['reused_layers']};warm={r['warm_hit']}",
        )
    emit(
        "serve_goodput/parity", 0.0,
        f"token_parity={payload['token_parity']};"
        f"resized_init_warm={payload['resized_init_warm']}",
    )
    emit("serve_goodput/json", 0.0, path)

    if check:
        recs = payload["records"]
        if len(recs) < 2:
            raise SystemExit(f"expected >=2 committed resizes, got {len(recs)}")
        if any(r["outcome"] != "committed" for r in recs):
            raise SystemExit(f"uncommitted resize: {recs}")
        if payload["resized"]["dropped"] != 0:
            raise SystemExit(f"dropped requests: {payload['resized']['dropped']}")
        if not payload["token_parity"]:
            raise SystemExit("post-resize tokens diverged from the oracle run")
        r1 = recs[0]  # dp2tp2 -> dp1tp2: tp-preserving
        if r1["cache_resident_layers"] <= 0 or r1["reused_layers"] <= 0:
            raise SystemExit(f"tp-preserving resize reused nothing: {r1}")
        if r1["executed_bytes"] != 0 or r1["plan_network_bytes"] != 0:
            raise SystemExit(f"tp-preserving resize moved cache bytes: {r1}")
        if not any(r["executed_bytes"] > 0 for r in recs):
            raise SystemExit("no resize exercised the reshard engine")
        if not payload["resized_init_warm"]:
            raise SystemExit("resized run did not warm-start from the pool")
        if payload["resized"]["goodput_tok_s"] <= 0:
            raise SystemExit("no goodput measured")


if __name__ == "__main__":
    main(sys.argv[1:])
