"""Fleet arbitration benchmark (DESIGN.md §18, ROADMAP item 4): N jobs
over one volatile device pool, three allocation policies on the SAME
seeded capacity trace.

Phase A (all-sim) runs the :class:`FleetArbiter` against closed-form
:class:`SimEndpoint` jobs on the shared DES clock — every job speaks the
wire protocol through ``WireEndpoint`` (serialized both legs), so the
phase also measures control-plane traffic at fleet scale. The metric is
cluster-wide goodput: total samples over what a zero-reconfig-cost
marginal allocation of the same capacity profile would have produced.
Static strands every capacity grow, fair-share adapts but ignores the
scaling curves, marginal water-fills on them.

Phase B (smoke only, mixed live+sim) plans the same arbitration over a
small fleet containing one REAL ``LiveRController`` job on 8 host
devices: ``FleetArbiter.plan_assignments`` turns policy decisions into
per-job event lists, the live job replays its list through the unmodified
``ElasticScheduler`` over the wire codec, the sim jobs replay theirs on
virtual clocks. Per-job goodput is reported for both.

``--smoke``: 6 sim jobs + the mixed leg; ``--check`` exits nonzero
unless phase A arbitrated >= 3 jobs with >= 10 per-job decisions and the
marginal policy's cluster goodput strictly beats BOTH baselines on the
same trace, and phase B committed >= 1 live resize with zero aborts.
Full mode scales phase A to 24 all-sim jobs (the 100-job regime is the
same code path; 24 keeps CI latency sane). Results land in
``results/BENCH_fleet.json``.
"""

from __future__ import annotations

import json
import sys

from benchmarks.common import Timed, emit, run_with_devices, write_results

# params mix spanning ~50x so the marginal curves genuinely differ
_PARAMS_MIX = (0.4e9, 0.8e9, 1.4e9, 2.8e9, 7e9, 14e9)
_FEASIBLE = (1, 2, 3, 4, 6, 8, 12, 16, 24)
_POLICIES = ("static", "fair_share", "marginal")


def _phase_a(n_jobs: int):
    """All-sim fleet: one seeded capacity trace, three policies."""
    from repro.configs.base import ParallelConfig
    from repro.elastic.endpoint import SimEndpoint, WireEndpoint
    from repro.fleet import FleetArbiter, FleetJob, make_policy
    from repro.sim.des import Simulator
    from repro.sim.volatility import spot_trace

    duration_s = 4 * 3600.0
    # admission at the pool's low point: most trace levels are GROWTH,
    # which static strands by construction, fair-share claims blindly and
    # marginal water-fills; shrinks + unannounced failures still occur
    # (failstop_every) to force recovery arbitration
    initial = 2 * n_jobs
    choices = tuple(sorted({initial, 3 * n_jobs, 4 * n_jobs,
                            5 * n_jobs, 7 * n_jobs}))
    trace = spot_trace(duration_s, 20 * 60, world_choices=choices, seed=17,
                       warning_s=120.0, failstop_every=4)

    def build(policy):
        sim = Simulator()
        jobs = []
        for i in range(n_jobs):
            params = _PARAMS_MIX[i % len(_PARAMS_MIX)]
            ep = WireEndpoint(SimEndpoint(
                f"job{i:02d}", params=params, global_batch=256,
                parallel=ParallelConfig(dp=4), sim=sim,
            ))
            jobs.append(FleetJob(
                name=f"job{i:02d}", endpoint=ep, params=params,
                global_batch=256, feasible_worlds=_FEASIBLE,
            ))
        return FleetArbiter(jobs, make_policy(policy), sim=sim)

    out = {"n_jobs": n_jobs, "trace_rows": len(trace),
           "duration_s": duration_s, "initial_capacity": initial,
           "policies": {}}
    for policy in _POLICIES:
        arb = build(policy)
        with Timed() as t:
            rep = arb.run(trace, duration_s=duration_s,
                          initial_capacity=initial)
        doc = rep.to_dict()
        doc["events"] = doc["events"][:200]  # cap artifact size
        doc["wire"] = {
            "commands": sum(j.endpoint.commands for j in arb.jobs),
            "bytes_tx": sum(j.endpoint.bytes_tx for j in arb.jobs),
            "bytes_rx": sum(j.endpoint.bytes_rx for j in arb.jobs),
        }
        doc["wall_us"] = t.us
        out["policies"][policy] = doc
    return out


_MIXED_SNIPPET = """
import json, tempfile
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.controller import LiveRController
from repro.core.topology_search import best_target
from repro.elastic import (
    ControllerEndpoint, DeadlineEstimator, ElasticScheduler, SimEndpoint,
    WireEndpoint,
)
from repro.elastic import protocol as P
from repro.fleet import FleetArbiter, FleetJob, make_policy
from repro.optim import AdamWConfig

cfg = get_config("qwen3-1.7b").reduced()
ctrl = LiveRController(
    cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(learning_rate=1e-3),
    seq_len=32, global_batch=8, overlap="stop_copy", sync_compile=True,
)
ctrl.train_steps(4)  # seed iteration timings for the estimator

live_ep = WireEndpoint(ControllerEndpoint(ctrl))
TARGETS = {w: best_target(cfg, w, 8, 32, max_pp=1) for w in (2, 4, 8)}
sim_eps = {}
jobs = [
    FleetJob(name="live", endpoint=live_ep, params=float(cfg.param_count()),
             global_batch=8, feasible_worlds=(2, 4, 8),
             target_fn=lambda w: TARGETS[w]),
]
for i, params in enumerate((0.8e9, 7e9)):
    ep = WireEndpoint(SimEndpoint(f"sim{i}", params=params, global_batch=256,
                                  parallel=ParallelConfig(dp=4)))
    sim_eps[f"sim{i}"] = ep
    jobs.append(FleetJob(name=f"sim{i}", endpoint=ep, params=params,
                         global_batch=256, feasible_worlds=(1, 2, 4, 8)))

# one shared capacity trace for the 3-job fleet (12 devices initially);
# times are wall seconds for the live replay, so they stay small
TRACE = [(8.0, 16, "resize", 1e9), (16.0, 8, "resize", 1e9),
         (24.0, 12, "resize", 1e9)]
arb = FleetArbiter(jobs, make_policy("marginal"), calibrate=False)
plans = arb.plan_assignments(TRACE, initial_capacity=12,
                             default_warning_s=1e9)

# live job: replay its assignment through the unmodified single-job
# scheduler, over the wire codec, on the wall clock
live_events = plans["live"]
rep = ElasticScheduler(
    live_ep, estimator=DeadlineEstimator(ctrl), sync_prepare=True,
    tail_steps=2, max_steps=20_000,
).run(live_events)

doc = {
    "live": {
        "events": [o.to_dict() for o in rep.outcomes],
        "committed": sum(1 for o in rep.outcomes if o.outcome == "committed"),
        "aborted": rep.aborted,
        "goodput": rep.goodput,
        "world": ctrl.world.parallel.world_size,
        "wire_commands": live_ep.commands,
    },
    "sim": {},
}
# sim jobs: replay theirs on their own virtual clocks
for name, events in plans.items():
    if name == "live":
        continue
    ep = sim_eps[name]
    srep = ElasticScheduler(ep, tail_steps=2).run(events)
    ledger = ep.handle(P.QueryLedger())
    doc["sim"][name] = {
        "committed": sum(1 for o in srep.outcomes
                         if o.outcome == "committed"),
        "aborted": srep.aborted,
        "goodput": ledger.goodput,
        "samples": ledger.samples,
    }
print("JSON " + json.dumps(doc))
"""


def main(argv=()) -> None:
    smoke = "--smoke" in argv
    check = "--check" in argv

    n_jobs = 6 if smoke else 24
    phase_a = _phase_a(n_jobs)
    payload = {"phase_a": phase_a}

    if smoke:
        out = run_with_devices(_MIXED_SNIPPET, n_devices=8, timeout=1800)
        mixed = None
        for line in out.splitlines():
            if line.startswith("JSON "):
                mixed = json.loads(line[5:])
        assert mixed is not None, f"no JSON in mixed leg:\n{out[-2000:]}"
        payload["mixed"] = mixed

    path = write_results("fleet", payload, mode="smoke" if smoke else "full")

    pols = phase_a["policies"]
    for policy in _POLICIES:
        doc = pols[policy]
        emit(
            f"fleet/{policy}", doc["wall_us"],
            f"goodput={doc['cluster_goodput']*100:.1f}%;"
            f"events={doc['arbitrated_events']};"
            f"samples={doc['total_samples']:.0f};"
            f"wire_cmds={doc['wire']['commands']}",
        )
    emit(
        "fleet/gain_vs_static", 0.0,
        f"{(pols['marginal']['cluster_goodput'] - pols['static']['cluster_goodput'])*100:+.1f}pp"
        f" over {n_jobs} jobs, {phase_a['trace_rows']} trace rows",
    )
    if smoke:
        live = payload["mixed"]["live"]
        emit(
            "fleet/mixed_live", 0.0,
            f"committed={live['committed']};aborted={live['aborted']};"
            f"goodput={live['goodput']};world={live['world']}",
        )
    emit("fleet/json", 0.0, path)

    if check:
        if phase_a["n_jobs"] < 3:
            raise SystemExit(f"CHECK FAIL: only {phase_a['n_jobs']} jobs")
        # the gate counts the curve-aware policy's decisions: the static
        # baseline ignores growth by construction, so it legitimately
        # arbitrates almost nothing
        if pols["marginal"]["arbitrated_events"] < 10:
            raise SystemExit(
                "CHECK FAIL: marginal arbitrated only "
                f"{pols['marginal']['arbitrated_events']} events (< 10)"
            )
        marg = pols["marginal"]["cluster_goodput"]
        for baseline in ("static", "fair_share"):
            base = pols[baseline]["cluster_goodput"]
            if not marg > base:
                raise SystemExit(
                    f"CHECK FAIL: marginal ({marg:.4f}) must strictly beat "
                    f"{baseline} ({base:.4f}) on the same trace"
                )
        if smoke:
            live = payload["mixed"]["live"]
            if live["committed"] < 1 or live["aborted"] != 0:
                raise SystemExit(
                    f"CHECK FAIL: mixed live leg committed="
                    f"{live['committed']} aborted={live['aborted']}"
                )
            for name, job in payload["mixed"]["sim"].items():
                if job["aborted"] != 0:
                    raise SystemExit(f"CHECK FAIL: sim job {name} aborted")
        print("CHECK OK", file=sys.stderr)


if __name__ == "__main__":
    main(sys.argv[1:])
