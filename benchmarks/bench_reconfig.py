"""Paper Fig. 6a: end-to-end reconfiguration downtime across model sizes —
LiveR vs Megatron-LM Checkpoint vs UCP (14x-23x speedup band)."""

from __future__ import annotations

from benchmarks.common import Timed, emit
from repro.sim.cluster import PAPER_TESTBED
from repro.sim.liver_sim import SystemKind, reconfig_downtime

SIZES = [("gpt-1.7b", 1.7e9), ("gpt-7b", 7e9), ("gpt-14b", 14e9),
         ("gpt-20b", 20e9), ("gpt-30b", 30e9)]


def main() -> None:
    for name, params in SIZES:
        with Timed() as t:
            mk = reconfig_downtime(SystemKind.MEGATRON_CKPT, PAPER_TESTBED, params, 32, 32)
            ucp = reconfig_downtime(SystemKind.UCP, PAPER_TESTBED, params, 32, 32)
            lv = reconfig_downtime(SystemKind.LIVER, PAPER_TESTBED, params, 32, 32)
        emit(
            f"fig6a/{name}", t.us,
            f"megatron={mk.total:.1f}s;ucp={ucp.total:.1f}s;liver={lv.total:.2f}s;"
            f"speedup={mk.total/lv.total:.1f}x (paper band 14-23x; liver 2-6s)",
        )


if __name__ == "__main__":
    main()
