"""Planner quality: fraction of state bytes that actually crosses the
network under the intersection plan, per transition class — the
``move_fraction`` input to the simulator's LiveR model and the quantity
behind the paper's 'minimal peer-to-peer transfer plan' claim.

Compares source-selection policies: "first" (paper-faithful arbitrary
replica) vs "nearest" (beyond-paper zero-copy-aware)."""

from __future__ import annotations

from benchmarks.common import Timed, emit
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer
from repro.core.resource_view import build_tensor_specs, total_state_bytes

TRANSITIONS = [
    ("tp_grow", ParallelConfig(dp=2, tp=4), ParallelConfig(dp=2, tp=8)),
    ("dp_grow", ParallelConfig(dp=2, tp=4), ParallelConfig(dp=4, tp=4)),
    ("dp_shrink", ParallelConfig(dp=4, tp=4), ParallelConfig(dp=2, tp=4)),
    ("pp_to_tp", ParallelConfig(dp=2, pp=2, tp=2), ParallelConfig(dp=2, pp=1, tp=4)),
    ("mixed_3d", ParallelConfig(dp=2, pp=2, tp=2), ParallelConfig(dp=1, pp=4, tp=2)),
]


def main() -> None:
    cfg = get_config("qwen3-1.7b")  # full 2B-param logical structure
    specs = build_tensor_specs(cfg, include_optimizer=True)
    total = total_state_bytes(specs)
    for name, ca, cb in TRANSITIONS:
        with Timed() as t:
            near = plan_transfer(specs, ca, cb, source_policy="nearest",
                                 layer_granular=False)
            first = plan_transfer(specs, ca, cb, source_policy="first",
                                  layer_granular=False)
        frac_near = near.network_bytes / total
        frac_first = first.network_bytes / total
        tx_first, _ = first.per_rank_bytes()
        tx_near, _ = near.per_rank_bytes()
        fan_first = max(tx_first.values()) if tx_first else 0
        fan_near = max(tx_near.values()) if tx_near else 0
        emit(
            f"movefrac/{name}", t.us,
            f"nearest={frac_near:.3f};paper_first={frac_first:.3f};"
            f"max_src_fanout_bytes nearest={fan_near/1e9:.2f}GB "
            f"first={fan_first/1e9:.2f}GB",
        )


if __name__ == "__main__":
    main()
