"""Planner quality: fraction of state bytes that actually crosses the
network under the intersection plan, per transition class — the
``move_fraction`` input to the simulator's LiveR model and the quantity
behind the paper's 'minimal peer-to-peer transfer plan' claim.

Compares source-selection policies: "first" (paper-faithful arbitrary
replica) vs "nearest" (beyond-paper zero-copy-aware), and cross-checks
the plan's byte accounting against an actual engine execution (the same
ReshardEngine the live path runs) on the reduced config."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Timed, emit
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer
from repro.core.resource_view import build_tensor_specs, total_state_bytes
from repro.core.streaming import (
    allocate_destination,
    execute_plan,
    materialize_rank,
)

TRANSITIONS = [
    ("tp_grow", ParallelConfig(dp=2, tp=4), ParallelConfig(dp=2, tp=8)),
    ("dp_grow", ParallelConfig(dp=2, tp=4), ParallelConfig(dp=4, tp=4)),
    ("dp_shrink", ParallelConfig(dp=4, tp=4), ParallelConfig(dp=2, tp=4)),
    ("pp_to_tp", ParallelConfig(dp=2, pp=2, tp=2), ParallelConfig(dp=2, pp=1, tp=4)),
    ("mixed_3d", ParallelConfig(dp=2, pp=2, tp=2), ParallelConfig(dp=1, pp=4, tp=2)),
]


def main() -> None:
    cfg = get_config("qwen3-1.7b")  # full 2B-param logical structure
    specs = build_tensor_specs(cfg, include_optimizer=True)
    total = total_state_bytes(specs)
    for name, ca, cb in TRANSITIONS:
        with Timed() as t:
            near = plan_transfer(specs, ca, cb, source_policy="nearest",
                                 layer_granular=False)
            first = plan_transfer(specs, ca, cb, source_policy="first",
                                  layer_granular=False)
        frac_near = near.network_bytes / total
        frac_first = first.network_bytes / total
        tx_first, _ = first.per_rank_bytes()
        tx_near, _ = near.per_rank_bytes()
        fan_first = max(tx_first.values()) if tx_first else 0
        fan_near = max(tx_near.values()) if tx_near else 0
        emit(
            f"movefrac/{name}", t.us,
            f"nearest={frac_near:.3f};paper_first={frac_first:.3f};"
            f"max_src_fanout_bytes nearest={fan_near/1e9:.2f}GB "
            f"first={fan_first/1e9:.2f}GB",
        )

    # plan-vs-executed agreement per policy: run the shared engine on the
    # reduced config (tractable shard sizes) and compare streamed bytes to
    # the planner's accounting — they must match exactly, by construction
    rcfg = get_config("qwen3-1.7b").reduced()
    rspecs = build_tensor_specs(rcfg, include_optimizer=True)
    rng = np.random.default_rng(0)
    g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in rspecs}
    for name, ca, cb in TRANSITIONS:
        for policy in ("first", "balanced", "nearest"):
            plan = plan_transfer(rspecs, ca, cb, source_policy=policy)
            src = {r: materialize_rank(rspecs, ca, r, g) for r in range(ca.world_size)}
            dst = {r: allocate_destination(rspecs, cb, r) for r in range(cb.world_size)}
            with Timed() as t:
                stats = execute_plan(plan, src, dst, staging_bytes=1 << 20)
            agree = (
                stats.network_bytes == plan.network_bytes
                and stats.local_bytes == plan.local_bytes
            )
            emit(
                f"movefrac_exec/{name}/{policy}", t.us,
                f"net={stats.network_bytes};local={stats.local_bytes};"
                f"layers={stats.layers_streamed};peak_staging={stats.peak_staging_bytes};"
                f"plan_agreement={agree}",
            )


if __name__ == "__main__":
    main()
