"""Paper Table 1: restart latency breakdown (GPT-20B, 32 GPUs).

Simulated on the paper-calibrated cluster model + the same breakdown
measured live on this host (reduced model, real teardown/compile/load).
"""

from __future__ import annotations

from benchmarks.common import Timed, emit, run_with_devices
from repro.sim.cluster import PAPER_TESTBED
from repro.sim.liver_sim import SystemKind, reconfig_downtime


def main() -> None:
    with Timed() as t:
        d = reconfig_downtime(SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 20e9, 32, 32)
    paper = {"ckpt_load": 54.6, "init": 70.1, "misc": 2.4, "total": 127.1}
    init = d.phases["proc_spawn"] + d.phases["cuda_init"] + d.phases["dist_init"]
    emit("table1/sim_ckpt_load_s", t.us, f"{d.phases['ckpt_load']:.1f} (paper {paper['ckpt_load']})")
    emit("table1/sim_init_s", t.us, f"{init:.1f} (paper {paper['init']})")
    emit("table1/sim_total_s", t.us, f"{d.total:.1f} (paper {paper['total']})")

    # measured on host: restart = save + teardown + rebuild world + load
    out = run_with_devices(
        """
        import tempfile, time, jax
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.shadow import build_train_world
        from repro.checkpoint import save_checkpoint, load_checkpoint
        from repro.distribution.step import init_train_state
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        par = ParallelConfig(dp=2, tp=2)
        w = build_train_world(cfg, par, AdamWConfig(), 8, 32)
        params, opt = init_train_state(cfg, w.mesh)
        ckpt = tempfile.mkdtemp()
        t0 = time.perf_counter(); save_checkpoint(ckpt, 1, {"p": params, "o": opt})
        save_s = time.perf_counter() - t0
        # "restart": rebuild world (mesh+compile) + reload
        t0 = time.perf_counter()
        w2 = build_train_world(cfg, ParallelConfig(dp=1, tp=4), AdamWConfig(), 8, 32)
        init_s = time.perf_counter() - t0
        ps, os_, _ = w2.shardings
        t0 = time.perf_counter()
        state, step, load_s = load_checkpoint(ckpt, {"p": params, "o": opt},
                                              {"p": ps, "o": os_})
        print(f"MEASURED save={save_s:.2f} init={init_s:.2f} load={load_s:.2f} "
              f"lower={w2.timings['lower_s']:.2f} compile={w2.timings['compile_s']:.2f}")
        """,
    )
    line = [l for l in out.splitlines() if l.startswith("MEASURED")][0]
    emit("table1/host_measured", 0.0, line.replace("MEASURED ", "").replace(" ", ";"))
    parts = dict(kv.split("=") for kv in line.split()[1:])
    init_frac = float(parts["init"]) / (float(parts["init"]) + float(parts["load"]))
    emit(
        "table1/host_init_fraction", 0.0,
        f"{init_frac*100:.0f}% of restart critical path is (re)initialization "
        "(paper: 55.1%)",
    )


if __name__ == "__main__":
    main()
