"""System-level behaviour: the paper's three required properties hold
end-to-end on the resource-view + planner + executor stack for a REAL model
(reduced config), not toy tensors — reshaping (any TP/PP/DP), storage-free,
bounded memory."""

from __future__ import annotations

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer, verify_completeness
from repro.core.resource_view import build_tensor_specs, view_of
from repro.core.streaming import (
    allocate_destination,
    execute_plan,
    materialize_rank,
)
from repro.models.transformer import block_program


@pytest.mark.parametrize(
    "arch,ca,cb",
    [
        ("qwen3-1.7b", ParallelConfig(dp=2, pp=2, tp=2), ParallelConfig(dp=1, pp=4, tp=2)),
        ("mixtral-8x7b", ParallelConfig(dp=2, tp=2, ep=2), ParallelConfig(dp=1, tp=2, ep=4)),
        ("mamba2-2.7b", ParallelConfig(dp=1, pp=2, tp=4), ParallelConfig(dp=4, pp=1, tp=2)),
        ("jamba-v0.1-52b", ParallelConfig(dp=2, pp=2, tp=2), ParallelConfig(dp=1, pp=1, tp=4)),
    ],
)
def test_model_state_reshape_any_topology(arch, ca, cb):
    """Real model state (params + AdamW moments) reshaped across arbitrary
    TP/PP/DP/EP — bit-exact, bounded, storage-free."""
    cfg = get_config(arch).reduced()
    specs = build_tensor_specs(cfg, include_optimizer=True)
    period = len(block_program(cfg))
    plan = plan_transfer(specs, ca, cb, num_positions=period)
    verify_completeness(specs, plan, cb)

    rng = np.random.default_rng(0)
    g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
    src = {r: materialize_rank(specs, ca, r, g) for r in range(ca.world_size)}
    dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}

    budget = 1 << 20
    stats = execute_plan(plan, src, dst, staging_bytes=budget)
    stats.assert_bounded(budget)

    for r in range(cb.world_size):
        ref = materialize_rank(specs, cb, r, g)
        for name, arr in ref.shards.items():
            np.testing.assert_array_equal(arr, dst[r].shards[name])

    # invariant I2: no rank ever held a full model replica
    total = sum(a.nbytes for a in g.values())
    for r, store in dst.items():
        assert store.bytes() < total, "a rank materialized the full state"


def test_plan_is_metadata_only_and_fast():
    """Planning touches only sharding metadata (paper: <1 s for 175B/1024
    ranks). Here: a full 52B-structure plan at 64->128 ranks, wall-bounded."""
    import time

    cfg = get_config("jamba-v0.1-52b")  # full config metadata, no arrays
    specs = build_tensor_specs(cfg, include_optimizer=True)
    ca = ParallelConfig(dp=4, pp=2, tp=8)
    cb = ParallelConfig(dp=4, pp=4, tp=8)
    t0 = time.perf_counter()
    plan = plan_transfer(specs, ca, cb, layer_granular=False)
    dt = time.perf_counter() - t0
    assert len(plan.tasks) > 0
    assert dt < 30, f"planning took {dt:.1f}s"


def test_optimizer_state_travels_with_params():
    cfg = get_config("qwen3-1.7b").reduced()
    specs = build_tensor_specs(cfg, include_optimizer=True)
    colls = {s.collection for s in specs}
    assert colls == {"params", "mu", "nu"}
    mu = [s for s in specs if s.collection == "mu"]
    assert any("dp" in s.roles for s in mu), "ZeRO sharding missing on moments"


def test_views_cover_tensors_exactly():
    cfg = get_config("mixtral-8x7b").reduced()
    specs = build_tensor_specs(cfg)
    c = ParallelConfig(dp=2, pp=2, tp=2, ep=2)
    for spec in specs:
        seen = np.zeros(spec.shape, np.int32)
        for r in range(c.world_size):
            v = view_of(spec, c, r)
            if v is None:
                continue
            sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
            seen[sl] += 1
        # every element owned by >= 1 rank; sharded dims exactly once per
        # replica group
        assert (seen > 0).all(), spec.name
