"""Distribution layer: sharding-rule validity for all archs × meshes,
pipeline-parallel parity, live resharder semantics, shadow/mock warmup,
gradient compression."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config


def test_sharding_rules_valid_on_production_meshes(subproc):
    """Every param of every arch gets a divisible PartitionSpec on both
    production meshes (this is what makes the dry-run lower)."""
    out = subproc(
        """
        import numpy as np
        from repro.configs import ASSIGNED
        from repro.launch.mesh import make_production_mesh
        from repro.distribution.sharding import param_shardings
        from repro.models.model import abstract_params
        from repro.utils.pytree import tree_paths
        import jax

        for multi in (False, True):
            mesh = make_production_mesh(multi_pod=multi)
            axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))
            for name, cfg in ASSIGNED.items():
                sh = tree_paths(param_shardings(cfg, mesh))
                pa = tree_paths(abstract_params(cfg))
                for path, s in sh.items():
                    shape = pa[path].shape
                    for d, ax in enumerate(s.spec):
                        if ax is None:
                            continue
                        axes = ax if isinstance(ax, tuple) else (ax,)
                        factor = int(np.prod([axis_size[a] for a in axes]))
                        assert shape[d] % factor == 0, (name, path, shape, s.spec)
        print("SHARDING_OK")
        """,
        n_devices=512,
        timeout=600,
    )
    assert "SHARDING_OK" in out


def test_pipeline_matches_dense(subproc):
    out = subproc(
        """
        import jax, jax.numpy as jnp, numpy as np
        import jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.distribution.sharding import make_elastic_mesh
        from repro.distribution.pipeline import jit_pipeline_train_step
        from repro.distribution.step import jit_train_step, init_train_state
        from repro.optim import AdamWConfig
        from repro.data import SyntheticLM

        cfg = get_config("qwen3-1.7b").reduced()
        opt_cfg = AdamWConfig(learning_rate=1e-3, warmup_steps=5)
        data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
        mesh1 = make_elastic_mesh(ParallelConfig(2, 1, 1, 2))
        p1, o1 = init_train_state(cfg, mesh1)
        s1, _ = jit_train_step(cfg, mesh1, opt_cfg, global_batch=8)
        par2 = ParallelConfig(dp=2, pp=2, tp=2)
        mesh2 = make_elastic_mesh(par2)
        p2, o2 = init_train_state(cfg, mesh2)
        s2, _ = jit_pipeline_train_step(cfg, mesh2, par2, opt_cfg,
                                        global_batch=8, microbatches=4)
        for i in range(2):
            batch = {"tokens": jnp.asarray(data.global_batch_at(i))}
            p1, o1, m1 = s1(p1, o1, batch)
            p2, o2, m2 = s2(p2, o2, batch)
            assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        n1 = jtu.tree_map(lambda a: np.asarray(jax.device_get(a), np.float32), p1)
        n2 = jtu.tree_map(lambda a: np.asarray(jax.device_get(a), np.float32), p2)
        md = max(jtu.tree_leaves(jtu.tree_map(
            lambda a, b: float(np.abs(a - b).max()), n1, n2)))
        assert md < 5e-4, md
        print("PIPELINE_OK", md)
        """,
        n_devices=8,
    )
    assert "PIPELINE_OK" in out


def test_live_reshard_chunked_bounded(subproc):
    out = subproc(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ParallelConfig
        from repro.distribution.sharding import make_elastic_mesh
        from repro.core.reshard import live_reshard

        mesh_a = make_elastic_mesh(ParallelConfig(tp=2))
        mesh_b = make_elastic_mesh(ParallelConfig(tp=4))
        x = jax.device_put(jnp.arange(64 * 128, dtype=jnp.float32).reshape(64, 128),
                           NamedSharding(mesh_a, P(None, "model")))
        state = {"w": x, "small": jax.device_put(jnp.ones(8), NamedSharding(mesh_a, P()))}
        targets = {"w": NamedSharding(mesh_b, P(None, "model")),
                   "small": NamedSharding(mesh_b, P())}
        # staging budget smaller than w (64*128*4 = 32KB) => chunked path
        new, rep = live_reshard(state, targets, staging_bytes=8 * 128 * 4)
        assert rep.chunked_leaves == 1, rep
        assert rep.max_inflight_bytes <= 8 * 128 * 4
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(new["w"])),
            np.arange(64 * 128, dtype=np.float32).reshape(64, 128))
        assert new["w"].sharding.mesh.shape == mesh_b.shape
        print("RESHARD_OK")
        """,
        n_devices=8,
    )
    assert "RESHARD_OK" in out


def test_mock_warmup_abstract_mesh(subproc):
    """Mock process groups: lower against an AbstractMesh touches no device."""
    out = subproc(
        """
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.mock_groups import mock_warmup
        from repro.distribution.sharding import make_elastic_mesh, param_shardings
        from repro.distribution.step import make_train_step
        from repro.models.model import abstract_params
        from repro.optim import AdamWConfig, adamw_init

        cfg = get_config("qwen3-1.7b").reduced()
        mesh = make_elastic_mesh(ParallelConfig(dp=2, tp=2))
        ps = param_shardings(cfg, mesh)
        step = make_train_step(cfg, AdamWConfig())
        aparams = abstract_params(cfg)
        aopt = jax.eval_shape(lambda: adamw_init(aparams))
        abatch = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
        res = mock_warmup(step, mesh, (ps, None, None),
                          (aparams, aopt, abatch))
        assert res.lower_seconds > 0
        assert res.hlo_bytes > 1000
        txt = res.lowered.as_text()
        assert "module" in txt
        print("MOCK_OK lower=%.2fs hlo=%dB" % (res.lower_seconds, res.hlo_bytes))
        """,
        n_devices=8,
    )
    assert "MOCK_OK" in out


def test_grad_compression_int8_ef():
    from repro.kernels.reshard_quant import (
        compress_decompress_with_ef,
        dequantize_int8,
        quantize_int8,
    )

    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    q, s = quantize_int8(g)
    assert q.dtype == jnp.int8
    err = float(jnp.abs(dequantize_int8(q, s) - g).max())
    assert err <= float(s) * 0.5 + 1e-7

    # error feedback: two identical steps — residual is reinjected
    grads = {"w": g}
    opt = {"ef": {"w": jnp.zeros_like(g)}}
    g1, opt = compress_decompress_with_ef(grads, opt)
    resid = opt["ef"]["w"]
    np.testing.assert_allclose(
        np.asarray(g1["w"] + resid), np.asarray(g), atol=1e-6
    )


def test_shadow_builder_thread():
    import time

    from repro.core.shadow import ShadowBuilder, WorldHandle

    def build():
        time.sleep(0.1)
        return WorldHandle(parallel=None, mesh=None, step_fn=None, shardings=None)

    b = ShadowBuilder(build, gen_id=3).start()
    assert not b.ready or True
    h = b.result(timeout=5)
    assert h.gen_id == 3
    assert h.timings["prepare_total_s"] >= 0.1

    def boom():
        raise RuntimeError("kaput")

    b2 = ShadowBuilder(boom, gen_id=4).start()
    with pytest.raises(RuntimeError):
        b2.result(timeout=5)
