"""Fleet arbitration (DESIGN.md §18): allocation policies on known
curves, the analytic roofline's concavity (the property the greedy
allocator's optimality rests on), SimEndpoint protocol conformance over
the wire codec, and the arbiter end-to-end gate the benchmark enforces —
marginal-throughput beats static and fair-share on the same trace.
"""

from __future__ import annotations

import math

import pytest

from repro.configs.base import ParallelConfig
from repro.elastic import ElasticScheduler, SimEndpoint, WireEndpoint
from repro.elastic import protocol as p
from repro.fleet import (
    FairSharePolicy,
    FleetArbiter,
    FleetJob,
    JobView,
    MarginalThroughputPolicy,
    StaticPolicy,
    make_policy,
)
from repro.sim.cluster import PAPER_TESTBED
from repro.sim.des import Simulator


# ---------------------------------------------------------------------------
# Analytic scaling curves
# ---------------------------------------------------------------------------


def test_analytic_throughput_monotone_and_concave():
    from repro.roofline.analysis import analytic_throughput

    for params in (0.4e9, 1.4e9, 7e9):
        t = [analytic_throughput(params, w, PAPER_TESTBED, 256)
             for w in range(1, 65)]
        gains = [b - a for a, b in zip(t, t[1:])]
        assert all(g > 0 for g in gains), "throughput must grow with devices"
        # concave: marginal gain shrinks — what makes greedy water-filling
        # the exact optimum (and prevents winner-take-all allocations)
        assert all(g2 < g1 + 1e-9 for g1, g2 in zip(gains, gains[1:]))


def test_analytic_curve_anchored_at_calibrated_ref_world():
    from repro.roofline.analysis import analytic_step_time

    got = analytic_step_time(1.4e9, 32, PAPER_TESTBED, ref_world=32)
    want = PAPER_TESTBED.step_time_s(1.4e9, 32, ref_world=32)
    assert got == pytest.approx(want, rel=1e-9)


# ---------------------------------------------------------------------------
# Policies on synthetic curves
# ---------------------------------------------------------------------------


def _view(name, current=2, feasible=(2, 4, 8, 16), weight=1.0, scale=1.0):
    return JobView(
        name=name, current=current, feasible=tuple(feasible), weight=weight,
        throughput=lambda w, s=scale: s * math.log1p(w),  # concave
    )


def test_marginal_policy_fills_highest_gain_first():
    # job "big" earns 10x per device: it should take all growth first
    views = [_view("big", scale=10.0), _view("small", scale=1.0)]
    alloc = MarginalThroughputPolicy().allocate(views, 20)
    assert alloc["big"] == 16
    assert alloc["small"] == 4  # the remainder
    # equal curves: deterministic name tie-break, both grow
    views = [_view("a"), _view("b")]
    alloc = MarginalThroughputPolicy().allocate(views, 12)
    assert alloc == {"a": 8, "b": 4}


def test_marginal_policy_respects_floors_and_capacity():
    views = [_view("a"), _view("b"), _view("c")]
    for cap in (6, 7, 12, 48, 100):
        alloc = MarginalThroughputPolicy().allocate(views, cap)
        assert sum(alloc.values()) <= cap
        assert all(alloc[v.name] >= v.floor for v in views)
        assert all(alloc[v.name] in v.feasible for v in views)


def test_policies_raise_below_fleet_floor():
    views = [_view("a"), _view("b")]
    for policy in (StaticPolicy(), FairSharePolicy(), MarginalThroughputPolicy()):
        with pytest.raises(ValueError):
            policy.allocate(views, 3)  # floors sum to 4


def test_static_policy_strands_growth_capacity():
    views = [_view("a"), _view("b")]
    pol = StaticPolicy()
    first = pol.allocate(views, 16)
    assert first == {"a": 8, "b": 8}
    # capacity doubles: static never claims it
    assert pol.allocate(views, 32) == first
    # forced shrink still fits
    shrunk = pol.allocate(views, 10)
    assert sum(shrunk.values()) <= 10


def test_fair_share_adapts_but_ignores_curves():
    views = [_view("big", scale=10.0), _view("small", scale=1.0)]
    pol = FairSharePolicy()
    assert pol.allocate(views, 16) == {"big": 8, "small": 8}
    assert pol.allocate(views, 32) == {"big": 16, "small": 16}


def test_make_policy_registry():
    assert make_policy("marginal").name == "marginal"
    assert make_policy("static").name == "static"
    assert make_policy("fair_share").name == "fair_share"
    with pytest.raises(ValueError):
        make_policy("nope")


# ---------------------------------------------------------------------------
# SimEndpoint: protocol conformance over the wire codec
# ---------------------------------------------------------------------------


def _sim_ep(**kw):
    kw.setdefault("params", 1.4e9)
    kw.setdefault("global_batch", 256)
    kw.setdefault("parallel", ParallelConfig(dp=4))
    return WireEndpoint(SimEndpoint("job", **kw))


def test_sim_endpoint_trains_on_virtual_clock():
    ep = _sim_ep()
    r = ep.handle(p.TrainSteps(n=10))
    assert isinstance(r, p.StepResult) and r.steps == 10
    assert r.clock_s > 0  # the endpoint owns a virtual clock
    status = ep.handle(p.QueryStatus())
    assert status.kind == "sim" and status.step == 10
    assert status.world_size == 4
    ledger = ep.handle(p.QueryLedger())
    assert ledger.steps == 10 and ledger.samples == 10 * 256
    assert ledger.goodput == pytest.approx(1.0)  # no pauses yet


def test_sim_endpoint_resize_commits_with_pause():
    ep = _sim_ep()
    ep.handle(p.TrainSteps(n=5))
    r = ep.handle(p.RequestResize(target=ParallelConfig(dp=8), overlap="stream"))
    assert isinstance(r, p.ResizeStarted) and r.gen_id == 1
    assert ep.handle(p.QueryStatus()).reconfig_pending
    # train far past prepare: the resize commits, a record appears
    ep.handle(p.TrainSteps(n=2000))
    status = ep.handle(p.QueryStatus())
    assert not status.reconfig_pending and status.world_size == 8
    recs = ep.handle(p.QueryRecords(since=0))
    assert recs.total == 1
    rec = recs.records[0]
    # record mode follows the controller's naming: the overlapped rung
    # commits as "live_overlap"
    assert rec.outcome == "committed" and rec.mode == "live_overlap"
    assert rec.total_pause_s > 0
    ledger = ep.handle(p.QueryLedger())
    assert 0 < ledger.goodput < 1  # the pause cost something


def test_sim_endpoint_failstop_and_estimates():
    ep = _sim_ep()
    ep.handle(p.TrainSteps(n=5))
    est = ep.handle(p.QueryEstimate(target=ParallelConfig(dp=2))).estimate
    assert est.step_s > 0 and est.stop_copy_pause_s > 0
    assert est.measured_bw > 0
    r = ep.handle(p.FailStopRecover(target=ParallelConfig(dp=2),
                                    devices_failed=True, lost_ranks=(2, 3)))
    assert isinstance(r, p.RecoverResult)
    assert r.record.mode == "peer_recover" and r.record.outcome == "committed"
    assert ep.handle(p.QueryStatus()).world_size == 2
    tgt = ep.handle(p.QuerySurvivorTarget(lost_ranks=(1,))).target
    assert tgt is not None and tgt.world_size == 1


def test_scheduler_drives_sim_endpoint_end_to_end():
    # the single-job scheduler runs unmodified against the sim model,
    # following the endpoint's virtual clock instead of wall time
    from repro.core.events import ResizeEvent

    ep = _sim_ep()
    events = [
        ResizeEvent(time_s=30.0, target=ParallelConfig(dp=8), warning_s=1e9),
        ResizeEvent(time_s=4000.0, target=ParallelConfig(dp=2), warning_s=1e9),
    ]
    rep = ElasticScheduler(ep, tail_steps=2).run(events)
    assert rep.aborted == 0
    assert [o.outcome for o in rep.outcomes] == ["committed", "committed"]
    assert ep.handle(p.QueryStatus()).world_size == 2
    assert rep.goodput is None or 0 < rep.goodput <= 1


# ---------------------------------------------------------------------------
# Arbiter end-to-end (the benchmark gate, in miniature)
# ---------------------------------------------------------------------------


def _fleet(policy_name):
    sim = Simulator()
    jobs = []
    for i, params in enumerate((0.4e9, 1.4e9, 7e9)):
        ep = WireEndpoint(SimEndpoint(
            f"job{i}", params=params, global_batch=256,
            parallel=ParallelConfig(dp=4), sim=sim,
        ))
        jobs.append(FleetJob(
            name=f"job{i}", endpoint=ep, params=params, global_batch=256,
            feasible_worlds=(1, 2, 3, 4, 6, 8, 12, 16, 24),
        ))
    return FleetArbiter(jobs, make_policy(policy_name), sim=sim)


TRACE = [
    (600.0, 24, "resize", 120.0),
    (1200.0, 40, "resize", 120.0),
    (1800.0, 16, "fail_stop", 0.0),
    (2400.0, 32, "resize", 120.0),
]


def test_arbiter_runs_fleet_and_marginal_wins():
    reports = {
        name: _fleet(name).run(TRACE, duration_s=3600.0, initial_capacity=32)
        for name in ("static", "fair_share", "marginal")
    }
    for rep in reports.values():
        assert rep.arbitrated_events >= 3
        assert rep.total_samples > 0
        assert 0 < rep.cluster_goodput <= 1.0
        assert rep.ideal_samples >= rep.total_samples
    # the gate: curve-aware arbitration strictly beats both baselines
    assert (reports["marginal"].cluster_goodput
            > reports["static"].cluster_goodput)
    assert (reports["marginal"].cluster_goodput
            > reports["fair_share"].cluster_goodput)


def test_arbiter_failstop_rows_force_recovery():
    arb = _fleet("marginal")
    rep = arb.run(TRACE, duration_s=3600.0, initial_capacity=32)
    forced = [e for e in rep.events if e.kind == "fail_stop"
              and e.world_after < e.world_before]
    assert forced, "capacity loss must shrink someone"
    assert all(e.decision == "peer_recover" for e in forced)


def test_plan_assignments_mirrors_policy_decisions():
    from repro.core.events import FailStopEvent, ResizeEvent

    arb = _fleet("marginal")
    plans = arb.plan_assignments(TRACE, initial_capacity=32)
    assert set(plans) == {"job0", "job1", "job2"}
    evs = [e for lst in plans.values() for e in lst]
    assert evs, "the trace must produce per-job events"
    for e in evs:
        assert isinstance(e, (ResizeEvent, FailStopEvent))
        assert e.target is not None
    # the fail_stop trace row surfaces as FailStopEvents for shrinkers
    assert any(isinstance(e, FailStopEvent) for e in evs)
