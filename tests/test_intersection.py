"""Property tests for the intersection-based transfer planner (App. A.2).

The fundamental correctness requirement (Eq. 1): the union of all shards in
the new configuration equals the union in the old one, and the planner's
tasks tile every destination view exactly once.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer, verify_completeness
from repro.core.resource_view import TensorSpec, split_bounds, view_of
from repro.core.streaming import (
    allocate_destination,
    execute_plan,
    materialize_rank,
)


def _mk_specs(layers, rows, cols):
    return [
        TensorSpec(
            "params/blocks/pos0/mlp/wi",
            (layers, rows, cols),
            "float32",
            ("pp", "none", "tp"),
            "stages",
            "params",
        ),
        TensorSpec(
            "params/embed/tok", (rows * 4, cols), "float32", ("tp", "none"),
            "first", "params",
        ),
        TensorSpec(
            "mu/blocks/pos0/mlp/wi",
            (layers, rows, cols),
            "float32",
            ("pp", "dp", "tp"),
            "stages",
            "mu",
        ),
        TensorSpec(
            "params/blocks/pos0/moe/wi",
            (8, rows, cols),
            "float32",
            ("ep", "none", "tp"),
            "stages",
            "params",
        ),
    ]


configs = st.builds(
    ParallelConfig,
    dp=st.sampled_from([1, 2, 3]),
    pp=st.sampled_from([1, 2, 4]),
    tp=st.sampled_from([1, 2, 4]),
    ep=st.sampled_from([1, 2]),
)


@settings(max_examples=25, deadline=None)
@given(
    ca=configs,
    cb=configs,
    policy=st.sampled_from(["first", "balanced", "nearest"]),
)
def test_plan_completeness_and_bit_exact(ca, cb, policy):
    specs = _mk_specs(layers=8, rows=12, cols=16)
    plan = plan_transfer(specs, ca, cb, source_policy=policy)
    verify_completeness(specs, plan, cb)

    rng = np.random.default_rng(0)
    gstate = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
    src = {r: materialize_rank(specs, ca, r, gstate) for r in range(ca.world_size)}
    dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
    stats = execute_plan(plan, src, dst, staging_bytes=512)
    stats.assert_bounded(512)
    for r in range(cb.world_size):
        ref = materialize_rank(specs, cb, r, gstate)
        for name, arr in ref.shards.items():
            np.testing.assert_array_equal(arr, dst[r].shards[name])


@settings(max_examples=40, deadline=None)
@given(
    size=st.integers(1, 200),
    parts=st.integers(1, 16),
)
def test_split_bounds_partition(size, parts):
    """Balanced splits tile [0, size) exactly."""
    prev = 0
    total = 0
    for i in range(parts):
        lo, hi = split_bounds(size, parts, i)
        assert lo == prev
        assert hi >= lo
        total += hi - lo
        prev = hi
    assert total == size


def test_identity_plan_is_all_resident():
    specs = _mk_specs(8, 12, 16)
    c = ParallelConfig(dp=2, pp=2, tp=2)
    plan = plan_transfer(specs, c, c, source_policy="nearest")
    assert plan.network_bytes == 0
    assert plan.local_bytes == 0
    assert plan.resident_bytes > 0
    assert all(t.kind == "resident" for t in plan.tasks)
    assert plan.resident_layers() == plan.layers()


def test_classification_tp_preserving_shrink_is_all_resident():
    """dp2tp2 -> dp1tp2: every surviving rank keeps an identical shard —
    the whole plan classifies resident and the delta executor moves zero
    bytes."""
    specs = [
        TensorSpec("params/w", (16, 16), "float32", ("tp", "none"), "stages", "params")
    ]
    plan = plan_transfer(
        specs, ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2),
        source_policy="nearest",
    )
    assert {t.kind for t in plan.tasks} == {"resident"}
    assert plan.network_bytes == 0
    assert plan.local_bytes == 0
    assert plan.resident_bytes == sum(t.nbytes for t in plan.tasks)
    assert plan.resident_layers() == plan.layers()


def test_classification_dp_grow_is_resident_plus_remote():
    """dp1tp2 -> dp2tp2: surviving ranks are resident; the new replica
    group receives remote broadcasts — no local relayout anywhere."""
    specs = [
        TensorSpec("params/w", (16, 16), "float32", ("tp", "none"), "stages", "params")
    ]
    plan = plan_transfer(
        specs, ParallelConfig(dp=1, tp=2), ParallelConfig(dp=2, tp=2),
        source_policy="nearest",
    )
    kinds = {t.dst_rank: t.kind for t in plan.tasks}
    by_kind = plan.kind_bytes()
    assert by_kind["local"] == 0
    assert by_kind["resident"] > 0
    assert by_kind["remote"] > 0
    # exactly the src-world ranks are resident; the grown ranks are remote
    resident_ranks = {r for r, k in kinds.items() if k == "resident"}
    remote_ranks = {r for r, k in kinds.items() if k == "remote"}
    assert resident_ranks | remote_ranks == set(range(4))
    assert len(resident_ranks) == 2
    assert len(remote_ranks) == 2


def test_classification_tp_change_is_local_plus_remote_no_resident():
    """dp2tp2 -> dp1tp4: tp width changes, so no shard survives verbatim —
    same-rank overlaps classify local (on-device relayout), the rest
    remote. Never resident."""
    specs = [
        TensorSpec("params/w", (16, 16), "float32", ("tp", "none"), "stages", "params")
    ]
    plan = plan_transfer(
        specs, ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=4),
        source_policy="nearest",
    )
    assert plan.resident_bytes == 0
    kinds = {t.kind for t in plan.tasks}
    assert kinds == {"local", "remote"}
    for t in plan.tasks:
        if t.kind == "local":
            assert t.src_rank == t.dst_rank
        else:
            assert t.src_rank != t.dst_rank
    assert plan.resident_layers() == []


def test_dp_increase_is_broadcast():
    """Paper A.2.3: growing replicas degenerates to a broadcast pattern."""
    specs = [
        TensorSpec("params/w", (16, 16), "float32", ("tp", "none"), "stages", "params")
    ]
    plan = plan_transfer(specs, ParallelConfig(dp=1, tp=2), ParallelConfig(dp=4, tp=2))
    dst_ranks = {t.dst_rank for t in plan.tasks}
    assert len(dst_ranks) == 8  # every new rank receives its replica
    # each destination holds the full tp-shard of its column group
    for t in plan.tasks:
        assert t.nbytes == 16 * 8 * 4


def test_pp_transition_moves_whole_layers():
    """Paper A.2.3: PP moves entire layers; intersections are full or empty."""
    specs = [
        TensorSpec(
            "params/blocks/pos0/w", (8, 4, 4), "float32", ("pp", "none", "none"),
            "stages", "params",
        )
    ]
    plan = plan_transfer(
        specs, ParallelConfig(pp=2), ParallelConfig(pp=4), layer_granular=True
    )
    for t in plan.tasks:
        # unit layer slices, full tensor cross-section
        assert t.shape() == (1, 4, 4)


def test_source_policy_balanced_spreads_load():
    specs = [
        TensorSpec("params/w", (64, 64), "float32", ("none", "none"), "stages", "params")
    ]
    ca, cb = ParallelConfig(dp=4), ParallelConfig(dp=4)
    # force network transfers by using "first" (all from rank 0)
    plan_first = plan_transfer(specs, ca, cb, source_policy="first")
    tx_first, _ = plan_first.per_rank_bytes()
    plan_near = plan_transfer(specs, ca, cb, source_policy="nearest")
    # nearest finds the same-rank replica => all-local
    assert plan_near.network_bytes == 0
    assert set(tx_first) <= {0}
