"""Elastic serving e2e: a generation that crosses a live resize must be
token-for-token identical to an uninterrupted same-seed run, with zero
dropped requests; plus in-process unit tests for the continuous-batching
bookkeeping (slot reuse/eviction, FIFO admission)."""

from __future__ import annotations

import numpy as np

from repro.serve.slots import plan_admission, RequestQueue, SlotAllocator


# ---------------------------------------------------------------------------
# Slot allocator: LIFO reuse, eviction accounting
# ---------------------------------------------------------------------------


def test_slot_allocator_first_fill_is_ordered():
    s = SlotAllocator(4)
    assert [s.alloc() for _ in range(4)] == [0, 1, 2, 3]
    assert s.alloc() is None  # exhausted, not an error
    assert s.free_count == 0 and len(s.in_use) == 4


def test_slot_allocator_reuses_most_recently_freed_first():
    s = SlotAllocator(4)
    for _ in range(3):
        s.alloc()  # 0, 1, 2 in use; 3 free
    s.free(1)
    s.free(0)
    # LIFO: last-freed slot comes back first (its cache row is warmest)
    assert s.alloc() == 0
    assert s.alloc() == 1
    assert s.alloc() == 3
    assert s.free_count == 0


def test_slot_allocator_counts_evictions_separately():
    s = SlotAllocator(2)
    a, b = s.alloc(), s.alloc()
    s.free(a)  # voluntary completion: not a drop
    assert s.evictions == 0
    s.evict(b)  # dropped in-flight request
    assert s.evictions == 1
    assert s.free_count == 2


# ---------------------------------------------------------------------------
# Admission: strict FIFO over requests, across waves
# ---------------------------------------------------------------------------


def test_admission_is_fifo_across_waves():
    q = RequestQueue()
    slots = SlotAllocator(2)
    reqs = [q.submit(np.zeros(4, np.int32), max_new_tokens=3) for _ in range(5)]

    wave1 = plan_admission(q, slots)
    assert [r.rid for r in wave1] == [reqs[0].rid, reqs[1].rid]
    assert [r.slot for r in wave1] == [0, 1]
    assert len(q) == 3

    # wave 1 finishes; freed slots admit the NEXT queued requests, oldest
    # first, onto LIFO-reused slots
    slots.free(wave1[1].slot)
    slots.free(wave1[0].slot)
    wave2 = plan_admission(q, slots)
    assert [r.rid for r in wave2] == [reqs[2].rid, reqs[3].rid]
    assert [r.slot for r in wave2] == [0, 1]  # last-freed first
    assert len(q) == 1

    # no free slots -> nothing admitted, queue untouched
    assert plan_admission(q, slots) == []
    assert len(q) == 1


def test_admission_partial_wave_when_queue_short():
    q = RequestQueue()
    slots = SlotAllocator(4)
    q.submit(np.zeros(4, np.int32), max_new_tokens=1)
    wave = plan_admission(q, slots)
    assert len(wave) == 1 and wave[0].slot == 0
    assert slots.free_count == 3


# ---------------------------------------------------------------------------
# Subprocess e2e: resize mid-generation, token parity + zero drops
# ---------------------------------------------------------------------------

_E2E_SNIPPET = """
import numpy as np
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.events import ResizeEvent
from repro.serve import LiveServeController, ServeSession

cfg = get_config("qwen3-1.7b").reduced()
pc = lambda dp, tp: ParallelConfig(dp=dp, pp=1, tp=tp, ep=1)
N_SLOTS, PLEN, GEN, MAX_SEQ = 4, 16, 10, 32
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size, PLEN) for _ in range(6)]

def run(trace):
    ctrl = LiveServeController(cfg, pc(2, 2), N_SLOTS, PLEN, MAX_SEQ,
                               sync_prepare=True, seed=0)
    sess = ServeSession(ctrl, step_time_s=1.0)  # deterministic cut steps
    for p in prompts:
        sess.submit(p, GEN)
    results, metrics = sess.run(trace)
    recs = list(ctrl.records)
    pool = ctrl.world_pool
    ctrl.shutdown()
    return results, metrics, recs, pool

# oracle: uninterrupted same-seed run
res_a, m_a, _, _ = run([])
assert m_a.dropped == 0 and len(res_a) == 6
assert m_a.waves == 2  # 6 requests over 4 slots: continuous batching

# the same request stream crossing TWO live resizes mid-generation:
# a tp-preserving shrink (resident cache adoption) and a byte-moving one
trace = [ResizeEvent(time_s=3.0, target=pc(1, 2)),
         ResizeEvent(time_s=6.0, target=pc(1, 1))]
res_b, m_b, recs, pool = run(trace)

assert m_b.dropped == 0, m_b.dropped
assert len(res_b) == 6
for rid in res_a:
    assert res_a[rid] == res_b[rid], (rid, res_a[rid], res_b[rid])
assert m_b.commits == 2 and len(recs) == 2
r1, r2 = recs
assert r1.outcome == "committed" and r2.outcome == "committed"
assert r1.cut_step > 0  # landed mid-generation, not at a wave boundary
# tp-preserving: live cache adopted in place — nothing executed
assert r1.cache_resident_layers > 0
assert r1.reused_layers > 0
assert r1.executed_bytes == 0 and r1.plan_network_bytes == 0
# tp-changing: bytes genuinely stream through the shared engine
assert r2.executed_bytes > 0 and r2.plan_network_bytes > 0
assert r2.cache_resident_layers == 0
# retired actives + the shutdown deposit make serving worlds pool citizens
assert pool.stats.puts >= 3, pool.stats
print("SERVE_E2E_OK parity=%d commits=%d drops=%d" %
      (len(res_b), m_b.commits, m_b.dropped))
"""


def test_generation_survives_resize_token_for_token(subproc):
    out = subproc(_E2E_SNIPPET, n_devices=8)
    assert "SERVE_E2E_OK" in out
