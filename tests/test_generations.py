"""Generation state machine invariants (paper §4.5.1, Fig. 4)."""

import threading

import pytest

from repro.core.generations import (
    GenerationMachine,
    GenState,
    InvalidTransition,
    StaleGeneration,
)


def test_full_lifecycle():
    m = GenerationMachine()
    assert m.state is GenState.STABLE
    g = m.begin_prepare("tp4")
    assert m.state is GenState.PREPARE
    assert m.generations_alive() == 2
    m.mark_ready(g.gen_id, payload="world")
    assert m.state is GenState.READY
    m.begin_switch(g.gen_id)
    old = m.commit_switch(g.gen_id)
    assert m.state is GenState.CLEANUP
    assert m.active.gen_id == g.gen_id
    assert old.gen_id == 0
    m.finish_cleanup()
    assert m.state is GenState.STABLE
    assert m.generations_alive() == 1


def test_monotonic_generation_ids():
    m = GenerationMachine()
    ids = []
    for _ in range(3):
        g = m.begin_prepare()
        ids.append(g.gen_id)
        m.mark_ready(g.gen_id)
        m.begin_switch(g.gen_id)
        m.commit_switch(g.gen_id)
        m.finish_cleanup()
    assert ids == sorted(ids)
    assert len(set(ids)) == 3


def test_at_most_two_generations():
    m = GenerationMachine()
    m.begin_prepare()
    with pytest.raises(InvalidTransition):
        m.begin_prepare()  # second shadow while one pending


def test_stale_generation_rejected():
    m = GenerationMachine()
    g = m.begin_prepare()
    with pytest.raises(StaleGeneration):
        m.mark_ready(g.gen_id + 7)


def test_cancel_pending_shadow():
    """Target topology became stale before commit (paper §7)."""
    m = GenerationMachine()
    g = m.begin_prepare()
    m.cancel()
    assert m.state is GenState.STABLE
    assert m.shadow is None
    g2 = m.begin_prepare()
    assert g2.gen_id > g.gen_id


def test_invalid_commit_before_switch():
    m = GenerationMachine()
    g = m.begin_prepare()
    m.mark_ready(g.gen_id)
    with pytest.raises(InvalidTransition):
        m.commit_switch(g.gen_id)


def test_thread_safety_smoke():
    m = GenerationMachine()
    g = m.begin_prepare()
    errs = []

    def worker():
        try:
            m.mark_ready(g.gen_id, payload="w")
        except Exception as e:  # only one thread may mark ready
            errs.append(e)

    ts = [threading.Thread(target=worker) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert m.state is GenState.READY
    assert len(errs) == 3  # the other three hit InvalidTransition
