"""Chunked prefill (beyond-paper serving feature): processing the prompt in
chunks against the growing cache must match whole-prompt prefill exactly
(same cache semantics, same logits) and support decode continuation."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import model as M

FAMILIES = ["qwen3-1.7b", "mamba2-2.7b", "jamba-v0.1-52b", "mixtral-8x7b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_chunked_matches_whole_prefill(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    b, s, C = 2, 32, 8
    toks = jax.random.randint(jax.random.key(1), (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :s]}

    last_ref, cache_ref, _ = M.prefill(
        cfg, params, batch, cache_dtype=jnp.float32, max_seq=s + 4
    )
    last_chk, cache_chk = M.prefill_chunked(
        cfg, params, batch, chunk_len=C, max_seq=s + 4
    )
    assert float(jnp.abs(last_chk - last_ref).max()) < 2e-4

    dec_ref, _ = M.decode_step(cfg, params, cache_ref, toks[:, s:], jnp.int32(s))
    dec_chk, _ = M.decode_step(cfg, params, cache_chk, toks[:, s:], jnp.int32(s))
    assert float(jnp.abs(dec_chk - dec_ref).max()) < 2e-4


def test_chunked_prefill_sliding_window_ring():
    """Chunks wrapping a ring cache (prompt 2x the window)."""
    cfg = get_config("mixtral-8x7b").reduced()
    assert cfg.sliding_window == 64
    params = M.init_params(cfg, jax.random.key(0))
    b, s, C = 1, 128, 32
    toks = jax.random.randint(jax.random.key(2), (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :s]}
    last_ref, cache_ref, _ = M.prefill(
        cfg, params, batch, cache_dtype=jnp.float32, max_seq=s + 4
    )
    last_chk, cache_chk = M.prefill_chunked(
        cfg, params, batch, chunk_len=C, max_seq=s + 4
    )
    assert float(jnp.abs(last_chk - last_ref).max()) < 2e-4
    dec_ref, _ = M.decode_step(cfg, params, cache_ref, toks[:, s:], jnp.int32(s))
    dec_chk, _ = M.decode_step(cfg, params, cache_chk, toks[:, s:], jnp.int32(s))
    assert float(jnp.abs(dec_chk - dec_ref).max()) < 2e-4
