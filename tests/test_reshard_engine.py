"""ReshardEngine: one plan, two backends — the live jax.Array executor must
produce byte-identical destination shards to the simulated-rank oracle, and
overlapped streaming must preserve training parity with stop-copy."""

from __future__ import annotations

import numpy as np
import pytest

from conftest import RESHAPE_PARITY_TOL
from repro.reshard.chunking import chunk_task, row_batches


def test_row_batches_shared_chunker():
    assert row_batches(0, 10, per_row_bytes=4, budget=12) == [
        (0, 3), (3, 6), (6, 9), (9, 10),
    ]
    assert row_batches(5, 6, per_row_bytes=1 << 30, budget=1) == [(5, 6)]


def test_chunk_task_uses_shared_row_batches():
    from repro.core.intersection import TransferTask

    t = TransferTask(
        tensor="params/w", collection="params", src_rank=0, dst_rank=1,
        bounds=((0, 64), (0, 32)), src_offset=(0, 0), dst_offset=(0, 0),
        nbytes=64 * 32 * 4, layer=0,
    )
    chunks = chunk_task(t, budget=32 * 4 * 16)
    assert [c.bounds[0] for c in chunks] == row_batches(0, 64, 32 * 4, 32 * 4 * 16)
    assert sum(c.nbytes for c in chunks) == t.nbytes


# The cross-backend parity sweep runs in a subprocess with 8 host devices:
# the plan is executed (a) by SimExecutor over per-rank numpy shards and
# (b) by LiveExecutor over globally-sharded jax.Arrays; destination shards
# must be byte-identical for every rank of the target configuration.
_PARITY_SNIPPET = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer
from repro.core.resource_view import TensorSpec, view_of
from repro.core.streaming import allocate_destination, execute_plan, materialize_rank
from repro.distribution.sharding import make_elastic_mesh
from repro.reshard import LiveExecutor, ReshardEngine

ROLE_AXIS = {"pp": "pipe", "tp": "model", "dp": "data", "ep": "expert", "none": None}

def sharding_for(spec, mesh):
    return NamedSharding(mesh, P(*[ROLE_AXIS[r] for r in spec.roles]))

specs = [
    TensorSpec("params/blocks/pos0/w", (8, 16, 32), "float32",
               ("pp", "none", "tp"), "stages", "params"),
    TensorSpec("params/blocks/pos0/b", (8, 32), "float32",
               ("pp", "tp"), "stages", "params"),
    TensorSpec("params/embed/tok", (64, 32), "float32", ("tp", "none"),
               "first", "params"),
    TensorSpec("mu/blocks/pos0/w", (8, 16, 32), "float32",
               ("pp", "none", "tp"), "stages", "mu"),
]
TRANSITIONS = [
    ("tp_grow",  ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=4)),
    ("dp_grow",  ParallelConfig(dp=1, tp=4), ParallelConfig(dp=2, tp=4)),
    ("dp_shrink",ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)),
    ("pp_to_tp", ParallelConfig(pp=2, tp=2), ParallelConfig(pp=1, tp=4)),
    ("tp_to_pp", ParallelConfig(dp=2, tp=2), ParallelConfig(pp=4, tp=2)),
]
rng = np.random.default_rng(0)
g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
for name, ca, cb in TRANSITIONS:
    plan = plan_transfer(specs, ca, cb, num_positions=1)
    # oracle: simulated ranks
    src = {r: materialize_rank(specs, ca, r, g) for r in range(ca.world_size)}
    dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
    sim_stats = execute_plan(plan, src, dst, staging_bytes=2048)
    # live: global jax.Arrays, sharded on mesh_a -> mesh_b
    mesh_a, mesh_b = make_elastic_mesh(ca), make_elastic_mesh(cb)
    live_src = {s.name: jax.device_put(jnp.asarray(g[s.name]), sharding_for(s, mesh_a))
                for s in specs}
    targets = {s.name: sharding_for(s, mesh_b) for s in specs}
    ex = LiveExecutor({s.name: s for s in specs}, live_src, targets, 2048)
    live_stats = ReshardEngine(plan, ex, staging_bytes=2048).run()
    ex.block_until_ready()
    # identical engine-side accounting from both backends
    assert live_stats.network_bytes == sim_stats.network_bytes, name
    assert live_stats.local_bytes == sim_stats.local_bytes, name
    assert live_stats.resident_bytes == sim_stats.resident_bytes, name
    assert live_stats.layers_streamed == sim_stats.layers_streamed, name
    live_stats.assert_bounded(2048)
    # byte-identical destination shards on every target rank
    for s in specs:
        got = np.asarray(jax.device_get(ex.results()[s.name]))
        np.testing.assert_array_equal(got, g[s.name], err_msg=f"{name}/{s.name}")
        for r in range(cb.world_size):
            v = view_of(s, cb, r)
            if v is None:
                continue
            sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
            np.testing.assert_array_equal(
                got[sl], dst[r].shards[s.name], err_msg=f"{name}/{s.name}/rank{r}")
    print("BACKEND_PARITY_OK", name)
print("ALL_OK")
"""


def test_live_matches_sim_across_reshapes(subproc):
    out = subproc(_PARITY_SNIPPET, n_devices=8)
    assert "ALL_OK" in out
    assert out.count("BACKEND_PARITY_OK") == 5


def test_stream_stats_surface_dispatch_drain_and_generic_cells():
    """The async data plane's accounting fields merge like the others, so
    per-round stats keep attributing dispatch-vs-drain after aggregation."""
    from repro.reshard import StreamStats

    a = StreamStats(dispatch_seconds=0.25, drain_seconds=0.5, generic_cells=2)
    b = StreamStats(dispatch_seconds=0.75, drain_seconds=1.0, generic_cells=3)
    a.merge(b)
    assert a.dispatch_seconds == 1.0
    assert a.drain_seconds == 1.5
    assert a.generic_cells == 5


def test_resident_skip_parity_and_dirty_reclassify(subproc):
    """Delta-aware plan IR (DESIGN.md §13): a tp-preserving shrink classifies
    fully resident — the live executor must move ZERO bytes (aliasing
    pass-throughs only) yet stay bitwise-identical to the SimExecutor
    oracle; dirtying the sources and re-syncing must refresh from the new
    cut still without streaming (re-classify, not re-stream); and the
    delta=False baseline must physically move every byte."""
    out = subproc(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ParallelConfig
        from repro.core.intersection import plan_transfer
        from repro.core.resource_view import TensorSpec, view_of
        from repro.core.streaming import (
            allocate_destination, execute_plan, materialize_rank)
        from repro.distribution.sharding import make_elastic_mesh
        from repro.reshard import LiveExecutor, OverlapSession, ReshardEngine

        specs = [
            TensorSpec("params/blocks/pos0/w", (8, 16, 32), "float32",
                       ("pp", "none", "tp"), "stages", "params"),
            TensorSpec("params/embed/tok", (64, 32), "float32",
                       ("tp", "none"), "first", "params"),
        ]
        ca, cb = ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)
        plan = plan_transfer(specs, ca, cb, num_positions=1)
        assert plan.network_bytes == 0 and plan.local_bytes == 0
        assert plan.resident_bytes > 0
        assert plan.resident_layers() == plan.layers()

        rng = np.random.default_rng(0)
        v0 = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
        v1 = {k: v + 1.0 for k, v in v0.items()}  # optimizer stepped

        # oracle
        src = {r: materialize_rank(specs, ca, r, v0) for r in range(ca.world_size)}
        dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
        sim_stats = execute_plan(plan, src, dst, staging_bytes=2048)
        assert sim_stats.resident_bytes == plan.resident_bytes
        assert sim_stats.executed_bytes == 0  # oracle prices resident at zero

        ROLE_AXIS = {"pp": "pipe", "tp": "model", "dp": "data", "none": None}
        mesh_a, mesh_b = make_elastic_mesh(ca), make_elastic_mesh(cb)
        def sharding_for(s, mesh):
            return NamedSharding(mesh, P(*[ROLE_AXIS[r] for r in s.roles]))
        def leaves(v):
            return {s.name: jax.device_put(jnp.asarray(v[s.name]),
                                           sharding_for(s, mesh_a))
                    for s in specs}
        targets = {s.name: sharding_for(s, mesh_b) for s in specs}

        # live delta path: zero bytes moved, pass-throughs only
        ex = LiveExecutor({s.name: s for s in specs}, leaves(v0), targets, 2048)
        live_stats = ReshardEngine(plan, ex, staging_bytes=2048).run()
        ex.block_until_ready()
        assert live_stats.resident_bytes == sim_stats.resident_bytes
        assert live_stats.executed_bytes == 0, live_stats.executed_bytes
        assert ex.resident_passthroughs > 0
        for s in specs:
            got = np.asarray(jax.device_get(ex.results()[s.name]))
            np.testing.assert_array_equal(got, v0[s.name])
            for r in range(cb.world_size):
                v = view_of(s, cb, r)
                if v is None:
                    continue
                sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
                np.testing.assert_array_equal(got[sl], dst[r].shards[s.name])
        print("RESIDENT_SKIP_PARITY_OK")

        # dirty-resident re-classification through the overlap session:
        # precopy is trivially done (no non-resident layers), the commit
        # resync refreshes from the NEW cut, still moving zero bytes
        sess = OverlapSession(specs, plan, {}, targets,
                              staging_bytes=1 << 20, stream_k=3)
        assert sess.done_precopy  # nothing to pre-copy: all resident
        assert sess.report.reused_layers == len(plan.layers())
        s1 = sess.resync(leaves(v1), step=1)
        assert s1.executed_bytes == 0, s1.executed_bytes
        assert s1.resident_bytes == plan.resident_bytes
        assert sess.report.skipped_bytes >= plan.resident_bytes
        for s in specs:
            got = np.asarray(jax.device_get(sess.results()[s.name]))
            np.testing.assert_array_equal(got, v1[s.name])  # new cut, not v0
        print("DIRTY_RECLASSIFY_OK")

        # full-copy baseline (delta=False): every byte physically moves
        ex_b = LiveExecutor({s.name: s for s in specs}, leaves(v0), targets, 2048)
        base = ReshardEngine(plan, ex_b, staging_bytes=2048, delta=False).run()
        ex_b.block_until_ready()
        assert base.resident_bytes == 0
        assert base.local_bytes == plan.resident_bytes
        assert ex_b.executed_bytes > 0
        for s in specs:
            got = np.asarray(jax.device_get(ex_b.results()[s.name]))
            np.testing.assert_array_equal(got, v0[s.name])
        print("BASELINE_MOVES_OK")
        """,
        n_devices=8,
    )
    assert "RESIDENT_SKIP_PARITY_OK" in out
    assert "DIRTY_RECLASSIFY_OK" in out
    assert "BASELINE_MOVES_OK" in out


def test_scattered_restream_idempotent_vs_sim(subproc):
    """The fused pack -> staged put -> overwrite-scatter path on a scattered
    row set (the dirty re-sync workload): re-streaming the same dirty layer
    twice must be bit-exact, stay off the generic fallback, and match the
    SimExecutor byte oracle's destination shard."""
    out = subproc(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core.intersection import TransferPlan, TransferTask
        from repro.core.resource_view import TensorSpec
        from repro.core.streaming import RankStore
        from repro.reshard import LiveExecutor, ReshardEngine, SimExecutor

        R, C = 32, 256
        spec = TensorSpec("params/w", (R, C), "float32", ("none", "none"),
                          "all", "params")
        rows = [1, 3, 4, 8, 13, 21, 22, 30]  # scattered: multi-run batches
        plan = TransferPlan(tasks=[
            TransferTask(tensor=spec.name, collection="params", src_rank=0,
                         dst_rank=1, bounds=((r, r + 1), (0, C)),
                         src_offset=(r, 0), dst_offset=(r, 0),
                         nbytes=C * 4, layer=0)
            for r in rows], cfg_src=None, cfg_dst=None)
        budget = C * 4 * 3  # 3 rows per staging batch: mixed run shapes

        rng = np.random.default_rng(0)
        v0 = rng.normal(size=(R, C)).astype(np.float32)
        v1 = v0 + 1.0  # "optimizer stepped": the layer is dirty

        # byte oracle: simulated ranks moving v1
        src_s = RankStore(0); src_s.shards[spec.name] = v1.copy()
        dst_s = RankStore(1); dst_s.shards[spec.name] = np.zeros((R, C), np.float32)
        ReshardEngine(plan, SimExecutor({0: src_s}, {1: dst_s}),
                      staging_bytes=budget).run()

        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        sh = NamedSharding(mesh, P(None, "model"))
        def leaves(v):
            return {spec.name: jax.device_put(jnp.asarray(v), sh)}

        ex = LiveExecutor({spec.name: spec}, leaves(v0), {spec.name: sh}, budget)
        eng = ReshardEngine(plan, ex, staging_bytes=budget)
        s0 = eng.run(); ex.block_until_ready()
        got0 = np.asarray(jax.device_get(ex.results()[spec.name]))
        exp0 = np.zeros((R, C), np.float32); exp0[rows] = v0[rows]
        np.testing.assert_array_equal(got0, exp0)
        assert s0.generic_cells == 0, s0.generic_cells  # stayed on fast path
        assert s0.dispatch_seconds > 0.0

        # dirty re-stream twice from the SAME post-step sources: overwrite
        # semantics => bit-identical both times, equal to the sim oracle
        for attempt in range(2):
            ex.update_sources(leaves(v1)); ex.reset_round()
            eng.run(); ex.block_until_ready()
            got = np.asarray(jax.device_get(ex.results()[spec.name]))
            exp1 = np.zeros((R, C), np.float32); exp1[rows] = v1[rows]
            np.testing.assert_array_equal(got, exp1, err_msg=f"pass{attempt}")
            np.testing.assert_array_equal(got, dst_s.shards[spec.name])
        print("IDEMPOTENT_OK")
        """,
        n_devices=8,
    )
    assert "IDEMPOTENT_OK" in out


def test_dirty_resync_is_byte_exact(subproc):
    """The one-step-stale failure class: pre-copy all layers, mutate the
    sources (as an optimizer step would), re-sync the dirty set — the
    destination must equal the NEW source bytes exactly, including layers
    that were re-streamed over their stale pre-copied values (overwrite,
    not accumulate) and scattered (non-contiguous) dirty row sets."""
    out = subproc(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ParallelConfig
        from repro.core.intersection import plan_transfer
        from repro.core.resource_view import TensorSpec
        from repro.distribution.sharding import make_elastic_mesh
        from repro.reshard import OverlapSession

        specs = [TensorSpec("params/blocks/pos0/w", (8, 16, 32), "float32",
                            ("pp", "none", "tp"), "stages", "params")]
        ca, cb = ParallelConfig(dp=2, tp=2), ParallelConfig(pp=2, tp=2)
        plan = plan_transfer(specs, ca, cb, num_positions=1)
        mesh_a, mesh_b = make_elastic_mesh(ca), make_elastic_mesh(cb)
        sh_a = NamedSharding(mesh_a, P(None, None, "model"))
        sh_b = NamedSharding(mesh_b, P("pipe", None, "model"))
        rng = np.random.default_rng(0)
        v0 = rng.normal(size=(8, 16, 32)).astype(np.float32)
        v1 = v0 + 1.0   # "optimizer stepped": every element changed

        def leaves(v):
            return {specs[0].name: jax.device_put(jnp.asarray(v), sh_a)}

        sess = OverlapSession(specs, plan, {}, {specs[0].name: sh_b},
                              staging_bytes=1 << 20, stream_k=3)
        # pre-copy rounds at step 0 (3 + 3 + 2 + 1(non-layer none) layers)
        while not sess.done_precopy:
            sess.stream_next(leaves(v0), step=0)
        got0 = np.asarray(jax.device_get(sess.results()[specs[0].name]))
        np.testing.assert_array_equal(got0, v0)
        # everything streamed at step 0 is dirty once the optimizer steps
        assert sorted(sess.dirty_layers(1)) == sess.engine.layers()
        sess.resync(leaves(v1), step=1)
        got1 = np.asarray(jax.device_get(sess.results()[specs[0].name]))
        np.testing.assert_array_equal(got1, v1)  # NOT v0+v1, NOT stale v0
        assert not sess.dirty_layers(1)
        print("RESYNC_EXACT_OK resynced=%d" % sess.report.resync_layers)
        """,
        n_devices=8,
    )
    assert "RESYNC_EXACT_OK" in out


def test_overlapped_streaming_matches_stop_copy(subproc):
    """Same data, same seeds: the overlapped (pre-copy + dirty re-sync +
    split-step commit) controller must track the stop-copy controller's
    loss trajectory step for step, and its blocking commit pause must not
    include the pre-copied bytes."""
    out = subproc(
        """
        import time, numpy as np
        import jax, jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)

        def run(mode):
            ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), opt,
                                   seq_len=32, global_batch=8,
                                   overlap=mode, stream_k=2)
            losses = ctrl.train_steps(3)
            ctrl.request_resize(ParallelConfig(dp=1, tp=4))
            t0 = time.time()
            while not ctrl.records and time.time() - t0 < 420:
                losses += ctrl.train_steps(1)
            assert ctrl.records, mode
            losses += ctrl.train_steps(3)
            return ctrl, losses

        c_stop, l_stop = run("stop_copy")
        c_ovl, l_ovl = run("stream")
        rec = c_ovl.records[0]
        assert rec.mode == "live_overlap", rec.mode
        assert rec.precopy_bytes > 0, "no layers were pre-copied"
        assert rec.dirty_layers <= rec.layers_total
        # every planned byte arrived (pre-copy round + dirty re-sync)
        assert rec.precopy_bytes + rec.resync_bytes >= (
            rec.plan_network_bytes + rec.plan_local_bytes)
        # equalize step counts (prepare duration varies between runs)
        n = max(len(l_stop), len(l_ovl))
        l_stop += c_stop.train_steps(n - len(l_stop))
        l_ovl += c_ovl.train_steps(n - len(l_ovl))
        dev = max(abs(a - b) for a, b in zip(l_stop, l_ovl))
        assert dev < __TOL__, f"loss trajectory diverged: {dev}"
        p_s = c_stop.gathered_params(); p_o = c_ovl.gathered_params()
        md = max(jtu.tree_leaves(jtu.tree_map(
            lambda a, b: float(np.abs(a - b).max()), p_s, p_o)))
        assert md < __TOL__, f"param divergence {md}"
        print("OVERLAP_PARITY_OK loss_dev=%.2e param_dev=%.2e pause=%.3fs" %
              (dev, md, rec.total_pause_s))
        """.replace("__TOL__", repr(RESHAPE_PARITY_TOL)),
        n_devices=8,
    )
    assert "OVERLAP_PARITY_OK" in out
