"""End-to-end live reconfiguration on multi-device meshes (subprocess with 8
host devices): the paper's §6.6 parity experiment, invariant I1 (training
continues during prepare), peer-replica fail-stop recovery with its demoted
checkpoint rung (DESIGN.md §15), and resize cancellation.
"""

from __future__ import annotations

import pytest

from conftest import RESHAPE_PARITY_TOL


def test_live_reshape_parity_and_overlap(subproc):
    out = subproc(
        """
        import time, jax, numpy as np
        import jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), opt,
                               seq_len=32, global_batch=8)
        losses = ctrl.train_steps(3)
        ctrl.request_resize(ParallelConfig(dp=2, tp=4))
        t0 = time.time(); steps_during = 0
        while not ctrl.records and time.time() - t0 < 420:
            losses += ctrl.train_steps(1); steps_during += 1
        assert ctrl.records, "switch never happened"
        rec = ctrl.records[0]
        assert steps_during > 0, "no overlap: training was blocked (I1 violated)"
        assert ctrl.world.parallel.tp == 4
        assert rec.total_pause_s < rec.prepare_s, "pause should be << prepare"
        assert rec.switch_s < 0.5
        # plan-vs-live agreement: the engine executed the planned bytes
        assert rec.plan_network_bytes + rec.plan_local_bytes > 0
        assert rec.executed_bytes > 0
        losses += ctrl.train_steps(3)

        ctrl2 = LiveRController(cfg, ParallelConfig(dp=2, tp=2), opt,
                                seq_len=32, global_batch=8)
        l_ref = ctrl2.train_steps(len(losses))
        ref = ctrl2.gathered_params(); now = ctrl.gathered_params()
        md = max(jtu.tree_leaves(jtu.tree_map(
            lambda a, b: float(np.abs(a - b).max()), now, ref)))
        # tolerance: cross-mesh reduction-order noise amplified by Adam —
        # see RESHAPE_PARITY_TOL in conftest.py (the byte movement itself
        # is bit-exact; tested in test_reshard_engine.py)
        assert md < __TOL__, f"param divergence {md}"
        print("PARITY_OK steps_during=%d pause=%.3fs" %
              (steps_during, rec.total_pause_s))
        """.replace("__TOL__", repr(RESHAPE_PARITY_TOL)),
        n_devices=8,
    )
    assert "PARITY_OK" in out


def test_scale_in_and_machine_states(subproc):
    out = subproc(
        """
        import time
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.generations import GenState
        from repro.optim import AdamWConfig

        cfg = get_config("mamba2-2.7b").reduced()
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2),
                               AdamWConfig(), seq_len=16, global_batch=4)
        ctrl.train_steps(2)
        ctrl.request_resize(ParallelConfig(dp=1, tp=2))  # scale-in 4 -> 2
        t0 = time.time()
        while not ctrl.records and time.time() - t0 < 420:
            ctrl.train_steps(1)
        assert ctrl.records and ctrl.world.parallel.world_size == 2
        assert ctrl.machine.state is GenState.STABLE
        hist = [s for s, _ in ctrl.machine.history]
        for phase in ("prepare", "ready", "switch", "cleanup", "stable"):
            assert phase in hist
        ctrl.train_steps(2)
        print("SCALE_IN_OK")
        """,
        n_devices=8,
    )
    assert "SCALE_IN_OK" in out


def test_failstop_peer_recovery_keeps_step(subproc):
    """A fail-stop with surviving DP replicas recovers from peers in
    memory (DESIGN.md §15): no checkpoint read, NO step rollback."""
    out = subproc(
        """
        import tempfile, time
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        ckpt = tempfile.mkdtemp()
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                               seq_len=16, global_batch=4,
                               ckpt_dir=ckpt, ckpt_interval=4)
        ctrl.train_steps(9)   # checkpoints at 4 and 8
        rec = ctrl.fail_stop_recover(ParallelConfig(dp=1, tp=2))
        assert rec.mode == "peer_recover", rec.mode
        assert rec.outcome == "committed", rec.outcome
        assert rec.lost_devices == 2, rec.lost_devices
        assert ctrl.step == 9, f"step rolled back to {ctrl.step}"
        assert ctrl.world.parallel.world_size == 2
        ctrl.train_steps(2)
        print("PEER_OK step=%d" % ctrl.step)
        """,
        n_devices=8,
    )
    assert "PEER_OK" in out


def test_failstop_demotes_to_checkpoint_when_uncovered(subproc):
    """dp=1, no parity snapshots: the dead ranks' tp shards have no
    surviving replica, so the controller demotes to the durable rung —
    which rolls back to the last checkpointed step."""
    out = subproc(
        """
        import tempfile, time
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        ckpt = tempfile.mkdtemp()
        ctrl = LiveRController(cfg, ParallelConfig(dp=1, tp=4), AdamWConfig(),
                               seq_len=16, global_batch=4,
                               ckpt_dir=ckpt, ckpt_interval=4)
        ctrl.train_steps(9)   # checkpoints at 4 and 8
        rec = ctrl.fail_stop_recover(ParallelConfig(dp=1, tp=2))
        assert rec.mode == "fallback", rec.mode
        assert rec.outcome == "fell_back", rec.outcome
        assert ctrl.step == 8, f"resumed at {ctrl.step}, expected ckpt step 8"
        assert ctrl.world.parallel.world_size == 2
        ctrl.train_steps(2)
        print("FALLBACK_OK resumed=%d" % ctrl.step)
        """,
        n_devices=8,
    )
    assert "FALLBACK_OK" in out


def test_failstop_without_ckpt_or_peers_raises_typed_error(subproc):
    """No surviving replica, no parity, no ckpt_dir: a typed RecoveryError
    (never a bare assert) so callers can degrade gracefully."""
    out = subproc(
        """
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.errors import RecoveryError
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        ctrl = LiveRController(cfg, ParallelConfig(dp=1, tp=4), AdamWConfig(),
                               seq_len=16, global_batch=4, ckpt_dir=None)
        ctrl.train_steps(2)
        try:
            ctrl.fail_stop_recover(ParallelConfig(dp=1, tp=2))
        except RecoveryError as e:
            print("TYPED_OK", type(e).__name__)
        else:
            raise SystemExit("expected RecoveryError")
        """,
        n_devices=8,
    )
    assert "TYPED_OK" in out


def test_cancel_stale_target(subproc):
    out = subproc(
        """
        import time
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.generations import GenState
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                               seq_len=16, global_batch=4)
        ctrl.request_resize(ParallelConfig(dp=2, tp=4))
        ctrl.cancel_resize()   # target became stale (paper §7)
        assert ctrl.machine.state is GenState.STABLE
        # a fresh resize still works afterwards
        ctrl.request_resize(ParallelConfig(dp=1, tp=4))
        t0 = time.time()
        while not ctrl.records and time.time() - t0 < 420:
            ctrl.train_steps(1)
        assert ctrl.world.parallel.describe() == "dp1xpp1xtp4"
        print("CANCEL_OK")
        """,
        n_devices=8,
    )
    assert "CANCEL_OK" in out


def test_live_reshape_with_optimized_sharding_hints(subproc):
    """The beyond-paper sharding hints (EXPERIMENTS §Perf) compose with the
    live reconfiguration path: resize under hint_version=v2."""
    out = subproc(
        """
        import time
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        ctrl = LiveRController(cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                               seq_len=32, global_batch=8, hint_version="v2")
        l0 = ctrl.train_steps(3)
        ctrl.request_resize(ParallelConfig(dp=1, tp=4))
        t0 = time.time()
        while not ctrl.records and time.time() - t0 < 420:
            l0 += ctrl.train_steps(1)
        assert ctrl.records and ctrl.world.parallel.tp == 4
        l1 = ctrl.train_steps(3)
        assert all(x == x for x in l1), "NaN loss after hinted reshape"
        print("HINTED_RESHAPE_OK")
        """,
        n_devices=8,
    )
    assert "HINTED_RESHAPE_OK" in out
