"""Pallas kernel validation: interpret=True vs pure-jnp oracle, with
shape/dtype sweeps (assignment requirement)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.reshard_pack import (
    pack_rows_pallas,
    relayout_rows_pallas,
    scatter_rows_pallas,
    unpack_rows_pallas,
)
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_intra_chunk_pallas

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kh,d,causal,window",
    [
        (1, 128, 2, 2, 64, True, 0),
        (2, 256, 4, 2, 64, True, 0),
        (2, 256, 4, 1, 32, True, 128),  # MQA + sliding window
        (1, 128, 2, 2, 128, False, 0),
        (1, 384, 6, 3, 64, True, 0),  # GQA rep=2, 3 blocks
    ],
)
def test_flash_attention_sweep(b, s, h, kh, d, causal, window, dtype):
    q, k, v = _rand((b, s, h, d), dtype), _rand((b, s, kh, d), dtype), _rand((b, s, kh, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(exp, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_cross_block_q_offset():
    """t > s: right-aligned queries (continuation chunk)."""
    q = _rand((1, 128, 2, 64))
    k = _rand((1, 256, 2, 64))
    v = _rand((1, 256, 2, 64))
    out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [
        (1, 64, 2, 16, 32, 16),
        (2, 128, 3, 32, 64, 32),
        (1, 96, 4, 64, 128, 16),  # jamba/mamba2-ish dims
    ],
)
def test_ssd_intra_chunk_sweep(b, s, h, p, n, chunk):
    x = _rand((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.3, (b, s, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = _rand((b, s, n))
    C = _rand((b, s, n))

    import os

    os.environ["REPRO_FORCE_PALLAS_INTERPRET"] = "1"
    try:
        from repro.kernels import ops

        y1, f1 = ops.ssd_scan(x, dt, A, B, C, chunk)
    finally:
        os.environ.pop("REPRO_FORCE_PALLAS_INTERPRET", None)
    y2, f2 = ref.ssd_scan_ref(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-5, rtol=1e-5)


def test_ssd_matches_sequential_recurrence():
    """Chunked SSD == naive per-token recurrence (ground truth)."""
    b, s, h, p, n, chunk = 1, 32, 2, 8, 16, 8
    x = np.asarray(_rand((b, s, h, p)))
    dt = RNG.uniform(0.01, 0.3, (b, s, h)).astype(np.float32)
    A = -RNG.uniform(0.5, 2.0, (h,)).astype(np.float32)
    B = np.asarray(_rand((b, s, n)))
    C = np.asarray(_rand((b, s, n)))

    state = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        decay = np.exp(dt[:, t] * A[None, :])  # (b,h)
        state = decay[:, :, None, None] * state + (
            dt[:, t][:, :, None, None]
            * x[:, t][:, :, :, None]
            * B[:, t][:, None, None, :]
        )
        ys[:, t] = np.einsum("bhpn,bn->bhp", state, C[:, t])

    y, final = ref.ssd_scan_ref(
        jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A), jnp.asarray(B),
        jnp.asarray(C), chunk,
    )
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(final), state, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm / pack / unpack
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 300),
    d=st.sampled_from([128, 256]),
)
def test_rmsnorm_property(rows, d):
    x = _rand((rows, d))
    sc = _rand((d,))
    out = rmsnorm_pallas(x, sc, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.rmsnorm_ref(x, sc)), atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_pack_unpack_roundtrip(data):
    nb = data.draw(st.integers(1, 6))
    block = data.draw(st.sampled_from([8, 16]))
    R = block * data.draw(st.integers(nb, 12))
    starts = data.draw(
        st.lists(
            st.integers(0, R // block - 1), min_size=nb, max_size=nb, unique=True
        )
    )
    starts = jnp.asarray(sorted(s * block for s in starts), jnp.int32)
    src = _rand((R, 128))
    packed = pack_rows_pallas(src, starts, block, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(packed), np.asarray(ref.pack_rows_ref(src, starts, block))
    )
    un = unpack_rows_pallas(packed, starts, block, R, interpret=True)
    for st_ in np.asarray(starts):
        np.testing.assert_array_equal(
            np.asarray(un[st_ : st_ + block]), np.asarray(src[st_ : st_ + block])
        )


# ---------------------------------------------------------------------------
# scatter_rows: overwrite-semantics scatter (the live re-sync fast path)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_scatter_rows_property(data):
    """Pallas (interpret) == jnp oracle == manual numpy overwrite, including
    preservation of every destination row NOT named by the offset table
    (the input_output_aliases carry-through)."""
    nb = data.draw(st.integers(1, 6))
    block = data.draw(st.sampled_from([1, 8]))
    R = block * data.draw(st.integers(max(nb, 2), 12))
    starts = data.draw(
        st.lists(
            st.integers(0, R // block - 1), min_size=nb, max_size=nb, unique=True
        )
    )
    starts = jnp.asarray([s * block for s in starts], jnp.int32)
    dst = _rand((R, 128))
    buf = _rand((nb * block, 128))
    out_p = scatter_rows_pallas(dst, buf, starts, block, interpret=True)
    out_r = ref.scatter_rows_ref(dst, buf, starts, block)
    exp = np.asarray(dst).copy()
    for i, s in enumerate(np.asarray(starts)):
        exp[s : s + block] = np.asarray(buf)[i * block : (i + 1) * block]
    np.testing.assert_array_equal(np.asarray(out_r), exp)
    np.testing.assert_array_equal(np.asarray(out_p), exp)


def test_scatter_rows_duplicate_starts_last_wins():
    """Both paths resolve duplicate offsets sequentially (last block wins) —
    the deterministic tie-break the oracle's fori_loop defines."""
    dst = _rand((16, 128))
    buf = _rand((3, 128))
    starts = jnp.asarray([4, 4, 9], jnp.int32)
    exp = np.asarray(dst).copy()
    exp[4] = np.asarray(buf)[1]
    exp[9] = np.asarray(buf)[2]
    np.testing.assert_array_equal(
        np.asarray(ref.scatter_rows_ref(dst, buf, starts, 1)), exp
    )
    np.testing.assert_array_equal(
        np.asarray(scatter_rows_pallas(dst, buf, starts, 1, interpret=True)), exp
    )


def test_scatter_rows_idempotent():
    """Overwrite semantics: re-applying the same scatter is a no-op (the
    dirty-layer re-stream invariant; an accumulate scatter would fail this)."""
    dst = _rand((24, 128))
    buf = _rand((4, 128))
    starts = jnp.asarray([2, 7, 11, 21], jnp.int32)
    once = ref.scatter_rows_ref(dst, buf, starts, 1)
    twice = ref.scatter_rows_ref(once, buf, starts, 1)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
    once_p = scatter_rows_pallas(dst, buf, starts, 1, interpret=True)
    twice_p = scatter_rows_pallas(once_p, buf, starts, 1, interpret=True)
    np.testing.assert_array_equal(np.asarray(once_p), np.asarray(twice_p))


# ---------------------------------------------------------------------------
# relayout_rows: fused gather->scatter for the classified "local" cells
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_relayout_rows_property(data):
    """Pallas (interpret) == jnp oracle == manual numpy copy: named row
    blocks of src overwrite the same offsets of dst; every other dst row
    keeps its bytes (the input_output_aliases carry-through)."""
    nb = data.draw(st.integers(1, 6))
    block = data.draw(st.sampled_from([1, 8]))
    R = block * data.draw(st.integers(max(nb, 2), 12))
    starts = data.draw(
        st.lists(
            st.integers(0, R // block - 1), min_size=nb, max_size=nb, unique=True
        )
    )
    starts = jnp.asarray([s * block for s in starts], jnp.int32)
    dst = _rand((R, 128))
    src = _rand((R, 128))
    out_p = relayout_rows_pallas(dst, src, starts, block, interpret=True)
    out_r = ref.relayout_rows_ref(dst, src, starts, block)
    exp = np.asarray(dst).copy()
    for s in np.asarray(starts):
        exp[s : s + block] = np.asarray(src)[s : s + block]
    np.testing.assert_array_equal(np.asarray(out_r), exp)
    np.testing.assert_array_equal(np.asarray(out_p), exp)


def test_relayout_rows_idempotent_and_matches_pack_scatter():
    """relayout == pack o scatter composed (same bytes, one program), and
    re-applying it is a no-op — the resident/dirty re-classify invariant."""
    from repro.kernels import ops

    src = _rand((32, 128))
    dst = _rand((32, 128))
    rows = jnp.asarray([0, 3, 4, 11, 30], jnp.int32)
    via_pack = ops.scatter_rows(dst, ops.pack_rows(src, rows, 1), rows, 1)
    once = ops.relayout_rows(dst, src, rows, 1)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(via_pack))
    twice = ops.relayout_rows(once, src, rows, 1)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
    once_p = relayout_rows_pallas(dst, src, rows, 1, interpret=True)
    np.testing.assert_array_equal(np.asarray(once_p), np.asarray(via_pack))


def test_pack_then_scatter_roundtrip():
    """ops-level dispatch: pack_rows o scatter_rows restores the gathered
    rows into a different destination exactly (the executor's fused path)."""
    from repro.kernels import ops

    src = _rand((32, 128))
    dst = _rand((32, 128))
    rows = jnp.asarray([1, 4, 5, 9, 30], jnp.int32)
    buf = ops.pack_rows(src, rows, 1)
    out = ops.scatter_rows(dst, buf, rows, 1)
    exp = np.asarray(dst).copy()
    for r in np.asarray(rows):
        exp[r] = np.asarray(src)[r]
    np.testing.assert_array_equal(np.asarray(out), exp)
