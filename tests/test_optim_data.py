"""Optimizer + data-pipeline unit tests."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import SyntheticLM
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr, global_norm


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(learning_rate=0.1, warmup_steps=0, weight_decay=0.0,
                      total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=0, grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, m = adamw_update(cfg, grads, opt, params)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_cosine_schedule_shape():
    cfg = AdamWConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1.0, rel=1e-3)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


# ---------------------------------------------------------------------------
# data pipeline: determinism + elastic invariant
# ---------------------------------------------------------------------------


def test_data_deterministic():
    d1 = SyntheticLM(512, 32, 8, seed=7)
    d2 = SyntheticLM(512, 32, 8, seed=7)
    np.testing.assert_array_equal(d1.global_batch_at(3), d2.global_batch_at(3))
    assert not np.array_equal(d1.global_batch_at(3), d1.global_batch_at(4))


@settings(max_examples=10, deadline=None)
@given(
    dp_a=st.sampled_from([1, 2, 4, 8]),
    dp_b=st.sampled_from([1, 2, 4, 8]),
    step=st.integers(0, 50),
)
def test_elastic_resharding_invariant(dp_a, dp_b, step):
    """The global token stream is identical under every DP decomposition —
    the data-plane requirement for live reconfiguration."""
    data = SyntheticLM(512, 16, 8, seed=1)
    ga = np.concatenate([data.shard_at(step, r, dp_a) for r in range(dp_a)])
    gb = np.concatenate([data.shard_at(step, r, dp_b) for r in range(dp_b)])
    np.testing.assert_array_equal(ga, gb)
    np.testing.assert_array_equal(ga, data.global_batch_at(step))


def test_structured_mode_is_learnable():
    """Markov structure => next token is predictable from current one."""
    data = SyntheticLM(512, 64, 4, seed=0, mode="structured")
    batch = data.global_batch_at(0)
    # consecutive-token mapping should be highly concentrated
    x, y = batch[:, :-1].ravel(), batch[:, 1:].ravel()
    from collections import Counter, defaultdict

    by_x = defaultdict(Counter)
    for a, b in zip(x, y):
        by_x[a][b] += 1
    top1 = sum(c.most_common(1)[0][1] for c in by_x.values())
    assert top1 / len(x) > 0.5
