"""ElasticScheduler: deadline fallback lattice, coalescing, mid-stream
retarget with streamed-state reuse, and byte parity of scheduler-driven
resizes against a direct ``request_resize`` and the SimExecutor oracle.

The decision/bookkeeping tests drive the scheduler with a tiny in-memory
stand-in controller so they run on bare CPU in milliseconds; the
end-to-end ones spawn the usual 8-host-device subprocess.
"""

from __future__ import annotations

import time
from types import SimpleNamespace

import pytest

from repro.configs.base import ParallelConfig
from repro.core.controller import ReconfigRecord
from repro.core.downtime import GoodputLedger
from repro.core.events import FailStopEvent, ResizeEvent, sort_trace
from repro.elastic import (
    ControllerEndpoint,
    ElasticScheduler,
    ReconfigEstimate,
    WireEndpoint,
    choose_mode,
)


# ---------------------------------------------------------------------------
# Pure lattice / trace helpers
# ---------------------------------------------------------------------------


def _est(prepare=10.0, precopy=5.0, pause=2.0):
    return ReconfigEstimate(
        prepare_s=prepare, precopy_s=precopy, stream_pause_s=pause,
        stop_copy_pause_s=pause, plan_bytes=1 << 20, rounds=5, step_s=0.1,
    )


def test_choose_mode_fallback_lattice():
    est = _est()  # stream total 17, stop-copy total 12
    assert choose_mode(est, 1e9) == "stream"
    assert choose_mode(est, 17 * 1.25) == "stream"  # boundary inclusive
    assert choose_mode(est, 17 * 1.25 - 1e-6) == "stop_copy"
    assert choose_mode(est, 12 * 1.25) == "stop_copy"
    assert choose_mode(est, 12 * 1.25 - 1e-6) == "checkpoint"
    assert choose_mode(est, 0.0) == "checkpoint"
    # peer_recover rung (DESIGN.md §15): sits between stop-copy and
    # checkpoint — it needs nothing inside the window, so any window the
    # live rungs cannot cover routes to it whenever peers cover the state
    import dataclasses

    peer = dataclasses.replace(_est(), peer_ok=True)
    assert choose_mode(peer, 12 * 1.25 - 1e-6) == "peer_recover"
    assert choose_mode(peer, 0.0) == "peer_recover"
    assert choose_mode(peer, 1e9) == "stream"  # live rungs still win
    # time_scale converts real estimates into trace units before comparing:
    # at scale 2 a 30 s window only covers the stop-copy rung (2x15)
    assert choose_mode(est, 30.0, time_scale=2.0) == "stop_copy"
    assert choose_mode(est, 30.0, time_scale=1.0) == "stream"


def test_sort_trace_is_stable_by_time():
    evs = [
        ResizeEvent(time_s=5.0, target=ParallelConfig(dp=2)),
        FailStopEvent(time_s=1.0),
        ResizeEvent(time_s=1.0, target=ParallelConfig(dp=1)),
    ]
    out = sort_trace(evs)
    assert [e.time_s for e in out] == [1.0, 1.0, 5.0]
    assert isinstance(out[0], FailStopEvent)  # stable: original order at ties


def test_spot_trace_is_deterministic_and_typed():
    from repro.sim.volatility import spot_trace

    a = spot_trace(4 * 3600, 600, world_choices=(4, 8), seed=7)
    b = spot_trace(4 * 3600, 600, world_choices=(4, 8), seed=7)
    assert a == b and len(a) > 5
    kinds = {row[2] for row in a}
    assert kinds <= {"resize", "fail_stop"} and "fail_stop" in kinds
    for t, world, kind, warn in a:
        assert world in (4, 8)
        assert warn == (0.0 if kind == "fail_stop" else 120.0)


def test_events_from_trace_compresses_times_and_windows():
    from repro.configs import get_config
    from repro.elastic import events_from_trace

    cfg = get_config("qwen3-1.7b").reduced()
    rows = [(120.0, 4, "resize", 60.0), (240.0, 8, "fail_stop", 0.0)]
    evs = events_from_trace(rows, cfg, global_batch=8, seq_len=32, compress=60.0)
    assert evs[0].time_s == pytest.approx(2.0)
    assert evs[0].warning_s == pytest.approx(1.0)
    assert evs[0].target.world_size == 4
    assert isinstance(evs[1], FailStopEvent) and evs[1].target.world_size == 8


def test_events_from_trace_rejects_malformed_rows():
    from repro.configs import get_config
    from repro.core.errors import TraceError
    from repro.elastic import events_from_trace

    cfg = get_config("qwen3-1.7b").reduced()

    def convert(rows):
        return events_from_trace(rows, cfg, global_batch=8, seq_len=32)

    ok = [(0.0, 4, "resize", 60.0)]
    assert len(convert(ok)) == 1
    bad = [
        [(5.0,)],  # too short
        [(-1.0, 4)],  # negative timestamp
        [(float("nan"), 4)],  # non-finite timestamp
        [("soon", 4)],  # non-numeric timestamp
        [(0.0, 0)],  # non-positive world
        [(0.0, 2.5)],  # fractional world
        [(0.0, 4, "explode")],  # unknown kind
        [(0.0, 4, "resize", -3.0)],  # negative warning
        [(0.0, 4, "resize", float("nan"))],  # NaN warning
        [(0.0, 4, "resize", 60.0, (1,))],  # lost_ranks on a non-failstop row
        [(0.0, 4, "fail_stop", 0.0, 7)],  # uniterable lost_ranks
        [(0.0, 4, "fail_stop", 0.0, (-1,))],  # negative rank
    ]
    for rows in bad:
        with pytest.raises(TraceError):
            convert(rows)
    # inf warning is VALID: an unhurried resize
    evs = convert([(0.0, 4, "resize", float("inf"))])
    assert evs[0].warning_s == float("inf")
    # the row index lands in the message for fast triage
    with pytest.raises(TraceError, match="row 1"):
        convert([(0.0, 4), (1.0, 0)])


# ---------------------------------------------------------------------------
# Scheduler decision loop against an in-memory controller stand-in
# ---------------------------------------------------------------------------


class StubEstimator:
    def __init__(self, est):
        self.est = est

    def estimate(self, target):
        return self.est


class FakeController:
    """Minimal duck-typed LiveRController: a resize 'commits' after a fixed
    number of train steps; no JAX anywhere."""

    def __init__(self, steps_to_commit=3, ckpt_dir=None, step_sleep=0.0,
                 peer_ok=False):
        self.records: list[ReconfigRecord] = []
        self.iteration_times: list[float] = []
        self.ledger = GoodputLedger()
        self.step = 0
        self.ckpt_dir = ckpt_dir
        self.peer_ok = peer_ok  # stand-in for surviving replica coverage
        self.stream_k = 4
        self.world = SimpleNamespace(parallel=ParallelConfig(dp=2), timings={})
        self.steps_to_commit = steps_to_commit
        self.step_sleep = step_sleep
        self._gen = 0
        self._inflight = None  # (gen, target, mode, countdown)

    # -- controller surface the scheduler uses --------------------------
    def train_steps(self, n):
        for _ in range(n):
            t = time.perf_counter()
            if self.step_sleep:
                time.sleep(self.step_sleep)
            self.step += 1
            self.iteration_times.append(self.step_sleep or 1e-4)
            self.ledger.record(t, time.perf_counter(), "train",
                               self.world.parallel.world_size)
            if self._inflight is not None:
                gen, target, mode, left = self._inflight
                left -= 1
                if left <= 0:
                    self._commit(gen, target, mode, "committed")
                else:
                    self._inflight = (gen, target, mode, left)
        return [0.0] * n

    def _commit(self, gen, target, mode, outcome):
        self.records.append(
            ReconfigRecord(
                gen_id=gen, src=self.world.parallel.describe(),
                dst=target.describe(), outcome=outcome,
                mode="live_overlap" if mode == "stream" else "live",
            )
        )
        self.world = SimpleNamespace(parallel=target, timings={})
        self._inflight = None

    def request_resize(self, target, overlap=None, operating_point=None):
        assert self._inflight is None
        self._gen += 1
        self.last_operating_point = operating_point
        self._inflight = (self._gen, target, overlap, self.steps_to_commit)
        return self._gen

    def retarget_resize(self, target, overlap=None, operating_point=None):
        gen, old_target, mode, _ = self._inflight
        self.records.append(
            ReconfigRecord(
                gen_id=gen, src=self.world.parallel.describe(),
                dst=old_target.describe(), outcome="retargeted",
            )
        )
        self._inflight = None
        return self.request_resize(
            target, overlap=overlap, operating_point=operating_point
        )

    def cancel_resize(self, outcome=None):
        if outcome is not None and self._inflight is not None:
            gen, target, mode, _ = self._inflight
            self.records.append(
                ReconfigRecord(
                    gen_id=gen, src=self.world.parallel.describe(),
                    dst=target.describe(), outcome=outcome,
                )
            )
        self._inflight = None

    def escalate_commit(self):
        if self._inflight is None:
            return None
        gen, target, mode, _ = self._inflight
        self._commit(gen, target, "stop_copy", "fell_back")
        return self.records[-1]

    def wait_shadow_ready(self, timeout=None):
        pass

    def checkpoint_now(self):
        pass

    def peer_coverage(self, target, lost_ranks=(), devices_failed=True):
        return self.peer_ok, (1 << 20 if self.peer_ok else 0)

    def fail_stop_recover(self, target, devices_failed=True, lost_ranks=()):
        from repro.core.errors import RecoveryError

        self.last_devices_failed = devices_failed
        self.last_lost_ranks = tuple(lost_ranks)
        if self.peer_ok:
            mode, outcome = "peer_recover", "committed"
        elif self.ckpt_dir:
            mode, outcome = "fallback", "fell_back"
        else:
            raise RecoveryError("no peers, no parity, no ckpt_dir")
        rec = ReconfigRecord(
            gen_id=-1, src=self.world.parallel.describe(),
            dst=target.describe(), mode=mode, outcome=outcome,
            total_pause_s=0.01,
        )
        self.records.append(rec)
        self.world = SimpleNamespace(parallel=target, timings={})
        self._inflight = None
        return rec


def _sched(ctrl, **kw):
    # Protocol-level: the scheduler gets a WIRE endpoint, not the
    # controller — every interaction below serializes through
    # ``protocol.dumps``/``loads`` on both legs, so these tests prove the
    # decision loop works over an RPC boundary, not via attribute access.
    kw.setdefault("estimator", StubEstimator(_est(prepare=0.001, precopy=0.001,
                                                  pause=0.001)))
    kw.setdefault("tail_steps", 1)
    return ElasticScheduler(WireEndpoint(ControllerEndpoint(ctrl)), **kw)


def test_scheduler_traffic_is_pure_protocol():
    # every command and response of a full scheduler run crosses the wire
    # codec, and the scheduler module itself never references a controller
    ctrl = FakeController(steps_to_commit=3)
    wire = WireEndpoint(ControllerEndpoint(ctrl))
    rep = ElasticScheduler(
        wire, estimator=StubEstimator(_est(prepare=0.001, precopy=0.001,
                                           pause=0.001)), tail_steps=1
    ).run([ResizeEvent(time_s=0.0, target=ParallelConfig(dp=4), warning_s=1e9)])
    assert rep.outcomes[0].outcome == "committed"
    assert wire.commands > 0 and wire.bytes_tx > 0 and wire.bytes_rx > 0

    import inspect

    import repro.elastic.scheduler as S

    src = inspect.getsource(S)
    for forbidden in ("self.controller", ".train_steps(", ".request_resize(",
                      ".retarget_resize(", ".escalate_commit(",
                      ".fail_stop_recover(", ".world.parallel"):
        assert forbidden not in src, forbidden


def test_coalesce_and_retarget_bookkeeping():
    ctrl = FakeController(steps_to_commit=3)
    A, B = ParallelConfig(dp=4), ParallelConfig(dp=8)
    events = [
        ResizeEvent(time_s=0.0, target=A, warning_s=1e9),
        ResizeEvent(time_s=0.0, target=B, warning_s=1e9),  # supersedes A
        ResizeEvent(time_s=0.0, target=B, warning_s=1e9),  # duplicate: coalesce
    ]
    rep = _sched(ctrl).run(events)
    assert [o.outcome for o in rep.outcomes] == [
        "retargeted", "committed", "coalesced",
    ]
    assert rep.aborted == 0
    assert ctrl.world.parallel == B
    # the superseded event retired with a retargeted ReconfigRecord
    assert [r.outcome for r in ctrl.records] == ["retargeted", "committed"]


def test_resize_back_to_current_cancels_inflight():
    ctrl = FakeController(steps_to_commit=50)
    cur = ctrl.world.parallel
    events = [
        ResizeEvent(time_s=0.0, target=ParallelConfig(dp=4), warning_s=1e9),
        ResizeEvent(time_s=0.0, target=cur, warning_s=1e9),  # back to current
    ]
    rep = _sched(ctrl).run(events)
    assert [o.outcome for o in rep.outcomes] == ["retargeted", "committed"]
    assert [o.decision for o in rep.outcomes][1] == "cancel"
    assert ctrl.world.parallel == cur and ctrl._inflight is None


def test_checkpoint_rung_aborts_without_ckpt_dir():
    ctrl = FakeController()  # ckpt_dir=None
    rep = _sched(ctrl).run(
        [ResizeEvent(time_s=0.0, target=ParallelConfig(dp=4), warning_s=0.0)]
    )
    assert rep.outcomes[0].decision == "checkpoint"
    assert rep.outcomes[0].outcome == "aborted"
    assert rep.aborted == 1


def test_checkpoint_rung_restores_when_durable():
    ctrl = FakeController(ckpt_dir="/tmp/fake")
    target = ParallelConfig(dp=4)
    rep = _sched(ctrl).run(
        [ResizeEvent(time_s=0.0, target=target, warning_s=0.0)]
    )
    o = rep.outcomes[0]
    assert (o.decision, o.outcome, o.mode) == ("checkpoint", "fell_back", "fallback")
    assert ctrl.world.parallel == target
    # warned event: the devices are fine — warm pool entries stay valid
    assert ctrl.last_devices_failed is False


def test_zero_window_resize_uses_peer_rung_when_covered():
    # a warned shrink whose window fits nothing live: with peer coverage
    # the event commits through in-memory recovery — no durable save, no
    # fell_back, and the devices are NOT marked failed (warm pool valid)
    import dataclasses

    ctrl = FakeController(peer_ok=True)  # ckpt_dir=None: peers only
    est = dataclasses.replace(_est(), peer_ok=True)
    target = ParallelConfig(dp=1)
    rep = _sched(ctrl, estimator=StubEstimator(est)).run(
        [ResizeEvent(time_s=0.0, target=target, warning_s=0.0)]
    )
    o = rep.outcomes[0]
    assert (o.decision, o.outcome, o.mode) == (
        "peer_recover", "committed", "peer_recover",
    )
    assert ctrl.world.parallel == target
    assert ctrl.last_devices_failed is False
    # warned shrink: the lost set is the prefix complement of the target
    assert ctrl.last_lost_ranks == (1,)


def test_failstop_routes_to_peer_recovery_when_covered():
    ctrl = FakeController(steps_to_commit=50, peer_ok=True)
    target = ParallelConfig(dp=1)
    events = [
        ResizeEvent(time_s=0.0, target=ParallelConfig(dp=4), warning_s=1e9),
        FailStopEvent(time_s=0.0, target=target, lost_ranks=(1,)),
    ]
    rep = _sched(ctrl).run(events)
    assert [o.outcome for o in rep.outcomes] == ["retargeted", "committed"]
    assert rep.outcomes[1].decision == "peer_recover"
    assert ctrl.world.parallel == target
    assert ctrl._inflight is None
    # unannounced: devices ARE suspect even on the peer path
    assert ctrl.last_devices_failed is True
    assert ctrl.last_lost_ranks == (1,)


def test_failstop_routes_to_checkpoint_and_supersedes_pending():
    ctrl = FakeController(steps_to_commit=50, ckpt_dir="/tmp/fake")
    target = ParallelConfig(dp=1)
    events = [
        ResizeEvent(time_s=0.0, target=ParallelConfig(dp=4), warning_s=1e9),
        FailStopEvent(time_s=0.0, target=target),
    ]
    rep = _sched(ctrl).run(events)
    assert [o.outcome for o in rep.outcomes] == ["retargeted", "fell_back"]
    assert ctrl.world.parallel == target
    # the superseded reconfig was cancelled on the CONTROLLER too
    assert ctrl._inflight is None
    assert "retargeted" in [r.outcome for r in ctrl.records]
    # unannounced: devices are suspect — the controller must purge
    # overlapping warm-pool entries and skip pooling the dead world
    assert ctrl.last_devices_failed is True


def test_failstop_without_ckpt_cancels_inflight_and_aborts():
    # no ckpt_dir: the event aborts, but the in-flight reconfiguration must
    # still be cancelled — an orphaned shadow would commit to a target the
    # event stream already abandoned (and block the next request_resize)
    ctrl = FakeController(steps_to_commit=50)  # ckpt_dir=None
    events = [
        ResizeEvent(time_s=0.0, target=ParallelConfig(dp=4), warning_s=1e9),
        FailStopEvent(time_s=0.0, target=ParallelConfig(dp=1)),
        ResizeEvent(time_s=0.0, target=ParallelConfig(dp=8), warning_s=1e9),
    ]
    rep = _sched(ctrl).run(events)
    assert [o.outcome for o in rep.outcomes] == [
        "retargeted", "aborted", "committed",
    ]
    assert ctrl.world.parallel == ParallelConfig(dp=8)


def test_failstop_survivor_target_walks_down_to_feasible():
    from repro.configs import get_config

    ctrl = FakeController(ckpt_dir="/tmp/fake")
    # real config surface for the topology search
    ctrl.cfg = get_config("qwen3-1.7b").reduced()
    ctrl.global_batch, ctrl.seq_len = 8, 32
    ctrl.world = SimpleNamespace(parallel=ParallelConfig(dp=2, tp=2), timings={})
    # 4 ranks, 1 lost -> survivors=3, infeasible for batch 8 -> walk to 2
    rep = _sched(ctrl).run([FailStopEvent(time_s=0.0, lost_ranks=(3,))])
    o = rep.outcomes[0]
    assert o.outcome == "fell_back"
    assert ctrl.world.parallel.world_size == 2


def test_deadline_escalation_falls_back_to_stop_copy():
    # stream is chosen (the estimate fits), but the fake commit needs far
    # more steps than the window covers -> the scheduler must escalate
    # mid-stream instead of blowing the deadline
    ctrl = FakeController(steps_to_commit=10_000, step_sleep=0.002)
    est = ReconfigEstimate(
        prepare_s=0.001, precopy_s=0.004, stream_pause_s=0.001,
        stop_copy_pause_s=0.001, plan_bytes=1 << 20, rounds=4, step_s=0.002,
    )
    rep = ElasticScheduler(
        WireEndpoint(ControllerEndpoint(ctrl)),
        estimator=StubEstimator(est), tail_steps=0, max_steps=500,
    ).run([ResizeEvent(time_s=0.0, target=ParallelConfig(dp=4), warning_s=0.05)])
    o = rep.outcomes[0]
    assert o.decision == "stream"
    assert o.outcome == "fell_back"
    assert ctrl.world.parallel == ParallelConfig(dp=4)


# ---------------------------------------------------------------------------
# Live end-to-end: parity + mid-stream retarget with reuse (8 host devices)
# ---------------------------------------------------------------------------


def test_scheduler_resize_byte_identical_to_direct(subproc):
    """A scheduler-driven resize must leave params byte-identical to a
    direct ``request_resize`` of the same target at the same step."""
    out = subproc(
        """
        import numpy as np, jax
        import jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.events import ResizeEvent
        from repro.elastic import ElasticScheduler
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)
        target = ParallelConfig(dp=1, tp=4)

        def make():
            return LiveRController(cfg, ParallelConfig(dp=2, tp=2), opt,
                                   seq_len=32, global_batch=8, seed=0)

        # A: one-event trace through the scheduler (deterministic replay)
        a = make()
        sched = ElasticScheduler(a, sync_prepare=True,
                                 mode_override="stop_copy", tail_steps=3)
        rep = sched.run([ResizeEvent(time_s=0.0, target=target, warning_s=1e9)])
        assert rep.aborted == 0
        assert rep.outcomes[0].outcome == "committed", rep.outcomes[0]

        # B: direct request_resize, same commit step
        b = make()
        b.request_resize(target)
        b.wait_shadow_ready()
        b.train_steps(1 + 3)  # commit lands at the first boundary, then tail
        assert b.records and b.records[0].outcome == "committed"
        assert a.step == b.step, (a.step, b.step)

        pa, pb = a.gathered_params(), b.gathered_params()
        jtu.tree_map(np.testing.assert_array_equal, pa, pb)
        print("SCHED_PARITY_OK steps=%d" % a.step)
        """,
        n_devices=8,
    )
    assert "SCHED_PARITY_OK" in out


def test_midstream_retarget_reuses_stream_and_matches_oracle(subproc):
    """A second event mid-stream retargets without restarting the stream
    from scratch: the adopted round makes the commit land at the SAME step
    as a direct resize triggered at the first event's step (without reuse
    it would land one boundary later), the streamed commit state
    byte-matches the SimExecutor oracle applied to the same consistent
    cut, and post-commit params are byte-identical to the direct run.
    Adoption itself must batch every mismatched-layout carry into a single
    ``device_put`` dispatch (and zero when all layouts agree)."""
    out = subproc(
        """
        import numpy as np, jax
        import jax.tree_util as jtu
        import repro.core.controller as C
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.reshard import named_state_leaves, plan_state_transfer
        from repro.core.resource_view import view_of
        from repro.core.streaming import (
            allocate_destination, execute_plan, materialize_rank,
        )
        from repro.optim import AdamWConfig

        # count device_put dispatches inside adopt(): relayout of N
        # mismatched carries must cost at most ONE batched call
        import repro.reshard.overlap as OV
        _orig_adopt = OV.OverlapSession.adopt
        adopt_put_calls = []
        def counting_adopt(self, *a, **kw):
            orig_put, n = jax.device_put, [0]
            def put(*aa, **kk):
                n[0] += 1
                return orig_put(*aa, **kk)
            jax.device_put = put
            try:
                out = _orig_adopt(self, *a, **kw)
            finally:
                jax.device_put = orig_put
            adopt_put_calls.append(n[0])
            return out
        OV.OverlapSession.adopt = counting_adopt

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)
        SRC = ParallelConfig(dp=2, tp=2)
        T1, T2 = ParallelConfig(dp=2, tp=4), ParallelConfig(dp=1, tp=4)

        def make():
            return LiveRController(cfg, SRC, opt, seq_len=32, global_batch=8,
                                   seed=0, overlap="stream", stream_k=1,
                                   sync_compile=True)

        # --- A: resize to T1, stream one round, retarget to T2 ----------
        a = make()
        a.train_steps(2)
        a.request_resize(T1); a.wait_shadow_ready()
        a.train_steps(1)  # boundary: session starts, streams round 1
        assert a._session is not None and len(a._session.streamed_at) == 1
        a.retarget_resize(T2); a.wait_shadow_ready()
        assert a.records[-1].outcome == "retargeted"

        cut, streamed = {}, {}
        orig_rebuild = C.rebuild_state
        def spy(named, p, o, extras):
            if not streamed:
                streamed.update({k: np.asarray(jax.device_get(v))
                                 for k, v in named.items()})
            return orig_rebuild(named, p, o, extras)
        C.rebuild_state = spy
        guard = 0
        while not any(r.outcome == "committed" for r in a.records):
            if a._commit_armed and not cut:
                named, _ = named_state_leaves(a.params, a.opt_state)
                cut.update({k: np.asarray(jax.device_get(v))
                            for k, v in named.items()})
            a.train_steps(1)
            guard += 1
            assert guard < 50, "commit never happened"
        C.rebuild_state = orig_rebuild
        commit_step_a = a.step
        rec = [r for r in a.records if r.outcome == "committed"][0]
        assert rec.reused_layers >= 1, rec  # the stream did NOT restart
        assert cut and streamed

        # --- SimExecutor byte oracle on the same consistent cut ---------
        specs, plan = plan_state_transfer(cfg, SRC, T2)
        src = {r: materialize_rank(specs, SRC, r, cut)
               for r in range(SRC.world_size)}
        dst = {r: allocate_destination(specs, T2, r)
               for r in range(T2.world_size)}
        execute_plan(plan, src, dst, staging_bytes=1 << 20)
        for s in specs:
            glob = np.zeros(s.shape, np.dtype(s.dtype))
            for r in range(T2.world_size):
                v = view_of(s, T2, r)
                sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
                glob[sl] = dst[r].shards[s.name]
            np.testing.assert_array_equal(streamed[s.name], glob, err_msg=s.name)

        # --- B: direct resize to T2 at the same trigger step ------------
        b = make()
        b.train_steps(2)
        b.request_resize(T2); b.wait_shadow_ready()
        guard = 0
        while not any(r.outcome == "committed" for r in b.records):
            b.train_steps(1)
            guard += 1
            assert guard < 50
        # reuse credit == the round spent on T1: same commit step as if T2
        # had been the target all along
        assert b.step == commit_step_a, (b.step, commit_step_a)
        a.train_steps(3); b.train_steps(3)
        jtu.tree_map(np.testing.assert_array_equal,
                     a.gathered_params(), b.gathered_params())
        # adopt ran exactly once, with at most one (batched) device_put —
        # parity above proves the batched relayout moved the right bytes
        assert len(adopt_put_calls) == 1, adopt_put_calls
        assert adopt_put_calls[0] <= 1, adopt_put_calls
        print("RETARGET_OK reused=%d commit_step=%d adopt_puts=%d" %
              (rec.reused_layers, commit_step_a, adopt_put_calls[0]))
        """,
        n_devices=8,
    )
    assert "RETARGET_OK" in out
