"""Topology search (paper §2.3(D) integration: search chooses the target,
LiveR executes the transition)."""

from __future__ import annotations

import pytest

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.topology_search import best_target, feasible_configs, search


def test_feasible_configs_respect_divisibility():
    cfg = get_config("qwen3-1.7b")  # 28 periods
    cands = feasible_configs(cfg, world=16, global_batch=32)
    assert cands
    for c in cands:
        assert c.world_size == 16
        assert 32 % c.dp == 0
        assert 28 % c.pp == 0


def test_search_returns_ranked_candidates():
    cfg = get_config("qwen3-1.7b")
    cands = search(cfg, world=16, global_batch=32, seq_len=1024)
    assert cands == sorted(cands, key=lambda c: c.score)
    assert all(c.mem_per_chip <= 16 * 1024**3 for c in cands)


def test_memory_filter_excludes_undersharded():
    """A 34B model cannot run dp-only on 16 v5e chips (10B/param state)."""
    cfg = get_config("chameleon-34b")
    cands = search(cfg, world=16, global_batch=32, seq_len=1024)
    for c in cands:
        assert c.parallel.tp * c.parallel.pp > 1, c


def test_transition_aware_search_prefers_nearby_layouts():
    """With transition cost dominating, the search must keep the current
    layout (zero bytes moved); with zero weight it ranks purely by speed."""
    cfg = get_config("qwen3-1.7b").reduced()
    cur = ParallelConfig(dp=1, tp=4)
    weighted = search(cfg, 4, 16, 128, current=cur, transition_weight=1.0)
    assert weighted
    assert weighted[0].parallel == cur
    assert weighted[0].transition_bytes == 0
    # other candidates move bytes
    others = [c for c in weighted if c.parallel != cur]
    assert all(c.transition_bytes > 0 for c in others)


def test_best_target_integration_shape():
    cfg = get_config("mixtral-8x7b")
    t = best_target(cfg, world=64, global_batch=256, seq_len=4096)
    assert t.world_size == 64


def test_no_feasible_raises():
    # world=13: dp=13 doesn't divide batch 16; pp=13 > max_pp and not a
    # period divisor; tp=13 divides neither d_ff nor heads*head_dim
    cfg = get_config("qwen3-1.7b")
    with pytest.raises(ValueError):
        best_target(cfg, world=13, global_batch=16, seq_len=128)
