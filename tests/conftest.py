"""Shared test helpers.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — in-process
tests see the real single CPU device. Tests that need a multi-device mesh
spawn a subprocess via ``run_with_devices`` so the 512-device dry-run
environment never leaks into the default test session.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import types

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

# Parity tolerance for cross-mesh training comparisons (test_elastic_e2e,
# bench_parity). The reshard byte-movement itself is exactly lossless —
# property-tested BIT-EXACT in test_reshard_engine/test_streaming, and
# the subtle one-step-stale-layer class (divergence ~lr, which a loose
# float tolerance could miss) is guarded bit-exactly by
# test_dirty_resync_is_byte_exact. What this tolerance covers is training
# *after* the switch: a different mesh factorization changes XLA's
# reduction order in matmul/collective lowerings, giving ~1-ulp gradient
# differences, and Adam's m̂/(√v̂+ε) normalization amplifies any
# sign-flip of a tiny-magnitude update to a full ±lr step. Observed drift
# is ≈2·lr·steps in the worst case (lr=1e-3, ~10 steps → ~2e-2); gross
# resharding bugs (wrong bytes) show up at O(0.1–1) or NaN, so 1e-2
# separates reduction-order noise from movement failures while the
# bit-exact tests above cover everything smaller.
RESHAPE_PARITY_TOL = 1e-2


# ---------------------------------------------------------------------------
# hypothesis fallback: this container cannot pip-install hypothesis, and a
# bare `from hypothesis import given` breaks collection of three modules.
# When the real package is absent we register a minimal deterministic stand-in
# that degrades each @given property to a seeded sample sweep (same API
# surface the tests use: given/settings/strategies.{integers,floats,
# sampled_from,builds,lists,data}). With hypothesis installed this block
# is inert.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - exercised implicitly by collection
    import hypothesis  # noqa: F401
except ImportError:

    class _Strategy:
        def sample(self, rng):
            raise NotImplementedError

    class _SampledFrom(_Strategy):
        def __init__(self, choices):
            self.choices = list(choices)

        def sample(self, rng):
            return self.choices[int(rng.integers(0, len(self.choices)))]

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _Builds(_Strategy):
        def __init__(self, target, **kw):
            self.target, self.kw = target, kw

        def sample(self, rng):
            return self.target(**{k: s.sample(rng) for k, s in self.kw.items()})

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=10, unique=False):
            self.elements = elements
            self.min_size, self.max_size, self.unique = min_size, max_size, unique

        def sample(self, rng):
            n = int(rng.integers(self.min_size, self.max_size + 1))
            out: list = []
            attempts = 0
            while len(out) < n and attempts < 1000:
                v = self.elements.sample(rng)
                attempts += 1
                if self.unique and v in out:
                    continue
                out.append(v)
            assert len(out) == n, "fallback lists(): could not draw enough uniques"
            return out

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.sample(self._rng)

    class _Data(_Strategy):
        def sample(self, rng):
            return _DataObject(rng)

    def _settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def _given(**strategies):
        def deco(fn):
            # NB: no functools.wraps — pytest would follow __wrapped__ and
            # treat the drawn parameters as fixtures.
            def wrapper(*args, **kw):
                import numpy as _np

                n = getattr(wrapper, "_fallback_max_examples", 20)
                rng = _np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **kw, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.sampled_from = _SampledFrom
    _st.integers = _Integers
    _st.floats = _Floats
    _st.builds = _Builds
    _st.lists = _Lists
    _st.data = _Data
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_fallback__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900) -> str:
    """Run a python snippet in a subprocess with N host platform devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={r.returncode})\nstdout:\n{r.stdout[-3000:]}"
            f"\nstderr:\n{r.stderr[-3000:]}"
        )
    return r.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
