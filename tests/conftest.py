"""Shared test helpers.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — in-process
tests see the real single CPU device. Tests that need a multi-device mesh
spawn a subprocess via ``run_with_devices`` so the 512-device dry-run
environment never leaks into the default test session.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 900) -> str:
    """Run a python snippet in a subprocess with N host platform devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={r.returncode})\nstdout:\n{r.stdout[-3000:]}"
            f"\nstderr:\n{r.stderr[-3000:]}"
        )
    return r.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
