"""Control-plane wire protocol (DESIGN.md §17): every message type
round-trips encode -> JSON -> decode bit-identically, the golden file
freezes the v1 wire layout, and the versioning rule (additive = ignore
unknown fields, breaking = reject newer versions) is enforced.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

import pytest

from repro.configs.base import ParallelConfig
from repro.core.controller import ReconfigRecord
from repro.core.errors import ProtocolError
from repro.elastic import protocol as p

GOLDEN = pathlib.Path(__file__).parent / "golden" / "protocol_v1.jsonl"


# ---------------------------------------------------------------------------
# Round-trip and canonical encoding
# ---------------------------------------------------------------------------


def test_every_message_round_trips_bit_identically():
    msgs = p.golden_messages()
    assert msgs, "golden corpus is empty"
    for msg in msgs:
        wire = p.dumps(msg)
        back = p.loads(wire)
        assert back == msg, f"{type(msg).__name__} changed across the wire"
        assert type(back) is type(msg)
        # canonical form is a fixed point: re-encoding is byte-identical
        assert p.dumps(back) == wire


def test_golden_corpus_covers_every_registered_type():
    covered = {type(m) for m in p.golden_messages()}
    registered = set(p._REGISTRY.values())
    missing = {c.__name__ for c in registered - covered}
    assert not missing, f"golden corpus misses wire types: {sorted(missing)}"


def test_golden_file_matches_current_encoder():
    """The committed golden file IS the v1 wire format. If this fails the
    change is breaking: bump PROTOCOL_VERSION and freeze a new golden —
    never regenerate over the old one (DESIGN.md §17 versioning rule)."""
    assert GOLDEN.exists(), (
        "regenerate with: PYTHONPATH=src python -m repro.elastic.protocol "
        "tests/golden/protocol_v1.jsonl"
    )
    want = [p.dumps(m) for m in p.golden_messages()]
    got = GOLDEN.read_text().splitlines()
    assert got == want
    # and every golden line decodes to a message that re-encodes to itself
    for line in got:
        assert p.dumps(p.loads(line)) == line


def test_envelope_carries_version_and_type():
    obj = p.encode(p.QueryStatus())
    assert obj["v"] == p.PROTOCOL_VERSION
    assert obj["type"] == "query_status"
    # dumps is canonical: sorted keys, no whitespace
    text = p.dumps(p.QueryStatus())
    assert text == json.dumps(obj, sort_keys=True, separators=(",", ":"))


def test_non_finite_floats_survive_json():
    est = dataclasses.replace(_some_estimate(), precopy_s=float("inf"))
    msg = p.EstimateResponse(estimate=est)
    back = p.loads(p.dumps(msg))
    assert back.estimate.precopy_s == float("inf")
    # strict JSON: the wire text must not contain bare Infinity/NaN tokens
    assert "Infinity" not in p.dumps(msg) and "NaN" not in p.dumps(msg)


def _some_estimate():
    return p.ReconfigEstimate(
        prepare_s=1.0, precopy_s=2.0, stream_pause_s=0.5,
        stop_copy_pause_s=1.5, plan_bytes=1 << 20, rounds=3, step_s=0.1,
    )


def test_parallel_config_round_trips_as_axis_dict():
    msg = p.RequestResize(target=ParallelConfig(dp=2, pp=2, tp=4))
    obj = p.encode(msg)
    assert obj["target"] == {"dp": 2, "ep": 1, "pp": 2, "tp": 4}
    back = p.decode(obj)
    assert back.target == ParallelConfig(dp=2, pp=2, tp=4)
    assert isinstance(back.target, ParallelConfig)


# ---------------------------------------------------------------------------
# Versioning rule
# ---------------------------------------------------------------------------


def test_newer_major_version_is_rejected():
    obj = p.encode(p.QueryStatus())
    obj["v"] = p.PROTOCOL_VERSION + 1
    with pytest.raises(ProtocolError):
        p.decode(obj)


def test_unknown_fields_are_ignored_additive_evolution():
    # an older decoder must accept messages from a newer additive peer
    obj = p.encode(p.TrainSteps(n=7))
    obj["some_future_field"] = {"nested": True}
    assert p.decode(obj) == p.TrainSteps(n=7)


def test_unknown_type_and_missing_fields_raise_typed_errors():
    with pytest.raises(ProtocolError):
        p.decode({"v": 1, "type": "no_such_verb"})
    with pytest.raises(ProtocolError):
        p.decode({"v": 1})  # no type tag at all
    with pytest.raises(ProtocolError):
        # required field (target has no default) absent
        p.decode({"v": 1, "type": "request_resize"})
    with pytest.raises(ProtocolError):
        p.loads("not json at all {{{")


def test_missing_optional_fields_take_defaults():
    # a v1 peer that predates StepResult.clock_s still decodes
    obj = p.encode(p.StepResult(steps=3))
    del obj["clock_s"]
    back = p.decode(obj)
    assert back == p.StepResult(steps=3, clock_s=-1.0)


# ---------------------------------------------------------------------------
# ServeEndpoint: the serving controller behind the same protocol
# ---------------------------------------------------------------------------


class FakeServeController:
    """Duck-typed LiveServeController surface the adapter touches."""

    def __init__(self):
        from types import SimpleNamespace

        self.gen_id = 0
        self.records = []
        self.active = SimpleNamespace(parallel=ParallelConfig(dp=2, tp=2))
        self._pending = None

    def request_resize(self, target):
        self._pending = target

    def _discard_pending(self):
        self._pending = None

    @property
    def resize_pending(self):
        return self._pending is not None


def test_serve_endpoint_answers_resize_subset_over_the_wire():
    from repro.elastic import ServeEndpoint, WireEndpoint

    ctrl = FakeServeController()
    ep = WireEndpoint(ServeEndpoint(ctrl))
    assert ep.kind == "serve"

    r = ep.handle(p.RequestResize(target=ParallelConfig(dp=4)))
    assert isinstance(r, p.ResizeStarted) and r.gen_id == 1
    status = ep.handle(p.QueryStatus())
    assert status.kind == "serve" and status.reconfig_pending
    assert status.world_size == 4  # dp2 x tp2 active world

    r = ep.handle(p.RetargetResize(target=ParallelConfig(dp=8)))
    assert isinstance(r, p.ResizeStarted)
    assert ctrl._pending == ParallelConfig(dp=8)

    assert ep.handle(p.CancelResize()).ok
    assert not ep.handle(p.QueryStatus()).reconfig_pending

    recs = ep.handle(p.QueryRecords(since=0))
    assert recs.total == 0 and recs.records == ()

    # serving has no train loop: the verb is unsupported, not a crash
    err = ep.handle(p.TrainSteps(n=1))
    assert isinstance(err, p.ErrorResponse) and err.kind == "unsupported"


# ---------------------------------------------------------------------------
# RecordView bridge from the controller's native record type
# ---------------------------------------------------------------------------


def test_record_view_from_real_reconfig_record():
    rec = ReconfigRecord(
        gen_id=3, src="dp2", dst="dp4", mode="live_overlap",
        outcome="committed", total_pause_s=0.25, reused_layers=5,
    )
    view = p.RecordView.from_record(rec)
    assert (view.gen_id, view.src, view.dst) == (3, "dp2", "dp4")
    assert view.outcome == "committed" and view.reused_layers == 5
    assert view.total_pause_s == pytest.approx(0.25)
    wrapped = p.RecordsResponse(records=(view,), total=1)
    back = p.loads(p.dumps(wrapped))
    assert back.records[0] == view
