"""Peer-redundant fail-stop recovery (DESIGN.md §15).

Unit tests for the redundancy layer (survivor sets, lost-cell plan
classification, donor balancing, the XOR parity store) run in-process on
bare CPU; the end-to-end proofs — DP-donor recovery bitwise-equal to an
uninterrupted run, dp=1 spare-shard reconstruction, and the fault matrix
(idle / mid-stream / mid-commit all end committed) — spawn the usual
8-host-device subprocess.
"""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.errors import RecoveryError
from repro.core.resource_view import build_tensor_specs
from repro.core.reshard import plan_state_transfer
from repro.elastic.redundancy import (
    ParityStore,
    RedundancyMap,
    _shard_groups,
    balance_donors,
    heal_plan,
    survivors_for,
)

CFG = get_config("qwen3-1.7b").reduced()


# ---------------------------------------------------------------------------
# Survivor sets and plan classification
# ---------------------------------------------------------------------------


def test_survivors_for_explicit_and_prefix_default():
    src = ParallelConfig(dp=2, tp=2)
    # explicit lost set wins
    assert survivors_for(src, lost_ranks=(1, 3)) == frozenset({0, 2})
    # prefix-allocation default: the ranks beyond the target world died
    assert survivors_for(
        src, target=ParallelConfig(dp=1, tp=2)
    ) == frozenset({0, 1})
    # warned event past its window: the machines are up — everyone survives
    assert survivors_for(
        src, target=ParallelConfig(dp=1, tp=2), devices_failed=False
    ) == frozenset({0, 1, 2, 3})


def test_survivor_constrained_plan_never_reads_dead_ranks():
    src, dst = ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)
    survivors = survivors_for(src, target=dst)
    _, plan = plan_state_transfer(CFG, src, dst, allowed_src=survivors)
    dead = frozenset(range(src.world_size)) - survivors
    assert plan.tasks, "empty plan"
    for t in plan.tasks:
        if t.kind != "lost":
            assert t.src_rank not in dead, (t.tensor, t.src_rank)
    # dp=2: the surviving replica covers everything — nothing is lost
    assert plan.lost_bytes == 0


def test_dp1_shrink_classifies_dead_shards_as_lost():
    src, dst = ParallelConfig(dp=1, tp=4), ParallelConfig(dp=1, tp=2)
    survivors = survivors_for(src, target=dst)  # ranks 2, 3 died
    _, plan = plan_state_transfer(CFG, src, dst, allowed_src=survivors)
    lost = plan.lost_tasks()
    assert lost and plan.lost_bytes > 0
    for t in lost:
        assert t.kind == "lost" and t.src_rank == -1
    # without the constraint the same transfer plans clean
    _, free = plan_state_transfer(CFG, src, dst)
    assert free.lost_bytes == 0


def test_engine_refuses_to_execute_lost_cells():
    from repro.reshard.engine import ReshardEngine

    src, dst = ParallelConfig(dp=1, tp=4), ParallelConfig(dp=1, tp=2)
    _, plan = plan_state_transfer(
        CFG, src, dst, allowed_src=survivors_for(src, target=dst)
    )

    class NullExecutor:
        executed_bytes = 0

        def begin_layer(self, layer):
            pass

        def apply(self, chunk):
            pass

        def end_layer(self, layer):
            pass

    with pytest.raises(RecoveryError):
        ReshardEngine(plan, NullExecutor()).run()


# ---------------------------------------------------------------------------
# Redundancy map and donor balancing
# ---------------------------------------------------------------------------


def test_redundancy_map_dp_replicas_cover_the_loss():
    specs = build_tensor_specs(CFG, include_optimizer=True, zero_sharding=False)
    src = ParallelConfig(dp=2, tp=2)
    rmap = RedundancyMap.build(specs, src, survivors_for(src, lost_ranks=(2, 3)))
    assert rmap.complete and rmap.uncovered_bytes == 0
    load = rmap.donor_load()
    assert set(load) <= {0, 1} and all(v > 0 for v in load.values())


def test_redundancy_map_reports_holes_without_replicas():
    specs = build_tensor_specs(CFG, include_optimizer=True, zero_sharding=False)
    src = ParallelConfig(dp=1, tp=4)
    rmap = RedundancyMap.build(specs, src, survivors_for(src, lost_ranks=(3,)))
    assert not rmap.complete
    holes = rmap.uncovered()
    assert holes and rmap.uncovered_bytes == sum(c.nbytes for c in holes)
    for c in holes:
        assert c.owners == (3,) and c.donors == ()


def test_balance_donors_preserves_bytes_and_uses_survivors_only():
    src, dst = ParallelConfig(dp=4, tp=1), ParallelConfig(dp=2, tp=1)
    survivors = survivors_for(src, target=dst)
    specs, plan = plan_state_transfer(CFG, src, dst, allowed_src=survivors)
    balanced = balance_donors(plan, specs, survivors)
    assert balanced.network_bytes == plan.network_bytes
    assert balanced.local_bytes == plan.local_bytes
    assert len(balanced.tasks) == len(plan.tasks)
    for t in balanced.tasks:
        if t.kind == "remote":
            assert t.src_rank in survivors
    # least-loaded greedy: no donor carries the whole remote stream when
    # more than one surviving replica could serve it
    loads: dict[int, int] = {}
    for t in balanced.tasks:
        if t.kind == "remote":
            loads[t.src_rank] = loads.get(t.src_rank, 0) + t.nbytes
    if len(loads) > 1:
        assert max(loads.values()) < balanced.network_bytes


# ---------------------------------------------------------------------------
# XOR parity store (spare-shard scheme for dp=1)
# ---------------------------------------------------------------------------


def _named_state(specs, seed=0):
    rng = np.random.default_rng(seed)
    return {
        s.name: rng.standard_normal(s.shape).astype(np.dtype(s.dtype))
        for s in specs
    }


def test_parity_repairs_a_dead_group_bitwise():
    specs = build_tensor_specs(CFG, include_optimizer=True, zero_sharding=False)
    cfg = ParallelConfig(dp=1, tp=2)
    named = _named_state(specs)
    ref = {k: v.copy() for k, v in named.items()}
    store = ParityStore(specs, cfg)
    store.refresh(named, step=5)
    assert store.covers(5) and not store.covers(6)

    # poison every region rank 1 exclusively owned: repair must not read it
    poisoned = {}
    for s in specs:
        arr = named[s.name].copy()
        for bounds, owners in _shard_groups(s, cfg):
            if owners == [1]:
                sl = tuple(slice(lo, hi) for lo, hi in bounds)
                arr[sl] = -777.0
        poisoned[s.name] = arr

    patched, repaired = store.repair(poisoned, frozenset({1}), step=5)
    assert repaired > 0
    for name, want in ref.items():
        np.testing.assert_array_equal(patched[name], want, err_msg=name)


def test_parity_stale_and_double_loss_raise_typed_errors():
    specs = build_tensor_specs(CFG, include_optimizer=True, zero_sharding=False)
    cfg = ParallelConfig(dp=1, tp=4)
    named = _named_state(specs)
    store = ParityStore(specs, cfg)
    store.refresh(named, step=3)
    with pytest.raises(RecoveryError):  # stale: survivors moved on
        store.repair(named, frozenset({3}), step=4)
    with pytest.raises(RecoveryError):  # two groups of one tensor died
        store.repair(named, frozenset({2, 3}), step=3)


def test_heal_plan_turns_lost_cells_into_remote_cells():
    src, dst = ParallelConfig(dp=1, tp=4), ParallelConfig(dp=1, tp=2)
    specs, plan = plan_state_transfer(
        CFG, src, dst, allowed_src=survivors_for(src, target=dst)
    )
    lost_before = plan.lost_bytes
    assert lost_before > 0
    healed, parity_bytes = heal_plan(plan, specs)
    assert parity_bytes == lost_before
    assert healed.lost_bytes == 0 and not healed.lost_tasks()
    assert healed.network_bytes == plan.network_bytes + lost_before


# ---------------------------------------------------------------------------
# Satellites: inf windows, async checkpoint error surfacing, traces
# ---------------------------------------------------------------------------


def test_event_outcome_serializes_infinite_windows_as_inf():
    from repro.elastic import EventOutcome

    o = EventOutcome(
        index=0, kind="resize", time_s=1.0, window_s=float("inf"), target="dp2"
    )
    d = o.to_dict()
    assert d["window_s"] == "inf"
    payload = json.dumps(d)  # must be standard JSON (no bare Infinity)
    assert "Infinity" not in payload
    assert json.loads(payload)["window_s"] == "inf"


def test_async_checkpointer_surfaces_background_write_errors(tmp_path):
    from repro.checkpoint.ckpt import AsyncCheckpointer

    blocker = tmp_path / "not_a_dir"
    blocker.write_text("occupied")  # makedirs inside _write will fail
    ckpt = AsyncCheckpointer(str(blocker))
    ckpt.save(1, {"w": np.ones(4, np.float32)})
    with pytest.raises(RuntimeError, match="async checkpoint write"):
        ckpt.wait()
    # the error is consumed: the checkpointer stays usable afterwards
    ckpt.wait()
    ok = AsyncCheckpointer(str(tmp_path / "ckpts"))
    ok.save(2, {"w": np.ones(4, np.float32)})
    ok.wait()
    assert os.path.isdir(tmp_path / "ckpts" / "step_00000002")


def test_spot_trace_emit_lost_names_dead_ranks():
    from repro.elastic import events_from_trace
    from repro.sim.volatility import spot_trace

    a = spot_trace(4 * 3600, 600, world_choices=(4, 8), seed=7, emit_lost=True)
    b = spot_trace(4 * 3600, 600, world_choices=(4, 8), seed=7, emit_lost=True)
    assert a == b
    failstops = [row for row in a if row[2] == "fail_stop"]
    assert failstops
    saw_lost = False
    for row in failstops:
        if len(row) > 4:
            saw_lost = True
            world = row[1]
            assert all(r >= world for r in row[4])  # survivors keep the prefix
    assert saw_lost
    # default shape unchanged: 4-tuples only
    for row in spot_trace(4 * 3600, 600, world_choices=(4, 8), seed=7):
        assert len(row) == 4

    evs = events_from_trace(
        [(60.0, 4, "fail_stop", 0.0, (5, 7))], CFG,
        global_batch=8, seq_len=32,
    )
    assert evs[0].lost_ranks == (5, 7)


# ---------------------------------------------------------------------------
# End-to-end proofs (8 host devices, subprocess)
# ---------------------------------------------------------------------------


def test_dp_donor_recovery_bitwise_equal_to_uninterrupted(subproc):
    """Fail-stop with surviving DP replicas: the recovered state on the
    survivor topology is bitwise the uninterrupted run's state at the same
    step — no rollback, no checkpoint, no tolerance."""
    out = subproc(
        """
        import numpy as np, jax
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.reshard import named_state_leaves
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)

        def make():
            return LiveRController(cfg, ParallelConfig(dp=2, tp=2), opt,
                                   seq_len=16, global_batch=4, seed=0,
                                   ckpt_dir=None)

        a = make()
        a.train_steps(6)
        rec = a.fail_stop_recover(ParallelConfig(dp=1, tp=2))
        assert rec.mode == "peer_recover" and rec.outcome == "committed"
        assert a.step == 6, a.step

        b = make()
        b.train_steps(6)

        na, _ = named_state_leaves(a.params, a.opt_state)
        nb, _ = named_state_leaves(b.params, b.opt_state)
        assert set(na) == set(nb)
        for name in sorted(na):
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(na[name])),
                np.asarray(jax.device_get(nb[name])), err_msg=name)
        a.train_steps(2)  # liveness on the survivor world
        print("BITWISE_OK leaves=%d" % len(na))
        """,
        n_devices=8,
    )
    assert "BITWISE_OK" in out


def test_dp1_parity_recovery_bitwise(subproc):
    """dp=1 world, one tp-shard owner dies: its bytes exist nowhere else —
    recovery reconstructs them from the idle-boundary XOR parity word,
    bitwise. The dead region is poisoned first, so any read of the dead
    rank's live bytes (instead of the parity path) fails the test."""
    out = subproc(
        """
        import numpy as np, jax
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.reshard import named_state_leaves, rebuild_state
        from repro.core.resource_view import build_tensor_specs
        from repro.elastic.redundancy import _shard_groups
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)
        SRC = ParallelConfig(dp=1, tp=2)

        def make(parity):
            return LiveRController(cfg, SRC, opt, seq_len=16, global_batch=4,
                                   seed=0, ckpt_dir=None,
                                   parity_every=1 if parity else 0)

        a = make(parity=True)
        a.train_steps(5)   # parity refreshed at every boundary; last at 5

        b = make(parity=False)
        b.train_steps(5)
        ref, _ = named_state_leaves(b.params, b.opt_state)
        ref = {k: np.asarray(jax.device_get(v)) for k, v in ref.items()}

        # poison rank 1's exclusive regions AFTER the parity snapshot:
        # recovery must rebuild them from parity, never read them
        specs = build_tensor_specs(cfg, include_optimizer=True,
                                   zero_sharding=False)
        named, extras = named_state_leaves(a.params, a.opt_state)
        poisoned = {}
        for s in specs:
            arr = named[s.name]
            for bounds, owners in _shard_groups(s, SRC):
                if owners == [1]:
                    sl = tuple(slice(lo, hi) for lo, hi in bounds)
                    arr = arr.at[sl].set(-777.0)
            poisoned[s.name] = arr
        a.params, a.opt_state = rebuild_state(
            poisoned, a.params, a.opt_state, extras)

        rec = a.fail_stop_recover(ParallelConfig(dp=1, tp=1), lost_ranks=(1,))
        assert rec.mode == "peer_recover", rec.mode
        assert rec.parity_bytes > 0, "no parity reconstruction happened"
        assert a.step == 5, a.step

        got, _ = named_state_leaves(a.params, a.opt_state)
        for name in sorted(ref):
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(got[name])), ref[name],
                err_msg=name)
        a.train_steps(1)
        print("PARITY_OK repaired=%d" % rec.parity_bytes)
        """,
        n_devices=8,
    )
    assert "PARITY_OK" in out


def test_fault_matrix_every_phase_ends_committed(subproc):
    """Kill devices at an idle boundary, mid-stream and mid-commit: every
    phase must end in a committed peer recovery and live training."""
    out = subproc(
        """
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.elastic import FaultInjector
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        for phase in ("idle", "mid_stream", "mid_commit"):
            ctrl = LiveRController(
                cfg, ParallelConfig(dp=2, tp=2), AdamWConfig(),
                seq_len=16, global_batch=4, ckpt_dir=None,
                overlap="stream", stream_k=1, sync_compile=True)
            ctrl.train_steps(3)
            inj = FaultInjector(ctrl)
            rep = inj.inject(phase, ParallelConfig(dp=1, tp=2),
                             lost_ranks=(2, 3),
                             resize_target=ParallelConfig(dp=4, tp=2))
            assert rep.phase == phase, rep
            assert rep.mode == "peer_recover", rep
            assert rep.outcome == "committed", rep
            assert rep.step_before == rep.step_after, rep
            ctrl.train_steps(2)
            assert ctrl.world.parallel.world_size == 2
            print("PHASE_OK", phase)
        print("MATRIX_OK")
        """,
        n_devices=8,
    )
    assert "MATRIX_OK" in out
