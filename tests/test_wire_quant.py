"""Compressed wire format: quantize-on-the-wire kernels vs jnp oracles,
round-trip error bounds, wire-byte accounting, and the operating-point
tuner's monotonicity (DESIGN.md §14)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.reshard_quant import (
    FP8_E4M3_MAX,
    WIRE_QMAX,
    dequant_scatter_rows_pallas,
    pack_quant_rows_pallas,
)
from repro.reshard.autotune import (
    FALLBACK,
    FALLBACK_STREAM_K,
    OperatingPoint,
    tune_operating_point,
)
from repro.reshard.engine import DEFAULT_STAGING_BYTES
from repro.reshard.wire import (
    SIDECAR_BYTES_PER_TILE,
    WirePolicy,
    wire_nbytes,
)

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


def _starts(data, nb, blocks, block):
    picks = data.draw(
        st.lists(st.integers(0, blocks - 1), min_size=nb, max_size=nb,
                 unique=True)
    )
    return jnp.asarray([s * block for s in picks], jnp.int32)


# ---------------------------------------------------------------------------
# pack_quant_rows: interpret-mode kernel vs oracle, error bound
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_pack_quant_rows_property(data):
    """Pallas (interpret) == jnp oracle bit-for-bit on payload AND sidecar,
    and the per-tile symmetric-quant error bound |x - deq| <= scale/2
    holds for int8 (fp8 is format-rounded, checked at a looser bound)."""
    fmt = data.draw(st.sampled_from(["int8", "fp8_e4m3"]))
    dtype = data.draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    nb = data.draw(st.integers(1, 6))
    block = data.draw(st.sampled_from([1, 8]))
    R = block * data.draw(st.integers(max(nb, 2), 12))
    starts = _starts(data, nb, R // block, block)
    src = _rand((R, 128), dtype)

    q_p, s_p = pack_quant_rows_pallas(src, starts, block, fmt, interpret=True)
    q_r, s_r = ref.pack_quant_rows_ref(src, starts, block, fmt)
    np.testing.assert_array_equal(np.asarray(q_p), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(s_p), np.asarray(s_r))

    # round-trip error bound per tile: int8 round-to-nearest stays within
    # scale/2 absolute; fp8-e4m3 (3 mantissa bits) within a half-ulp of
    # the VALUE (2^-4 relative) plus a sub-normal absolute floor
    scales = np.asarray(s_r, np.float32).reshape(nb)
    deq = np.asarray(q_r, np.float32).reshape(nb, block, 128) * scales[
        :, None, None
    ]
    x = np.stack(
        [np.asarray(src[s : s + block], np.float32) for s in np.asarray(starts)]
    )
    err = np.abs(x - deq)
    s3 = scales[:, None, None]
    if fmt == "int8":
        assert (err <= 0.5 * s3 * (1 + 1e-6)).all()
    else:
        assert (err <= 0.0625 * np.abs(x) + 0.01 * s3).all()


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_dequant_scatter_rows_property(data):
    """Dequant-scatter (interpret) == oracle, preserves every destination
    row not named by the offset table, and composes with pack_quant as a
    bounded-error round trip."""
    fmt = data.draw(st.sampled_from(["int8", "fp8_e4m3"]))
    dtype = data.draw(st.sampled_from([jnp.float32, jnp.bfloat16]))
    nb = data.draw(st.integers(1, 6))
    block = data.draw(st.sampled_from([1, 8]))
    R = block * data.draw(st.integers(max(nb, 2), 12))
    starts = _starts(data, nb, R // block, block)
    src = _rand((R, 128), dtype)
    dst = _rand((R, 128), dtype)

    q, scales = ref.pack_quant_rows_ref(src, starts, block, fmt)
    out_p = dequant_scatter_rows_pallas(
        dst, q, scales, starts, block, interpret=True
    )
    out_r = ref.dequant_scatter_rows_ref(dst, q, scales, starts, block)
    np.testing.assert_array_equal(np.asarray(out_p), np.asarray(out_r))

    named = np.zeros(R, bool)
    for s in np.asarray(starts):
        named[s : s + block] = True
    np.testing.assert_array_equal(
        np.asarray(out_p)[~named], np.asarray(dst)[~named]
    )
    # bounded-error round trip on the named rows (gathered in starts order
    # so each row lines up with its tile's sidecar scale): quantization
    # error plus the destination-dtype cast (bf16 adds 2^-8 relative)
    x = np.concatenate(
        [np.asarray(src[s : s + block], np.float32) for s in np.asarray(starts)]
    )
    err = np.abs(
        np.concatenate(
            [
                np.asarray(out_p[s : s + block], np.float32)
                for s in np.asarray(starts)
            ]
        )
        - x
    )
    s = np.repeat(np.asarray(scales, np.float32).reshape(nb), block)[:, None]
    if fmt == "int8":
        assert (err <= 0.01 * np.abs(x) + 0.51 * s).all()
    else:
        assert (err <= 0.07 * np.abs(x) + 0.01 * s).all()


def test_quant_stream_idempotent_and_deterministic():
    """Quantize + dequant-scatter is a deterministic elementwise map: the
    dirty-layer re-stream invariant (re-applying the same round produces
    bitwise-identical destination bytes) survives compression."""
    src = _rand((24, 128), jnp.bfloat16)
    dst = _rand((24, 128), jnp.bfloat16)
    starts = jnp.asarray([2, 7, 11, 21], jnp.int32)
    for fmt in ("int8", "fp8_e4m3"):
        q1, s1 = pack_quant_rows_pallas(src, starts, 1, fmt, interpret=True)
        q2, s2 = pack_quant_rows_pallas(src, starts, 1, fmt, interpret=True)
        np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        once = dequant_scatter_rows_pallas(dst, q1, s1, starts, 1, interpret=True)
        twice = dequant_scatter_rows_pallas(once, q1, s1, starts, 1, interpret=True)
        np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@pytest.mark.parametrize("fmt", ["int8", "fp8_e4m3"])
def test_quant_edge_tiles(fmt):
    """All-zero tiles (scale floors at QUANT_EPS, dequant gives exact
    zeros), denormal tiles, and max-magnitude bf16 tiles (scale maps the
    absmax onto qmax without overflow) all survive the round trip."""
    starts = jnp.asarray([0, 1, 2], jnp.int32)
    zero = jnp.zeros((1, 128), jnp.float32)
    denorm = jnp.full((1, 128), 1e-40, jnp.float32)
    big = jnp.full((1, 128), 3.38e38, jnp.float32)  # ~max finite bf16
    src = jnp.concatenate([zero, denorm, big])

    q, scales = pack_quant_rows_pallas(src, starts, 1, fmt, interpret=True)
    q_r, s_r = ref.pack_quant_rows_ref(src, starts, 1, fmt)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(scales), np.asarray(s_r))
    assert np.isfinite(np.asarray(scales)).all()

    out = dequant_scatter_rows_pallas(
        jnp.ones_like(src), q, scales, starts, 1, interpret=True
    )
    out = np.asarray(out, np.float32)
    np.testing.assert_array_equal(out[0], np.zeros(128))  # exact zeros
    assert np.isfinite(out).all()  # no inf/nan from denormal or max tiles
    qmax = WIRE_QMAX[fmt]
    np.testing.assert_allclose(out[2], np.asarray(big[0]), rtol=1.5 / qmax)


def test_fp8_constant_matches_dtype():
    assert float(jnp.finfo(jnp.float8_e4m3fn).max) == FP8_E4M3_MAX


# ---------------------------------------------------------------------------
# wire-byte accounting
# ---------------------------------------------------------------------------


class _Task:
    def __init__(self, collection, shape, nbytes, kind="remote"):
        self.collection = collection
        self._shape = shape
        self.nbytes = nbytes
        self.kind = kind

    def shape(self):
        return self._shape


def test_wire_policy_nbytes():
    pol = WirePolicy()  # moments int8, params lossless
    mu = _Task("mu", (64, 128), 64 * 128 * 4)
    assert wire_nbytes(pol, mu) == 64 * 128 + 64 * SIDECAR_BYTES_PER_TILE
    par = _Task("params", (64, 128), 64 * 128 * 4)
    assert wire_nbytes(pol, par) == par.nbytes  # lossless by default
    step = _Task("step", (), 8)
    assert wire_nbytes(pol, step) == 8  # scalars always lossless
    local = _Task("mu", (64, 128), 64 * 128 * 4, kind="local")
    assert wire_nbytes(pol, local) == local.nbytes  # relayouts never quantize
    assert wire_nbytes(None, mu) == mu.nbytes  # None policy == lossless

    assert (
        WirePolicy(params="fp8_e4m3").wire_nbytes(par)
        == 64 * 128 + 64 * SIDECAR_BYTES_PER_TILE
    )
    with pytest.raises(ValueError):
        WirePolicy(moments="int4")


def test_chunk_budget_counts_wire_bytes():
    """The staging budget bounds what is physically staged: a quantized
    task packs ~4x more logical rows per chunk than its lossless self."""
    from repro.core.intersection import TransferTask
    from repro.reshard.chunking import chunk_task

    t = TransferTask(
        tensor="mu/x", collection="mu", src_rank=0, dst_rank=1,
        bounds=((0, 64), (0, 128)), src_offset=(0, 0), dst_offset=(0, 0),
        nbytes=64 * 128 * 4, layer=0,
    )
    budget = 16 * (128 + SIDECAR_BYTES_PER_TILE)  # 16 quantized rows
    lossless = chunk_task(t, budget, None)
    quant = chunk_task(t, budget, WirePolicy())
    assert len(quant) < len(lossless)
    for chunks in (lossless, quant):
        assert sum(c.nbytes for c in chunks) == t.nbytes  # logical preserved
    assert all(
        wire_nbytes(WirePolicy(), c) <= budget for c in quant
    )


def test_engine_sim_prices_wire_vs_logical_bytes():
    """End-to-end through the sim oracle: wire_bytes ~ logical/4 under the
    default policy (moments int8, params lossless stay 1:1), destination
    bytes for params are exact, and the lossless run reports wire ==
    logical."""
    import numpy as np
    from repro.configs.base import ParallelConfig
    from repro.core.intersection import plan_transfer
    from repro.core.resource_view import TensorSpec
    from repro.core.streaming import (
        allocate_destination,
        execute_plan,
        materialize_rank,
    )

    specs = [
        TensorSpec("params/blocks/pos0/w", (8, 16, 32), "float32",
                   ("pp", "none", "tp"), "stages", "params"),
        TensorSpec("mu/blocks/pos0/w", (8, 16, 32), "float32",
                   ("pp", "none", "tp"), "stages", "mu"),
    ]
    ca, cb = ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=4)
    plan = plan_transfer(specs, ca, cb, num_positions=1)
    rng = np.random.default_rng(0)
    g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}

    def run(policy):
        src = {r: materialize_rank(specs, ca, r, g) for r in range(ca.world_size)}
        dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
        return execute_plan(plan, src, dst, staging_bytes=2048,
                            wire_policy=policy), dst

    s_none, _ = run(None)
    assert s_none.wire_bytes == s_none.logical_bytes == s_none.network_bytes

    s_q, dst = run(WirePolicy())
    assert s_q.logical_bytes == s_none.logical_bytes  # plan unchanged
    assert s_q.wire_bytes < s_q.logical_bytes  # moments shrank on the wire
    # params stayed lossless: their destination shards are byte-exact
    for r, store in dst.items():
        if "params/blocks/pos0/w" in store.shards:
            got = store.shards["params/blocks/pos0/w"]
            from repro.core.resource_view import view_of

            v = view_of(specs[0], cb, r)
            sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
            np.testing.assert_array_equal(got, g["params/blocks/pos0/w"][sl])


# ---------------------------------------------------------------------------
# operating-point tuner
# ---------------------------------------------------------------------------


def test_tuner_fallback_without_bandwidth():
    for bw in (None, 0.0, -1.0):
        assert tune_operating_point(1 << 30, 10, 30.0, bw) == FALLBACK
    assert FALLBACK.stream_k == FALLBACK_STREAM_K
    assert FALLBACK.staging_bytes == DEFAULT_STAGING_BYTES
    assert FALLBACK.source == "fallback"
    # degenerate plans never tune either
    assert tune_operating_point(0, 10, 30.0, 1e9).source == "fallback"
    assert tune_operating_point(1 << 20, 0, 30.0, 1e9).source == "fallback"


@settings(max_examples=25, deadline=None)
@given(
    plan_mb=st.integers(1, 4096),
    layers=st.integers(1, 64),
    w1=st.floats(0.0, 600.0),
    w2=st.floats(0.0, 600.0),
    bw_mb=st.floats(1.0, 1e5),
)
def test_tuner_monotone_in_window(plan_mb, layers, w1, w2, bw_mb):
    """At fixed plan bytes and bandwidth, stream_k and chunk size are
    monotone non-decreasing in the warning window — a wider window never
    buys a *smaller* round or chunk."""
    lo, hi = sorted((w1, w2))
    a = tune_operating_point(plan_mb << 20, layers, lo, bw_mb * 1e6)
    b = tune_operating_point(plan_mb << 20, layers, hi, bw_mb * 1e6)
    assert a.source == b.source == "measured"
    assert a.stream_k <= b.stream_k
    assert a.chunk_bytes <= b.chunk_bytes
    # bounds every point must respect
    for op in (a, b):
        assert 1 <= op.stream_k <= layers
        assert op.chunk_bytes <= op.staging_bytes <= DEFAULT_STAGING_BYTES


def test_operating_point_to_dict_roundtrip():
    op = tune_operating_point(100 << 20, 10, 30.0, 50e6)
    d = op.to_dict()
    assert OperatingPoint(**d) == op
    assert d["source"] == "measured"
