"""Checkpoint subsystem: save/load roundtrip, load-time resharding (UCP
baseline), async save, latest-step discovery."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)


def _state():
    k = jax.random.key(0)
    return {
        "params": {
            "w": jax.random.normal(k, (16, 8)),
            "b": jnp.zeros((8,)),
        },
        "opt": {"count": jnp.int32(5)},
    }


def test_roundtrip(tmp_path):
    state = _state()
    dt = save_checkpoint(str(tmp_path), 42, state)
    assert dt > 0
    assert latest_step(str(tmp_path)) == 42
    loaded, step, _ = load_checkpoint(str(tmp_path), state)
    assert step == 42
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state,
        loaded,
    )


def test_latest_step_picks_max(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 1, state)
    save_checkpoint(str(tmp_path), 12, state)
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 12


def test_load_time_resharding(tmp_path):
    """UCP baseline semantics: a checkpoint written under one layout loads
    under any target sharding (here: replicated -> device sharding)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import ParallelConfig
    from repro.distribution.sharding import make_elastic_mesh

    state = _state()
    save_checkpoint(str(tmp_path), 3, state)
    mesh = make_elastic_mesh(ParallelConfig())  # single device
    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), state)
    loaded, step, secs = load_checkpoint(str(tmp_path), state, target_shardings=sh)
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(loaded["params"]["w"]), np.asarray(state["params"]["w"])
    )


def test_async_checkpointer(tmp_path):
    state = _state()
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(10, state)
    ck.wait()
    assert latest_step(str(tmp_path)) == 10
    loaded, _, _ = load_checkpoint(str(tmp_path), state)
    np.testing.assert_array_equal(
        np.asarray(loaded["params"]["w"]), np.asarray(state["params"]["w"])
    )
    assert ck.last_save_seconds is not None


def test_atomic_publish(tmp_path):
    """A .tmp dir must never be visible as a checkpoint."""
    state = _state()
    save_checkpoint(str(tmp_path), 9, state)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
