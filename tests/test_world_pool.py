"""Speculative warm world pool (DESIGN.md §12) and the prepare-path bugfix
sweep: pool LRU/release semantics, ShadowBuilder timing stamped at thread
start, abandoned-shadow device-memory release, the DeadlineEstimator
sampling every completed prepare (not just committed ones) with separate
warm/cold estimates, the encdec abstract-batch dtype sweep, and the
prefetch candidate enumeration. Live end-to-end (8 host devices): a warm
pool roundtrip commits params bitwise-equal to a cold-built run, with
warm Prepare >=5x faster; prefetch -> join/pool-hit -> warm resize; an
abandoned shadow deposits into the pool.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from types import SimpleNamespace

import pytest

from repro.configs.base import ParallelConfig


def _handle(par=None, **kw):
    from repro.core.shadow import WorldHandle

    return WorldHandle(
        parallel=par or ParallelConfig(), mesh=None, step_fn=object(),
        shardings=object(), **kw,
    )


# ---------------------------------------------------------------------------
# WorldPool semantics (pure; no JAX)
# ---------------------------------------------------------------------------


def test_pool_lru_eviction_releases_oldest():
    from repro.core.world_pool import WorldPool

    pool = WorldPool(capacity=2)
    a, b, c = _handle(), _handle(), _handle()
    pool.put(("a",), a)
    pool.put(("b",), b)
    pool.put(("c",), c)  # evicts a (LRU)
    assert len(pool) == 2 and not pool.contains(("a",))
    assert a.released and a.step_fn is None, "eviction must release"
    assert not b.released and not c.released
    assert pool.stats.evictions == 1 and pool.stats.puts == 3


def test_pool_take_transfers_ownership():
    from repro.core.world_pool import WorldPool

    pool = WorldPool(capacity=2)
    h = _handle()
    pool.put(("k",), h)
    assert pool.take(("k",)) is h
    assert not h.released, "take must NOT release (caller owns the world)"
    assert pool.take(("k",)) is None
    assert pool.stats.hits == 1 and pool.stats.misses == 1


def test_pool_duplicate_put_keeps_resident_and_releases_incoming():
    from repro.core.world_pool import WorldPool

    pool = WorldPool(capacity=2)
    first, second = _handle(), _handle()
    pool.put(("k",), first)
    pool.put(("k",), second)
    assert pool.take(("k",)) is first
    assert second.released and not first.released
    assert pool.stats.duplicate_puts == 1


def test_pool_rejects_released_and_evict_invalidate():
    from repro.core.world_pool import WorldPool

    pool = WorldPool(capacity=4)
    dead = _handle()
    dead.release()
    pool.put(("dead",), dead)
    assert len(pool) == 0, "a released handle must never be pooled"

    h1, h2 = _handle(), _handle()
    pool.put(("k1",), h1)
    pool.put(("k2",), h2)
    assert pool.evict(("k1",)) and h1.released
    assert not pool.evict(("k1",))  # already gone
    assert pool.invalidate(lambda k, h: True) == 1 and h2.released
    assert len(pool) == 0


# ---------------------------------------------------------------------------
# ShadowBuilder: prepare timing + abandoned release (satellites 1 & 4)
# ---------------------------------------------------------------------------


def test_prepare_timing_stamped_at_thread_start_not_construction():
    from repro.core.shadow import ShadowBuilder

    builder = ShadowBuilder(_handle, gen_id=1)
    assert builder.started_at is None
    time.sleep(0.25)  # the pool routinely separates construction and start
    handle = builder.start().result(timeout=30)
    assert handle.timings["prepare_total_s"] < 0.2, (
        "prepare_total_s must not include the construction->start gap"
    )


def test_abandon_before_completion_releases_on_completion():
    from repro.core.shadow import ShadowBuilder

    release_gate = threading.Event()
    made = {}

    def build():
        release_gate.wait(30)
        made["h"] = _handle()
        return made["h"]

    builder = ShadowBuilder(build, gen_id=1).start()
    builder.abandon()  # mid-build: discard must fire when the build lands
    release_gate.set()
    builder._done.wait(30)
    builder._thread.join(30)
    assert made["h"].released, "abandoned shadow must not pin memory to GC"


def test_abandon_after_completion_releases_immediately():
    from repro.core.shadow import ShadowBuilder

    builder = ShadowBuilder(_handle, gen_id=1).start()
    handle = builder.result(timeout=30)
    assert not handle.released
    builder.abandon()
    assert handle.released


def test_abandon_routes_through_on_discard_exactly_once():
    from repro.core.shadow import ShadowBuilder

    got = []
    builder = ShadowBuilder(_handle, gen_id=1, on_discard=got.append).start()
    handle = builder.result(timeout=30)
    builder.abandon()
    builder.abandon()
    assert got == [handle]
    assert not handle.released, "on_discard owns the disposal (pool deposit)"


# ---------------------------------------------------------------------------
# DeadlineEstimator: sampling + warm/cold split (satellite 2 + tentpole)
# ---------------------------------------------------------------------------


def _rec(outcome, prepare_s, mode="live_overlap", warm=False, **kw):
    from repro.core.controller import ReconfigRecord

    return ReconfigRecord(
        gen_id=1, src="a", dst="b", outcome=outcome, prepare_s=prepare_s,
        mode=mode, warm_hit=warm, **kw,
    )


def _estimator(records):
    from repro.elastic import DeadlineEstimator

    ctrl = SimpleNamespace(
        records=records,
        world=SimpleNamespace(timings={}),
        iteration_times=[],
        stream_k=4,
    )
    return DeadlineEstimator(ctrl, default_prepare_s=999.0)


def test_estimator_samples_survive_retarget_heavy_stretch():
    # a stretch with zero committed records used to silently reset the
    # estimator to its defaults; completed prepares must keep feeding it
    recs = [_rec("retargeted", 3.0) for _ in range(4)]
    recs += [_rec("fell_back", 5.0)]  # escalated commit: prepare completed
    est = _estimator(recs)
    assert est.prepare_estimate() == pytest.approx(3.0)  # median of 3,3,3,3,5
    # mid-prepare retargets (no completed prepare) contribute nothing
    est2 = _estimator([_rec("retargeted", 0.0) for _ in range(6)])
    assert est2.prepare_estimate() == pytest.approx(999.0)
    # checkpoint-rung records stay excluded by mode
    est3 = _estimator([_rec("fell_back", 7.0, mode="fallback")])
    assert est3.prepare_estimate() == pytest.approx(999.0)


def test_estimator_bandwidth_uses_noncommitted_precopy():
    recs = [
        _rec("retargeted", 2.0, precopy_s=1.0, moved_bytes=1 << 20),
        _rec("retargeted", 2.0, precopy_s=2.0, moved_bytes=1 << 21),
    ]
    est = _estimator(recs)
    assert est.bandwidth_estimate() == pytest.approx(1 << 20)


def test_estimator_excludes_speculative_joins_from_both_classes():
    # a join times only the residual wait of an in-flight prefetch: it is
    # neither a warm nor a cold Prepare sample and must not drag the cold
    # median toward zero
    recs = [_rec("committed", 10.0) for _ in range(3)]
    recs += [
        _rec("committed", 0.5, prepare_source="speculative_join")
        for _ in range(5)
    ]
    est = _estimator(recs)
    assert est.prepare_estimate(warm=False) == pytest.approx(10.0)
    assert est.prepare_estimate(warm=True) == pytest.approx(1.0)  # default


def test_estimator_keeps_separate_warm_cold_prepare():
    recs = [_rec("committed", 10.0) for _ in range(3)]
    recs += [_rec("committed", 0.05, warm=True) for _ in range(3)]
    est = _estimator(recs)
    assert est.prepare_estimate(warm=False) == pytest.approx(10.0)
    assert est.prepare_estimate(warm=True) == pytest.approx(0.05)
    # no warm history: bounded by min(cold estimate, warm default)
    est2 = _estimator([_rec("committed", 10.0)])
    assert est2.prepare_estimate(warm=True) == pytest.approx(1.0)
    est3 = _estimator([_rec("committed", 0.3)])
    assert est3.prepare_estimate(warm=True) == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# abstract_batch dtype sweep (satellite 3)
# ---------------------------------------------------------------------------


def test_abstract_batch_resolves_any_configured_dtype():
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.shadow import abstract_batch

    encdec = get_config("seamless-m4t-large-v2").reduced()
    for dtype in ("bfloat16", "float32", "float16"):
        cfg = dataclasses.replace(encdec, dtype=dtype)
        abatch = abstract_batch(cfg, 4, 16)
        assert abatch["frames"].dtype == jnp.dtype(dtype)
        assert abatch["frames"].shape == (4, 16, cfg.d_model)
        assert abatch["tokens"].dtype == jnp.int32
    # non-encdec families carry no frames regardless of dtype
    dense = dataclasses.replace(
        get_config("qwen3-1.7b").reduced(), dtype="float16"
    )
    assert set(abstract_batch(dense, 4, 16)) == {"tokens"}


# ---------------------------------------------------------------------------
# Prefetch candidate enumeration (tentpole)
# ---------------------------------------------------------------------------


def test_likely_next_targets_walks_down_and_up():
    from repro.configs import get_config
    from repro.core.topology_search import likely_next_targets

    cfg = get_config("qwen3-1.7b").reduced()
    current = ParallelConfig(dp=2, tp=2)
    out = likely_next_targets(cfg, current, 8, 8, 32, k=2, max_pp=1)
    assert 1 <= len(out) <= 2
    assert current not in out
    assert {t.world_size for t in out} <= {2, 8}
    # at the device ceiling the walk-up neighbor clamps away
    out_top = likely_next_targets(
        cfg, ParallelConfig(dp=2, tp=4), 8, 8, 32, k=2, max_pp=1
    )
    assert all(t.world_size == 4 for t in out_top)
    assert len(likely_next_targets(cfg, current, 8, 8, 32, k=0)) == 0


def test_prefetch_policy_guardrails_with_stub_controller():
    from repro.elastic import PrefetchPolicy

    calls = []

    class Ctrl:
        def __init__(self):
            from repro.configs import get_config

            self.cfg = get_config("qwen3-1.7b").reduced()
            self.world = SimpleNamespace(parallel=ParallelConfig(dp=2, tp=2))
            self.devices = list(range(8))
            self.global_batch, self.seq_len = 8, 32

        def prefetch_world(self, target):
            calls.append(target)
            return True

    policy = PrefetchPolicy(Ctrl(), k=2)
    # k likely-next targets plus the failover standby chain (DESIGN.md
    # §15): the prefix-survivor standby leads the list — fail-stop
    # readiness outranks walk guesses — while the world_size-1 chain tail
    # queues last so it can't hog the build slot before a walk-up
    assert policy.tick() == 4 and policy.started == 4
    assert len(calls) == 4 and len(set(calls)) == 4
    assert calls[0] == ParallelConfig(dp=1, tp=2)  # dp2xtp2 minus a replica
    assert calls[-1] == ParallelConfig(dp=1, tp=1)  # ws1 standby queues last
    # idle ticks reuse the cached candidates (no re-search) until the
    # active world changes, and a pending reconfiguration skips entirely
    policy.candidates = None  # would raise if re-enumerated
    assert policy.tick() == 4
    policy.ctrl.reconfig_pending = True
    assert policy.tick() == 0


def test_failover_target_prefix_survivor_scheme():
    from repro.configs import get_config
    from repro.core.topology_search import failover_target

    cfg = get_config("qwen3-1.7b").reduced()
    # dp>1: drop one replica, same (pp, tp)
    assert failover_target(cfg, ParallelConfig(dp=2, tp=2), 8) == \
        ParallelConfig(dp=1, tp=2)
    # dp-1 must divide the batch: dp=4 with batch 8 can't run dp=3,
    # falls to the next feasible dp below
    assert failover_target(cfg, ParallelConfig(dp=4, tp=2), 8) == \
        ParallelConfig(dp=2, tp=2)
    # dp=1: halve tp (the parity word repairs one dead tp group)
    assert failover_target(cfg, ParallelConfig(dp=1, tp=4), 8) == \
        ParallelConfig(dp=1, tp=2)
    # single device: nothing to fail over to
    assert failover_target(cfg, ParallelConfig(dp=1, tp=1), 8) is None


def test_prefetch_tick_prewarms_pooled_transfer_pairs():
    from repro.elastic import PrefetchPolicy

    prewarmed = []

    class Pool:
        def keys(self):
            # pool_key layout: (cfg, parallel, fingerprint, ...)
            return [(None, ParallelConfig(dp=1, tp=4), (0, 1, 2, 3)),
                    (None, ParallelConfig(dp=2, tp=2), (0, 1, 2, 3))]

    class Ctrl:
        def __init__(self):
            from repro.configs import get_config

            self.cfg = get_config("qwen3-1.7b").reduced()
            self.world = SimpleNamespace(parallel=ParallelConfig(dp=2, tp=2))
            self.devices = list(range(8))
            self.global_batch, self.seq_len = 8, 32
            self.world_pool = Pool()

        def prefetch_world(self, target):
            return False  # everything "already pooled/building"

        def prewarm_transfer(self, target):
            prewarmed.append(target)
            return True

    policy = PrefetchPolicy(Ctrl(), k=1)
    assert policy.tick() == 0
    # candidates that were already pooled get their transfer pair warmed,
    # and so does every pooled same-size retopology — but never the
    # current world itself
    assert ParallelConfig(dp=1, tp=4) in prewarmed
    assert ParallelConfig(dp=2, tp=2) not in prewarmed


def test_prefetch_tick_streams_ahead_during_resize():
    """Mid-resize ticks must warm the INCOMING world's failover pairs
    (prewarm_failover_ahead) instead of doing nothing: a window-0 event
    right after the commit pays any cold transfer compile in its pause."""
    from repro.elastic import PrefetchPolicy

    calls = []

    class Ctrl:
        reconfig_pending = True

        def prewarm_failover_ahead(self):
            calls.append("ahead")
            return 1

        def prefetch_world(self, target):  # must NOT be reached
            raise AssertionError("no builds mid-resize")

    policy = PrefetchPolicy(Ctrl(), k=1)
    assert policy.tick() == 0
    assert calls == ["ahead"]


# ---------------------------------------------------------------------------
# Live end-to-end (8 host devices)
# ---------------------------------------------------------------------------


def test_warm_pool_roundtrip_parity_and_speed(subproc):
    """Resize A->B->A with a warm pool: the return leg must be served from
    the pool (lower+compile skipped; prepare >=5x faster than the cold
    leg) and commit params BITWISE-equal to the identical no-pool run."""
    out = subproc(
        """
        import numpy as np
        import jax.tree_util as jtu
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.world_pool import WorldPool
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=5)
        A, B = ParallelConfig(dp=2, tp=2), ParallelConfig(dp=2, tp=4)

        def run(pool):
            c = LiveRController(cfg, A, opt, seq_len=32, global_batch=8,
                                seed=0, world_pool=pool)
            c.train_steps(2)
            for target in (B, A):
                c.request_resize(target)
                c.wait_shadow_ready()
                c.train_steps(1)  # stop-copy commit at the boundary
                assert c.records[-1].outcome == "committed"
                c.train_steps(2)
            return c

        pool = WorldPool(capacity=2)
        w = run(pool)
        c = run(None)
        r_cold, r_warm = w.records[0], w.records[1]
        assert not r_cold.warm_hit
        assert r_warm.warm_hit, (pool.stats.to_dict(),
                                 [r.warm_hit for r in w.records])
        assert r_warm.prepare_s * 5 <= r_cold.prepare_s, (
            r_warm.prepare_s, r_cold.prepare_s)
        assert pool.stats.hits >= 1 and pool.stats.puts >= 1
        assert all(not rr.warm_hit for rr in c.records)
        assert w.step == c.step
        jtu.tree_map(np.testing.assert_array_equal,
                     w.gathered_params(), c.gathered_params())
        print("WARM_PARITY_OK warm=%.4fs cold=%.4fs" %
              (r_warm.prepare_s, r_cold.prepare_s))
        """,
        n_devices=8,
    )
    assert "WARM_PARITY_OK" in out


def test_prefetch_join_abandon_deposit_and_warm_resize(subproc):
    """The three pool producers live: a speculative prefetch serves a
    resize (join or pool hit), a cancelled shadow deposits its world, and
    the retired active world serves the resize back warm."""
    out = subproc(
        """
        import time
        from repro.configs import get_config
        from repro.configs.base import ParallelConfig
        from repro.core.controller import LiveRController
        from repro.core.world_pool import WorldPool
        from repro.elastic import DeadlineEstimator
        from repro.optim import AdamWConfig

        cfg = get_config("qwen3-1.7b").reduced()
        A, T = ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=4)
        ctrl = LiveRController(cfg, A, AdamWConfig(), seq_len=16,
                               global_batch=8, world_pool=WorldPool(capacity=3))
        ctrl.train_steps(1)

        # speculative build; joined (in flight) or pooled (already landed)
        assert ctrl.prefetch_world(T)
        assert not ctrl.prefetch_world(T)  # dedupe: already building
        ctrl.request_resize(T)
        ctrl.wait_shadow_ready()
        src = ctrl._builder.result().timings.get("prepare_source")
        assert src in ("pool", "speculative_join"), src
        ctrl.train_steps(1)
        assert ctrl.records[-1].outcome == "committed"
        assert ctrl.world.parallel == T

        # retired A is warm now: the estimator must see it and the resize
        # back must hit the pool
        assert ctrl.world_pool.contains(ctrl.pool_key(A))
        est = DeadlineEstimator(ctrl).estimate(A)
        assert est.warm and est.prepare_s <= 1.0, est
        ctrl.request_resize(A)
        ctrl.wait_shadow_ready()
        ctrl.train_steps(1)
        rec = ctrl.records[-1]
        assert rec.outcome == "committed" and rec.warm_hit, rec

        # a cancelled shadow's world deposits into the pool instead of
        # pinning device memory until GC
        Bp = ParallelConfig(dp=1, tp=2)
        ctrl.request_resize(Bp)
        ctrl.wait_shadow_ready()
        ctrl.cancel_resize()
        t0 = time.time()
        while (not ctrl.world_pool.contains(ctrl.pool_key(Bp))
               and time.time() - t0 < 60):
            time.sleep(0.05)
        assert ctrl.world_pool.contains(ctrl.pool_key(Bp))
        # and a warm world taken for that target skips the build
        ctrl.request_resize(Bp)
        ctrl.wait_shadow_ready()
        ctrl.train_steps(1)
        assert ctrl.records[-1].warm_hit
        ctrl.train_steps(1)

        # a broken warm world must not fail the resize: the Prepare thread
        # falls back to a cold build and releases the taken handle
        key = ctrl.pool_key(A)  # A was retired warm by the Bp commit
        assert ctrl.world_pool.contains(key)
        warmA = ctrl.world_pool.take(key)
        ctrl.world_pool.put(key, warmA)  # peek: keep a reference
        def bad_refresh(handle, mode, source="pool"):
            raise RuntimeError("poisoned warm world")
        ctrl._refresh_pooled = bad_refresh
        ctrl.request_resize(A)
        ctrl.wait_shadow_ready()  # must not raise
        ctrl.train_steps(1)
        rec = ctrl.records[-1]
        assert rec.outcome == "committed"
        assert not rec.warm_hit and rec.prepare_source == "cold", rec
        assert warmA.released, "broken warm world must release, not leak"
        print("PREFETCH_POOL_OK hits=%d puts=%d" %
              (ctrl.world_pool.stats.hits, ctrl.world_pool.stats.puts))
        """,
        n_devices=8,
    )
    assert "PREFETCH_POOL_OK" in out
