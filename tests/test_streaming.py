"""Algorithm 1 executor: bounded staging memory (Theorem 1), layer ordering,
chunking of oversized tasks."""

from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer
from repro.core.streaming import (
    allocate_destination,
    execute_plan,
    materialize_rank,
)
from repro.reshard.chunking import chunk_task
from repro.core.resource_view import TensorSpec


def _setup(staging):
    specs = [
        TensorSpec(
            "params/blocks/pos0/w", (4, 64, 32), "float32",
            ("pp", "none", "tp"), "stages", "params",
        ),
        TensorSpec(
            "params/blocks/pos1/w", (4, 64, 32), "float32",
            ("pp", "none", "tp"), "stages", "params",
        ),
    ]
    ca, cb = ParallelConfig(pp=2, tp=2), ParallelConfig(pp=1, tp=4)
    plan = plan_transfer(specs, ca, cb, num_positions=2)
    rng = np.random.default_rng(1)
    g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
    src = {r: materialize_rank(specs, ca, r, g) for r in range(ca.world_size)}
    dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
    return specs, plan, g, src, dst


@pytest.mark.parametrize("staging", [256, 1024, 1 << 20])
def test_bounded_memory_theorem1(staging):
    specs, plan, g, src, dst = _setup(staging)
    stats = execute_plan(plan, src, dst, staging_bytes=staging)
    stats.assert_bounded(staging)
    assert stats.peak_staging_bytes <= staging
    for r, store in dst.items():
        ref = materialize_rank(specs, plan.cfg_dst, r, g)
        for name in ref.shards:
            np.testing.assert_array_equal(ref.shards[name], store.shards[name])


def test_layer_streaming_order():
    """Layers stream in global-layer order: pos interleaved across periods."""
    specs, plan, *_ = _setup(1024)
    layers = plan.layers()
    assert layers == sorted(layers)
    # num_positions=2, 4 periods -> global layers 0..7
    assert layers == list(range(8))


def test_chunking_splits_oversized_tasks():
    from repro.core.intersection import TransferTask

    t = TransferTask(
        tensor="params/w", collection="params", src_rank=0, dst_rank=1,
        bounds=((0, 64), (0, 32)), src_offset=(0, 0), dst_offset=(0, 0),
        nbytes=64 * 32 * 4, layer=0,
    )
    chunks = chunk_task(t, budget=32 * 4 * 8)  # 8 rows per chunk
    assert len(chunks) == 8
    assert all(c.nbytes <= 32 * 4 * 8 for c in chunks)
    # chunks tile the task
    starts = sorted(c.bounds[0][0] for c in chunks)
    assert starts == list(range(0, 64, 8))
    assert sum(c.nbytes for c in chunks) == t.nbytes


def test_transition_overhead_independent_of_model_size():
    """Paper §4.6.2: staging overhead never scales with total model size."""
    peaks = []
    for layers in (2, 8):
        specs = [
            TensorSpec(
                "params/blocks/pos0/w", (layers, 32, 32), "float32",
                ("pp", "none", "tp"), "stages", "params",
            )
        ]
        ca, cb = ParallelConfig(tp=2), ParallelConfig(tp=4)
        plan = plan_transfer(specs, ca, cb)
        rng = np.random.default_rng(0)
        g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
        src = {r: materialize_rank(specs, ca, r, g) for r in range(ca.world_size)}
        dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
        stats = execute_plan(plan, src, dst, staging_bytes=2048)
        peaks.append(stats.peak_staging_bytes)
    assert peaks[0] == peaks[1]  # O(B), not O(model)
