"""Serving-state migration: the KV/SSD cache pytree moves through the SAME
intersection-planner -> ReshardEngine pipeline as params, byte-identical
between the SimExecutor oracle and the LiveExecutor, with delta
classification making tp-preserving resizes free (0 executed bytes)."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.serve.cache_view import (
    cache_tensor_specs,
    named_serve_leaves,
    serve_plan,
    serve_state_specs,
)
from repro.utils.pytree import tree_paths

FAMILY_ARCHS = ["qwen3-1.7b", "mamba2-2.7b", "seamless-m4t-large-v2"]


@pytest.mark.parametrize("arch", FAMILY_ARCHS + ["mixtral-8x7b"])
def test_cache_specs_match_cache_pytree(arch):
    """Every decode-cache leaf (kvcache.init_cache layout, incl. cross-KV)
    has a spec with exactly its shape/dtype under the resource-view name
    that named_serve_leaves assigns — the contract that lets one plan cover
    the live cache."""
    from repro.models import kvcache

    cfg = get_config(arch).reduced()
    batch, max_seq, cross_len = 2, 16, 8
    specs = {
        s.name: s
        for s in cache_tensor_specs(
            cfg, batch, max_seq, cache_dtype="float32", cross_len=cross_len
        )
    }
    cache = jax.eval_shape(
        lambda: kvcache.init_cache(cfg, batch, max_seq, np.float32)
    )
    cross = None
    if cfg.family == "encdec":
        cross = jax.eval_shape(
            lambda: kvcache.init_cross_kv(cfg, batch, cross_len, np.float32)
        )
    named = {}
    for path, leaf in tree_paths(cache).items():
        named[f"cache/{path}"] = leaf
    for path, leaf in tree_paths(cross or {}).items():
        named[f"cross/{path}"] = leaf
    assert set(named) == set(specs)
    for name, leaf in named.items():
        assert specs[name].shape == tuple(leaf.shape), name
        assert np.dtype(specs[name].dtype) == np.dtype(leaf.dtype), name
        assert len(specs[name].roles) == len(leaf.shape), name
        assert specs[name].roles[0] == "pp", name  # stacked period axis


@pytest.mark.parametrize("arch", FAMILY_ARCHS)
def test_tp_preserving_resize_is_fully_resident(arch):
    """The serving residency invariant (DESIGN.md §16): no serving-state
    spec carries a dp role, so any resize that preserves the tp degree
    classifies params AND cache fully resident — zero planned movement."""
    cfg = get_config(arch).reduced()
    cross = 8 if cfg.family == "encdec" else 0
    specs = serve_state_specs(cfg, 2, 16, cache_dtype="float32", cross_len=cross)
    assert all("dp" not in s.roles for s in specs)
    plan = serve_plan(
        cfg, specs, ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)
    )
    assert plan.network_bytes == 0 and plan.local_bytes == 0
    assert plan.resident_bytes > 0
    assert plan.resident_layers() == plan.layers()
    # and a dp-GROW only broadcasts: surviving ranks keep their shards
    grow = serve_plan(
        cfg, specs, ParallelConfig(dp=1, tp=2), ParallelConfig(dp=2, tp=2)
    )
    assert grow.network_bytes > 0
    assert grow.resident_bytes > 0


def test_named_serve_leaves_handles_params_only():
    named = named_serve_leaves({"w": np.zeros(2)}, None, None)
    assert list(named) == ["params/w"]


# Cross-backend cache-migration parity in a subprocess with 8 host devices:
# one plan, executed by SimExecutor over per-rank numpy shards and by
# LiveExecutor over globally-sharded jax.Arrays — destination shards must
# be byte-identical for every target rank, across a tp-change, a dp-change,
# and a tp-preserving (resident-skip) resize, for attn AND ssm caches.
_CACHE_PARITY_SNIPPET = """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.core.resource_view import view_of
from repro.core.streaming import allocate_destination, execute_plan, materialize_rank
from repro.distribution.sharding import make_elastic_mesh
from repro.reshard import LiveExecutor, ReshardEngine
from repro.serve.cache_view import cache_tensor_specs, role_sharding, serve_plan

TRANSITIONS = [
    ("tp_change",   ParallelConfig(dp=1, tp=2), ParallelConfig(dp=1, tp=4)),
    ("dp_change",   ParallelConfig(dp=1, tp=2), ParallelConfig(dp=2, tp=2)),
    ("tp_preserve", ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)),
]
BUDGET = 8192
for arch in ("qwen3-1.7b", "mamba2-2.7b"):
    cfg = get_config(arch).reduced()
    if cfg.family != "ssm":
        # 4 kv heads so the tp4 leg splits heads evenly
        cfg = dataclasses.replace(cfg, num_kv_heads=4, num_heads=4)
    specs = cache_tensor_specs(cfg, 4, 32, cache_dtype="float32")
    rng = np.random.default_rng(0)
    g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in specs}
    for name, ca, cb in TRANSITIONS:
        plan = serve_plan(cfg, specs, ca, cb)
        # oracle: simulated ranks
        src = {r: materialize_rank(specs, ca, r, g) for r in range(ca.world_size)}
        dst = {r: allocate_destination(specs, cb, r) for r in range(cb.world_size)}
        sim_stats = execute_plan(plan, src, dst, staging_bytes=BUDGET)
        # live: global jax.Arrays, role-derived shardings on mesh_a -> mesh_b
        mesh_a, mesh_b = make_elastic_mesh(ca), make_elastic_mesh(cb)
        live_src = {s.name: jax.device_put(jnp.asarray(g[s.name]),
                                           role_sharding(s, mesh_a))
                    for s in specs}
        targets = {s.name: role_sharding(s, mesh_b) for s in specs}
        ex = LiveExecutor({s.name: s for s in specs}, live_src, targets, BUDGET)
        live_stats = ReshardEngine(plan, ex, staging_bytes=BUDGET).run()
        ex.block_until_ready()
        # identical engine-side accounting from both backends
        assert live_stats.network_bytes == sim_stats.network_bytes, (arch, name)
        assert live_stats.local_bytes == sim_stats.local_bytes, (arch, name)
        assert live_stats.resident_bytes == sim_stats.resident_bytes, (arch, name)
        assert live_stats.layers_streamed == sim_stats.layers_streamed, (arch, name)
        live_stats.assert_bounded(BUDGET)
        # byte-identical destination shards on every target rank
        for s in specs:
            got = np.asarray(jax.device_get(ex.results()[s.name]))
            np.testing.assert_array_equal(got, g[s.name], err_msg=f"{name}/{s.name}")
            for r in range(cb.world_size):
                v = view_of(s, cb, r)
                if v is None or s.name not in dst[r].shards:
                    continue
                sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
                np.testing.assert_array_equal(
                    got[sl], dst[r].shards[s.name],
                    err_msg=f"{name}/{s.name}/rank{r}")
        if name == "tp_preserve":
            # resident-skip: zero planned movement, zero executed bytes on
            # BOTH backends, aliasing pass-throughs only
            assert plan.network_bytes == 0 and plan.local_bytes == 0, arch
            assert sim_stats.executed_bytes == 0, sim_stats.executed_bytes
            assert live_stats.executed_bytes == 0, live_stats.executed_bytes
            assert ex.resident_passthroughs > 0
            # delta=False baseline physically moves every cache byte
            ex_b = LiveExecutor({s.name: s for s in specs}, live_src,
                                targets, BUDGET)
            base = ReshardEngine(plan, ex_b, staging_bytes=BUDGET,
                                 delta=False).run()
            ex_b.block_until_ready()
            assert base.resident_bytes == 0
            assert base.local_bytes == plan.resident_bytes
            assert ex_b.executed_bytes > 0
            for s in specs:
                got = np.asarray(jax.device_get(ex_b.results()[s.name]))
                np.testing.assert_array_equal(got, g[s.name])
        print("CACHE_PARITY_OK", arch, name)
print("ALL_OK")
"""


def test_cache_migration_live_matches_sim(subproc):
    out = subproc(_CACHE_PARITY_SNIPPET, n_devices=8)
    assert "ALL_OK" in out
    assert out.count("CACHE_PARITY_OK") == 6
