"""Regression: the reuse-accounting identity on partially-resident plans.

BENCH_goodput.json once reported ``skipped_bytes: 12800`` next to
``resident_layers: 0`` — not a bug in the byte counter but in the identity
readers assumed: ``skipped_bytes`` accrues per resident CELL, and a
dp-grow plan has many resident cells in layers that are not FULLY
resident. The fixed invariant is cell-level (``reuse_identity_ok``,
core/records.py) and must hold on every record the stack emits."""

from __future__ import annotations

import numpy as np

from repro.configs.base import ParallelConfig
from repro.core.intersection import plan_transfer
from repro.core.records import ReuseRecordMixin, reuse_identity_ok
from repro.core.resource_view import TensorSpec
from repro.core.streaming import (
    allocate_destination,
    execute_plan,
    materialize_rank,
)

SPECS = [
    TensorSpec("params/blocks/pos0/w", (8, 16, 32), "float32",
               ("pp", "none", "tp"), "stages", "params"),
    TensorSpec("params/embed/tok", (64, 32), "float32", ("tp", "none"),
               "first", "params"),
    TensorSpec("mu/blocks/pos0/w", (8, 16, 32), "float32",
               ("pp", "none", "tp"), "stages", "mu"),
]


def _run(ca, cb):
    plan = plan_transfer(SPECS, ca, cb, num_positions=1)
    rng = np.random.default_rng(0)
    g = {s.name: rng.normal(size=s.shape).astype(s.dtype) for s in SPECS}
    src = {r: materialize_rank(SPECS, ca, r, g) for r in range(ca.world_size)}
    dst = {r: allocate_destination(SPECS, cb, r) for r in range(cb.world_size)}
    return plan, execute_plan(plan, src, dst, staging_bytes=2048)


def test_partial_residency_skips_bytes_with_zero_resident_layers():
    """The regression shape itself: dp1tp4 -> dp2tp4 keeps every source
    cell in place on the surviving replica (resident cells, skipped bytes)
    yet fans each layer out to a new replica too — so NO layer is fully
    resident. resident_layers == 0 with skipped_bytes > 0 is correct, and
    the cell-level identity is what must hold instead."""
    plan, stats = _run(ParallelConfig(dp=1, tp=4), ParallelConfig(dp=2, tp=4))
    assert plan.resident_bytes > 0
    assert plan.resident_layers() == []  # every layer only PARTIALLY resident
    assert stats.resident_bytes == plan.resident_bytes
    assert stats.resident_cells > 0
    assert (stats.resident_bytes > 0) == (stats.resident_cells > 0)


def test_identity_across_transition_sweep():
    """Every transition — no residency, partial residency, full residency —
    satisfies the cell-level identity on the engine's StreamStats."""
    for ca, cb in [
        (ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=4)),  # none
        (ParallelConfig(dp=1, tp=4), ParallelConfig(dp=2, tp=4)),  # partial
        (ParallelConfig(dp=2, tp=2), ParallelConfig(dp=1, tp=2)),  # full
        (ParallelConfig(pp=2, tp=2), ParallelConfig(pp=1, tp=4)),  # none
    ]:
        plan, stats = _run(ca, cb)
        assert (stats.resident_bytes > 0) == (stats.resident_cells > 0), (ca, cb)
        # and the identity as records downstream will carry it
        rec = ReuseRecordMixin(
            skipped_bytes=stats.resident_bytes,
            resident_cells=stats.resident_cells,
            resident_layers=len(plan.resident_layers()),
        )
        assert reuse_identity_ok(rec)
        assert reuse_identity_ok(
            {"skipped_bytes": rec.skipped_bytes,
             "resident_cells": rec.resident_cells}
        )


def test_reuse_identity_ok_flags_the_original_bug():
    assert not reuse_identity_ok(
        {"skipped_bytes": 12800, "resident_cells": 0}
    )
    assert not reuse_identity_ok(ReuseRecordMixin(skipped_bytes=12800))
    assert reuse_identity_ok(ReuseRecordMixin())
