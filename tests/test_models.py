"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward/train step on CPU, output shapes + no NaNs; plus prefill/decode
consistency against the full forward."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import model as M

ARCHS = sorted(ASSIGNED)


def _batch(cfg, b=2, s=16, extra=1):
    toks = jax.random.randint(jax.random.key(1), (b, s + extra), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (b, 8, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, extra=0)
    b, s = batch["tokens"].shape

    logits, aux = M.forward(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    from repro.distribution.step import make_train_step
    from repro.optim import AdamWConfig, adamw_init

    step = make_train_step(cfg, AdamWConfig(learning_rate=1e-3))
    opt = adamw_init(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l[0] - l[1]).sum()),
        jax.tree_util.tree_map(lambda a, b_: (a, b_), params, new_params),
        0.0,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 2, 16
    batch_full = _batch(cfg, b, s)
    batch_pre = dict(batch_full)
    batch_pre["tokens"] = batch_full["tokens"][:, :s]

    logits_full, _ = M.forward(cfg, params, batch_full)
    last, cache, cross = M.prefill(
        cfg, params, batch_pre, cache_dtype=jnp.float32, max_seq=s + 4
    )
    assert float(jnp.abs(last[:, 0] - logits_full[:, s - 1]).max()) < 2e-4

    logits_dec, new_cache = M.decode_step(
        cfg, params, cache, batch_full["tokens"][:, s : s + 1], jnp.int32(s), cross
    )
    assert float(jnp.abs(logits_dec[:, 0] - logits_full[:, s]).max()) < 2e-4
    # cache structure preserved
    jax.tree_util.tree_map(
        lambda a, b_: None if a.shape == b_.shape else pytest.fail("cache shape"),
        cache,
        new_cache,
    )


def test_analytic_param_counts_at_full_scale():
    """Full configs land near their nameplate sizes (no allocation)."""
    expected = {
        "minitron-8b": (7.5e9, 10e9),
        "qwen2.5-14b": (13e9, 16e9),
        "gemma-7b": (8e9, 10e9),  # 8.5B with its 256k embed
        "chameleon-34b": (32e9, 36e9),
        "jamba-v0.1-52b": (45e9, 56e9),
        "mixtral-8x7b": (45e9, 48e9),
        "mamba2-2.7b": (2.4e9, 3.0e9),
    }
    for arch, (lo, hi) in expected.items():
        n = M.analytic_param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_smaller():
    cfg = get_config("mixtral-8x7b")
    total = M.analytic_param_count(cfg)
    active = M.analytic_param_count(cfg, active_only=True)
    assert active < total * 0.45  # top-2 of 8 experts + shared trunk


def test_long_context_eligibility():
    from repro.configs import shape_applicable

    ok, _ = shape_applicable(get_config("mamba2-2.7b"), "long_500k")
    assert ok
    ok, _ = shape_applicable(get_config("jamba-v0.1-52b"), "long_500k")
    assert ok
    ok, _ = shape_applicable(get_config("mixtral-8x7b"), "long_500k")
    assert ok  # sliding window => linear-attention class
    ok, reason = shape_applicable(get_config("minitron-8b"), "long_500k")
    assert not ok and "full-attention" in reason


# Decode-step cross-world invariance (subprocess, 8 host devices): at an
# identical (params, cache, pos), decode logits must agree across meshes —
# the property the elastic serving commit relies on to continue a
# generation token-for-token after migrating the cache to a new world.
_DECODE_INVARIANCE_SNIPPET = """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ParallelConfig
from repro.distribution.sharding import make_elastic_mesh
from repro.models import model as M
from repro.serve.cache_view import serve_state_specs, target_shardings_by_name
from repro.utils.pytree import tree_from_paths, tree_paths

# attn/ssm/encdec. The ssm legs stay on tp-only meshes: XLA's CPU SPMD
# partitioner miscomputes the fused xi|B|C channel concat/split in the
# mamba mixer (segment bounds 128|16|16 vs an even model-axis split) as
# soon as the mesh has a second >1 axis next to "model" — tp-only and
# data-only meshes are exact, dp2tp2/pp2tp2 are not. Pre-existing and
# decode-independent (the training forward shares _pre_ssd).
MESHES = {
    "qwen3-1.7b": [ParallelConfig(dp=1, tp=2), ParallelConfig(dp=2, tp=2)],
    "mamba2-2.7b": [ParallelConfig(dp=1, tp=2), ParallelConfig(dp=1, tp=4)],
    "seamless-m4t-large-v2": [ParallelConfig(dp=1, tp=2), ParallelConfig(dp=2, tp=2)],
}
b, s = 2, 16
for arch in sorted(MESHES):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :s]}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (b, 8, cfg.d_model), jnp.float32)
    _, cache, cross = M.prefill(cfg, params, batch,
                                cache_dtype=jnp.float32, max_seq=s + 4)
    ref, _ = M.decode_step(cfg, params, cache, toks[:, s:s+1], jnp.int32(s), cross)
    ref = np.asarray(ref)
    specs = serve_state_specs(cfg, b, s + 4, cache_dtype="float32",
                              cross_len=8 if cfg.family == "encdec" else 0)
    for pc in MESHES[arch]:
        mesh = make_elastic_mesh(pc)
        by_name = target_shardings_by_name(specs, mesh)
        def put(tree, prefix):
            return tree_from_paths(
                {p: jax.device_put(leaf, by_name[prefix + "/" + p])
                 for p, leaf in tree_paths(tree).items()}, tree)
        p_m, c_m = put(params, "params"), put(cache, "cache")
        if cfg.family == "encdec":
            x_m = put(cross, "cross")
            fn = jax.jit(lambda p, c, t, pos, x: M.decode_step(cfg, p, c, t, pos, x))
            got, _ = fn(p_m, c_m, toks[:, s:s+1], jnp.int32(s), x_m)
        else:
            fn = jax.jit(lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
            got, _ = fn(p_m, c_m, toks[:, s:s+1], jnp.int32(s))
        got = np.asarray(jax.device_get(got))
        dev = float(np.abs(got - ref).max())
        assert dev < 2e-4, (arch, pc.describe(), dev)
        # greedy continuation is mesh-invariant, not just close
        assert (got.argmax(-1) == ref.argmax(-1)).all(), (arch, pc.describe())
        print("DECODE_INVARIANT_OK", arch, pc.describe(), "dev=%.2e" % dev)
print("ALL_OK")
"""


def test_decode_step_cross_world_invariance(subproc):
    out = subproc(_DECODE_INVARIANCE_SNIPPET, n_devices=8)
    assert "ALL_OK" in out
    assert out.count("DECODE_INVARIANT_OK") == 6


def test_sliding_window_ring_cache():
    """Decode far past the window: ring buffer must stay correct."""
    cfg = get_config("mixtral-8x7b").reduced()
    assert cfg.sliding_window == 64
    params = M.init_params(cfg, jax.random.key(0))
    b, s = 1, 128  # past the 64-token window (multiple of it, ring-aligned)
    toks = jax.random.randint(jax.random.key(3), (b, s + 1), 0, cfg.vocab_size)
    logits_full, _ = M.forward(cfg, params, {"tokens": toks})
    last, cache, _ = M.prefill(
        cfg, params, {"tokens": toks[:, :s]}, cache_dtype=jnp.float32, max_seq=s + 4
    )
    # ring cache capacity equals the window
    k0 = cache["pos0"]["k"]
    assert k0.shape[2] == cfg.sliding_window
    logits_dec, _ = M.decode_step(cfg, params, cache, toks[:, s:s+1], jnp.int32(s))
    assert float(jnp.abs(logits_dec[:, 0] - logits_full[:, s]).max()) < 2e-4
