"""Simulator: DES determinism, paper-parity checks (Table 1, Figs. 6–8
bands), downtime monotonicity."""

from __future__ import annotations

import pytest

from repro.sim.cluster import PAPER_TESTBED, TPU_V5E_POD, model_state_bytes
from repro.sim.des import Simulator
from repro.sim.liver_sim import SystemKind, reconfig_downtime, volatility_run
from repro.sim.volatility import REGIMES, make_trace, paper_24h_trace


def test_des_ordering_and_determinism():
    sim = Simulator()
    log = []
    sim.schedule(2.0, log.append, "b")
    sim.schedule(1.0, log.append, "a")
    sim.schedule(2.0, log.append, "c")  # FIFO among equal timestamps

    def proc():
        yield 0.5
        log.append("p1")
        yield 1.0
        log.append("p2")

    sim.process(proc())
    sim.run()
    assert log == ["p1", "a", "p2", "b", "c"]
    assert sim.now == 2.0


def test_des_ties_never_compare_payloads():
    # equal timestamps force the heap to the tie-breaker; the monotonic
    # sequence number must decide BEFORE Python ever compares the payloads
    # (lambdas and dicts below are uncomparable: without the counter this
    # raises TypeError from heapq)
    sim = Simulator()
    log = []
    sim.schedule(1.0, lambda: log.append("first"))
    sim.schedule(1.0, lambda: log.append("second"))
    sim.schedule(1.0, log.append, {"payload": 3})  # dict arg, same instant
    sim.schedule(0.0, lambda: log.append("zero"))
    sim.run()
    assert log == ["zero", "first", "second", {"payload": 3}]


def test_des_tiebreak_is_fifo_at_scale():
    # 100 same-instant events interleaved with other timestamps: strict
    # submission order among equals, global time order overall
    sim = Simulator()
    log = []
    for i in range(100):
        sim.schedule(5.0, log.append, ("tie", i))
    sim.schedule(4.0, log.append, "before")
    sim.schedule(6.0, log.append, "after")
    sim.run()
    assert log[0] == "before" and log[-1] == "after"
    assert log[1:-1] == [("tie", i) for i in range(100)]
    assert sim.now == 6.0


def test_des_run_until_does_not_advance_past_deadline():
    sim = Simulator()
    log = []
    sim.schedule(1.0, log.append, "a")
    sim.schedule(10.0, log.append, "late")
    sim.run(until=5.0)
    assert log == ["a"] and sim.now == 5.0
    sim.run()  # the late event is still queued, not lost
    assert log == ["a", "late"] and sim.now == 10.0


def test_table1_breakdown_parity():
    d = reconfig_downtime(SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 20e9, 32, 32)
    assert d.phases["ckpt_load"] == pytest.approx(54.6, abs=1.5)
    init = d.phases["proc_spawn"] + d.phases["cuda_init"] + d.phases["dist_init"]
    assert init == pytest.approx(70.1, abs=2.0)
    assert d.total == pytest.approx(127.1, abs=3.0)


def test_fig6a_speedup_band():
    """Paper: 14x-23x over Megatron-LM Checkpoint; LiveR < ~8 s."""
    for params in (1.7e9, 7e9, 14e9, 20e9, 30e9):
        mk = reconfig_downtime(SystemKind.MEGATRON_CKPT, PAPER_TESTBED, params, 32, 32)
        lv = reconfig_downtime(SystemKind.LIVER, PAPER_TESTBED, params, 32, 32)
        speedup = mk.total / lv.total
        assert 13.0 <= speedup <= 24.0, (params, speedup)
        assert lv.total < 8.5
        assert lv.phases["switch"] < 0.5


def test_fig6b_storage_sensitivity():
    """Checkpoint systems degrade sharply at low storage bw; LiveR does not."""
    slow = reconfig_downtime(
        SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 14e9, 32, 32,
        storage_bw_override=0.25,
    )
    fast = reconfig_downtime(
        SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 14e9, 32, 32,
        storage_bw_override=2.0,
    )
    # paper reports >300 s at 0.25 Gb/s; with Table-1-exact calibration our
    # model gives ~140 s — the 8x load-time degradation trend is what the
    # figure demonstrates (absolute divergence noted in bench_storage).
    assert slow.phases["ckpt_load"] > 100
    assert slow.phases["ckpt_load"] / fast.phases["ckpt_load"] == pytest.approx(8.0, rel=0.01)
    assert slow.total / fast.total > 2.2  # fixed init costs dampen the total
    lv_slow = reconfig_downtime(
        SystemKind.LIVER, PAPER_TESTBED, 14e9, 32, 32, storage_bw_override=0.25
    )
    lv_fast = reconfig_downtime(
        SystemKind.LIVER, PAPER_TESTBED, 14e9, 32, 32, storage_bw_override=2.0
    )
    assert lv_slow.total == pytest.approx(lv_fast.total)  # storage-free


def test_volatility_ordering():
    for regime, interval in REGIMES.items():
        tr = make_trace(8 * 3600, interval, seed=2)
        g = {
            k: volatility_run(k, PAPER_TESTBED, 14e9, tr, 8 * 3600, 32).goodput
            for k in SystemKind
        }
        assert g[SystemKind.LIVER] > 0.985
        assert g[SystemKind.LIVER] > g[SystemKind.UCP] >= g[SystemKind.MEGATRON_CKPT]


def test_fig8_wasted_gpu_hours():
    tr = paper_24h_trace()
    r_m = volatility_run(SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 14e9, tr, 24 * 3600, 32)
    r_l = volatility_run(SystemKind.LIVER, PAPER_TESTBED, 14e9, tr, 24 * 3600, 32)
    assert r_m.wasted_gpu_hours > 70  # paper: "80+ GPU-hours" (trace-seed dependent)
    assert r_l.wasted_gpu_hours < 8  # paper: 4.1
    assert r_m.reconfig_pause_s / max(r_l.reconfig_pause_s, 1e-9) > 10


def test_downtime_monotone_in_model_size():
    prev = 0.0
    for params in (1e9, 5e9, 20e9, 70e9):
        t = reconfig_downtime(SystemKind.LIVER, PAPER_TESTBED, params, 32, 32).total
        assert t >= prev
        prev = t


def test_fig11_70b_1024gpu_extrapolation():
    """Paper: ~565 s cold restart vs ~11 s LiveR at 70B/1024 GPUs (50x)."""
    mk = reconfig_downtime(SystemKind.MEGATRON_CKPT, PAPER_TESTBED, 70e9, 1024, 1024)
    lv = reconfig_downtime(SystemKind.LIVER, PAPER_TESTBED, 70e9, 1024, 1024)
    assert 300 < mk.total < 900
    assert lv.total < 15
    assert mk.total / lv.total > 30
