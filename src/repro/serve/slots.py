"""Continuous-batching bookkeeping: FIFO request queue + slot allocator.

Slots are cache rows (the batch axis of the decode cache). The allocator
reuses the most-recently-freed slot first (LIFO free list — its cache row
is the one most likely still warm) and counts evictions separately from
voluntary frees: an eviction is a dropped in-flight request, the quantity
the serving benchmark gates at zero across resizes.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestQueue", "SlotAllocator", "plan_admission"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int
    submitted_s: float = 0.0
    frames: Optional[np.ndarray] = None  # (frames_len, d_model) — encdec only
    # filled by the serve loop
    slot: int = -1
    tokens: list = field(default_factory=list)  # emitted token ids
    finished: bool = False


class RequestQueue:
    """Strict-FIFO admission queue."""

    def __init__(self):
        self._q: list[Request] = []
        self._ids = itertools.count()

    def submit(
        self, prompt: np.ndarray, max_new_tokens: int, now_s: float = 0.0, frames=None
    ) -> Request:
        req = Request(
            rid=next(self._ids),
            prompt=np.asarray(prompt, dtype=np.int32),
            max_new_tokens=int(max_new_tokens),
            submitted_s=now_s,
            frames=None if frames is None else np.asarray(frames),
        )
        self._q.append(req)
        return req

    def pop(self, n: int) -> list[Request]:
        """Admit up to ``n`` requests, oldest first."""
        taken, self._q = self._q[:n], self._q[n:]
        return taken

    def __len__(self) -> int:
        return len(self._q)


class SlotAllocator:
    """Fixed pool of cache rows with LIFO reuse and eviction accounting."""

    def __init__(self, n_slots: int):
        assert n_slots > 0
        self.n_slots = n_slots
        # LIFO free list: seeded so first-ever allocations come out 0,1,2,...
        self._free: list[int] = list(range(n_slots - 1, -1, -1))
        self._in_use: set[int] = set()
        self.evictions = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> frozenset:
        return frozenset(self._in_use)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._in_use.add(slot)
        return slot

    def free(self, slot: int) -> None:
        """Voluntary release (request completed)."""
        assert slot in self._in_use, f"slot {slot} not allocated"
        self._in_use.discard(slot)
        self._free.append(slot)

    def evict(self, slot: int) -> None:
        """Forced release (in-flight request dropped) — counted."""
        self.free(slot)
        self.evictions += 1


def plan_admission(
    queue: RequestQueue, slots: SlotAllocator, now_s: float = 0.0
) -> list[Request]:
    """Admit queued requests into free slots, FIFO over requests, LIFO over
    slots. Pure bookkeeping (no device work) so admission-order policy is
    unit-testable without a model."""
    admitted = queue.pop(slots.free_count)
    for req in admitted:
        slot = slots.alloc()
        assert slot is not None
        req.slot = slot
        req.submitted_s = req.submitted_s or now_s
    return admitted
