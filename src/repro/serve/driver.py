"""Shared prefill/decode demo driver.

One copy of the prompt-batch → prefill → autoregressive-decode loop that
``launch/serve.py`` and ``examples/serve_decode.py`` used to duplicate.
Single-world (no mesh, no resizes) — the elastic path lives in
``serve.loop``/``serve.controller``; this is the minimal serving harness
the stubs needed.
"""

from __future__ import annotations

import time

from repro.configs.base import ModelConfig

__all__ = ["demo_batch", "serve_once"]


def demo_batch(cfg: ModelConfig, batch: int, prompt_len: int, frames_len: int = 16):
    """Deterministic synthetic prompt batch (keys match the seed stubs)."""
    import jax
    import jax.numpy as jnp

    out = {
        "tokens": jax.random.randint(
            jax.random.key(1), (batch, prompt_len), 0, cfg.vocab_size
        )
    }
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(
            jax.random.key(2), (batch, frames_len, cfg.d_model), jnp.float32
        )
    return out


def serve_once(
    cfg: ModelConfig,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    temperature: float = 0.0,
    seed: int = 0,
):
    """Prefill a prompt batch and decode ``gen`` tokens per request.

    Returns ``{"tokens": (batch, gen+1) np.ndarray, "prefill_s": float,
    "decode_s": float}`` — the first column is the token argmaxed from the
    prefill logits, the rest are decode-loop emissions.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import model as M

    horizon = prompt_len + gen
    params = M.init_params(cfg, jax.random.key(seed))
    inputs = demo_batch(cfg, batch, prompt_len)

    t0 = time.perf_counter()
    logits, cache, cross = M.prefill(cfg, params, inputs, max_seq=horizon)
    logits.block_until_ready()
    prefill_s = time.perf_counter() - t0

    decode = jax.jit(
        (lambda p, c, t, pos, x: M.decode_step(cfg, p, c, t, pos, x))
        if cfg.family == "encdec"
        else (lambda p, c, t, pos, x: M.decode_step(cfg, p, c, t, pos))
    )
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [cur]
    t0 = time.perf_counter()
    for i in range(gen):
        logits, cache = decode(params, cache, cur, jnp.int32(prompt_len + i), cross)
        if temperature > 0:
            key = jax.random.fold_in(jax.random.key(7), i)
            cur = jax.random.categorical(key, logits[:, -1] / temperature)[:, None]
        else:
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(cur)
    jax.block_until_ready(cur)
    decode_s = time.perf_counter() - t0
    return {
        "tokens": np.asarray(jnp.concatenate(out, axis=1)),
        "prefill_s": prefill_s,
        "decode_s": decode_s,
    }
