"""Live serving reconfiguration controller (DESIGN.md §16).

The serving counterpart of ``core/controller.py``'s ``LiveRController``,
specialised to decode state: Prepare builds (or takes warm from the shared
:class:`WorldPool`) a target serving world in the background while decode
continues on the active world; the commit lands at a decode-step boundary
mid-generation — params AND the live KV/SSD cache stream through one
intersection plan + ReshardEngine pass, then the session continues
token-for-token on the new world. Retired actives and abandoned shadow
builds are deposited back into the pool, so serving worlds are pooled
citizens exactly like training worlds.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.records import ReuseRecordMixin
from repro.core.reshard import DEFAULT_STAGING_BYTES, live_reshard_planned
from repro.core.shadow import ShadowBuilder, WorldHandle
from repro.core.world_pool import WorldPool
from repro.serve.cache_view import (
    named_serve_leaves,
    rebuild_serve_state,
    serve_plan,
    serve_state_specs,
)
from repro.serve.world import build_serve_world

__all__ = ["LiveServeController", "ServeRecord"]


@dataclass
class ServeRecord(ReuseRecordMixin):
    """One committed serving reconfiguration (mirrors ``ReconfigRecord``)."""

    gen_id: int
    src: str
    dst: str
    # decode-step index (global token position counter) the cut landed on:
    # requests decoded on the old world up to this step, on the new after
    cut_step: int = -1
    prepare_s: float = 0.0
    plan_s: float = 0.0
    pause_s: float = 0.0  # decode stalled: plan + stream + drain + rebind
    moved_bytes: int = 0
    executed_bytes: int = 0
    plan_network_bytes: int = 0
    plan_local_bytes: int = 0
    # layers whose CACHE/cross cells were all resident (the serving reuse
    # headline: tp-preserving resizes keep every live cache shard in place)
    cache_resident_layers: int = 0
    warm_hit: bool = False
    outcome: str = "committed"


@dataclass
class _Pending:
    target: ParallelConfig
    key: tuple
    handle: Optional[WorldHandle] = None  # warm pool hit
    builder: Optional[ShadowBuilder] = None  # cold shadow build
    requested_at: float = field(default_factory=time.perf_counter)

    @property
    def ready(self) -> bool:
        return self.handle is not None or self.builder.ready


class LiveServeController:
    """Owns the active serving world + params; serves resize requests."""

    def __init__(
        self,
        cfg: ModelConfig,
        parallel: ParallelConfig,
        n_slots: int,
        prompt_len: int,
        max_seq: int,
        devices=None,
        cache_dtype=jnp.float32,
        frames_len: int = 16,
        pool: Optional[WorldPool] = None,
        pool_capacity: int = 2,
        staging_bytes: int = DEFAULT_STAGING_BYTES,
        sync_prepare: bool = False,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.n_slots = n_slots
        self.prompt_len = prompt_len
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.frames_len = frames_len
        self.devices = list(devices) if devices is not None else list(jax.devices())
        self.world_pool = pool if pool is not None else WorldPool(capacity=pool_capacity)
        self.staging_bytes = staging_bytes
        self.sync_prepare = sync_prepare
        self.gen_id = 0
        self.records: list[ServeRecord] = []
        self._pending: Optional[_Pending] = None
        # one spec list serves every topology: specs are config-level, the
        # planner applies each ParallelConfig's factors at plan time
        self.specs = serve_state_specs(
            cfg,
            n_slots,
            max_seq,
            cache_dtype=cache_dtype,
            cross_len=frames_len if cfg.family == "encdec" else 0,
        )
        self.active = self._acquire(parallel)
        self.active.gen_id = self.gen_id
        # params live on the controller; host init is mesh-independent, so
        # same-seed sessions start from identical values on any topology
        from repro.models import model as M

        params = M.init_params(cfg, jax.random.key(seed))
        self.params = jax.device_put(params, self.active.shardings["params"])

    # -- world acquisition ---------------------------------------------
    def _device_subset(self, target: ParallelConfig):
        n = target.world_size
        assert n <= len(self.devices), (n, len(self.devices))
        return self.devices[:n]

    def pool_key(self, target: ParallelConfig) -> tuple:
        """Pool identity of the serving world for ``target``: everything
        shaping the compiled decode/prefill executables plus the device-set
        fingerprint. The leading tag keeps serve worlds from colliding with
        training worlds in a shared pool."""
        fingerprint = tuple(
            getattr(d, "id", i) for i, d in enumerate(self._device_subset(target))
        )
        return (
            "serve",
            self.cfg,
            target,
            fingerprint,
            self.n_slots,
            self.prompt_len,
            self.max_seq,
            str(jnp.dtype(self.cache_dtype)),
            self.frames_len,
        )

    def _build(self, target: ParallelConfig) -> WorldHandle:
        return build_serve_world(
            self.cfg,
            target,
            self.n_slots,
            self.prompt_len,
            self.max_seq,
            devices=self._device_subset(target),
            cache_dtype=self.cache_dtype,
            frames_len=self.frames_len,
        )

    def _acquire(self, target: ParallelConfig) -> WorldHandle:
        """Initial world: warm from the pool when a previous session (or
        prefetch) deposited one, else a synchronous cold build."""
        warm = self.world_pool.take(self.pool_key(target))
        if warm is not None:
            warm.timings = dict(warm.timings)
            warm.timings["warm_hit"] = True
            return warm
        return self._build(target)

    # -- Prepare --------------------------------------------------------
    def request_resize(self, target: ParallelConfig) -> None:
        """Start Prepare for ``target``; decode keeps running. A newer
        request supersedes an in-flight one (retarget): the abandoned
        build deposits its world into the pool on completion."""
        if self._pending is not None:
            self._discard_pending()
        key = self.pool_key(target)
        warm = self.world_pool.take(key)
        if warm is not None:
            self._pending = _Pending(target=target, key=key, handle=warm)
            return
        builder = ShadowBuilder(
            lambda: self._build(target),
            gen_id=self.gen_id + 1,
            on_discard=lambda h, k=key: self.world_pool.put(k, h),
        )
        builder.start()
        self._pending = _Pending(target=target, key=key, builder=builder)
        if self.sync_prepare:
            builder.result()

    def _discard_pending(self) -> None:
        p, self._pending = self._pending, None
        if p is None:
            return
        if p.handle is not None:
            self.world_pool.put(p.key, p.handle)
        else:
            p.builder.abandon()

    @property
    def resize_pending(self) -> bool:
        return self._pending is not None

    @property
    def resize_ready(self) -> bool:
        return self._pending is not None and self._pending.ready

    # -- Switch (the mid-generation commit) -----------------------------
    def commit(self, cache: Any, cross_kv: Any, cut_step: int):
        """Commit the pending resize at a decode-step boundary.

        Streams params + live cache (+ cross-KV) through one intersection
        plan on the shared engine; returns (cache, cross_kv) re-hosted on
        the new world. Token-for-token continuity is the migrated state:
        byte-identical cache rows, same positions, same params.
        """
        assert self._pending is not None, "no resize pending"
        p, self._pending = self._pending, None
        if p.handle is not None:
            handle, warm_hit = p.handle, True
            prepare_s = time.perf_counter() - p.requested_at
        else:
            handle = p.builder.result()  # blocks for any remaining Prepare
            warm_hit = False
            prepare_s = handle.timings.get("prepare_total_s", 0.0)
        handle.gen_id = self.gen_id + 1

        t_pause = time.perf_counter()
        # wave-boundary commit (no generation in flight): params-only plan
        specs = (
            self.specs
            if cache is not None
            else [s for s in self.specs if s.collection == "params"]
        )
        t0 = time.perf_counter()
        plan = serve_plan(self.cfg, specs, self.active.parallel, handle.parallel)
        plan_s = time.perf_counter() - t0
        named = named_serve_leaves(self.params, cache, cross_kv)
        dst_named, stats = live_reshard_planned(
            specs,
            plan,
            named,
            handle.shardings["by_name"],
            staging_bytes=self.staging_bytes,
        )
        params, new_cache, new_cross = rebuild_serve_state(
            dst_named, self.params, cache if cache is not None else None, cross_kv
        )

        old, old_key = self.active, self.pool_key(self.active.parallel)
        self.active, self.params, self.gen_id = handle, params, handle.gen_id
        # retired active becomes the pool's warm world for its topology
        self.world_pool.put(old_key, old)
        pause_s = time.perf_counter() - t_pause

        cache_layers = {t.layer for t in plan.tasks if t.collection in ("cache", "cross")}
        cache_moved = {
            t.layer
            for t in plan.tasks
            if t.collection in ("cache", "cross") and t.kind != "resident"
        }
        rec = ServeRecord(
            gen_id=self.gen_id,
            src=old.parallel.describe(),
            dst=handle.parallel.describe(),
            cut_step=cut_step,
            prepare_s=prepare_s,
            plan_s=plan_s,
            pause_s=pause_s,
            moved_bytes=stats.network_bytes + stats.local_bytes,
            executed_bytes=stats.executed_bytes,
            plan_network_bytes=plan.network_bytes,
            plan_local_bytes=plan.local_bytes,
            cache_resident_layers=len(cache_layers - cache_moved),
            warm_hit=warm_hit,
            reused_layers=len(plan.resident_layers()),
            resident_layers=len(plan.resident_layers()),
            resident_cells=stats.resident_cells,
            skipped_bytes=stats.resident_bytes,
            logical_bytes=stats.logical_bytes,
            wire_bytes=stats.wire_bytes,
        )
        self.records.append(rec)
        return new_cache, new_cross

    def shutdown(self, retire_to_pool: bool = True) -> None:
        """Release controller-held worlds. ``retire_to_pool`` deposits the
        active world for the next session (cross-session warm start)."""
        self._discard_pending()
        if retire_to_pool:
            self.world_pool.put(self.pool_key(self.active.parallel), self.active)
        else:
            self.active.release()
        self.active = None
