"""Continuous-batching decode loop with live mid-generation resizes.

Wave-granularity continuous batching: at each wave start, free slots admit
queued requests FIFO (``plan_admission``); the wave shares one prefill and
one scalar decode position (``decode_step`` takes a scalar ``pos`` — the
cache write slot and validity mask are global, see DESIGN.md §16 for why
per-slot positions would need model surgery). Requests that finish early
release their slot for the NEXT wave while the batch keeps decoding;
their rows' outputs are ignored.

Resize events from the elasticity trace (``core/events.ResizeEvent``,
replayed on the scheduler's virtual clock) trigger Prepare in the
background; the commit lands at the next decode-step boundary — the cut.
Requests decode on the old world up to the cut and continue token-for-token
on the new one, because the migrated cache/params are byte-identical and
greedy decode is deterministic.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.events import ResizeEvent, sort_trace
from repro.serve.controller import LiveServeController
from repro.serve.slots import plan_admission, RequestQueue, SlotAllocator

__all__ = ["ServeMetrics", "ServeSession"]


@dataclass
class ServeMetrics:
    tokens_emitted: int = 0
    wall_s: float = 0.0
    goodput_tok_s: float = 0.0
    p99_stall_s: float = 0.0
    max_stall_s: float = 0.0
    dropped: int = 0
    waves: int = 0
    commits: int = 0
    requests_served: int = 0
    stalls_s: list = field(default_factory=list)


def _p99(xs: list) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(np.ceil(0.99 * len(s))) - 1)]


class ServeSession:
    """Drives the controller's active world over a request stream + trace.

    ``step_time_s > 0`` advances the virtual clock by a fixed amount per
    decode step (deterministic replay: a trace time maps to an exact cut
    step); ``0`` uses wall time × ``time_scale``, the scheduler's idiom.
    """

    def __init__(
        self,
        controller: LiveServeController,
        time_scale: float = 1.0,
        step_time_s: float = 0.0,
    ):
        self.ctrl = controller
        self.queue = RequestQueue()
        self.slots = SlotAllocator(controller.n_slots)
        self.time_scale = time_scale
        self.step_time_s = step_time_s
        self.clock = 0.0
        self.global_step = 0  # decode steps across all waves (cut_step unit)
        self._t0 = 0.0

    def submit(self, prompt, max_new_tokens: int, frames=None):
        return self.queue.submit(
            prompt, max_new_tokens, now_s=self.clock, frames=frames
        )

    # -- event replay ---------------------------------------------------
    def _fire_due(self, events: list, ei: int) -> int:
        while ei < len(events) and self.clock >= events[ei].time_s:
            self.ctrl.request_resize(events[ei].target)
            ei += 1
        return ei

    def _tick(self) -> None:
        if self.step_time_s > 0:
            self.clock += self.step_time_s
        else:
            self.clock = (time.perf_counter() - self._t0) * self.time_scale

    def _assemble_batch(self, wave):
        import jax.numpy as jnp

        cfg, n, plen = self.ctrl.cfg, self.ctrl.n_slots, self.ctrl.prompt_len
        tokens = np.zeros((n, plen), np.int32)
        for req in wave:
            assert req.prompt.shape == (plen,), (req.prompt.shape, plen)
            tokens[req.slot] = req.prompt
        batch = {"tokens": np.asarray(tokens)}
        if cfg.family == "encdec":
            frames = np.zeros(
                (n, self.ctrl.frames_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            for req in wave:
                if req.frames is not None:
                    frames[req.slot] = req.frames
            batch["frames"] = frames
        return batch

    def _emit(self, live, cur, results, metrics):
        """Record this step's token for every in-flight request; finished
        ones free their slot for the next wave's admission."""
        still = []
        for req in live:
            req.tokens.append(int(cur[req.slot, 0]))
            metrics.tokens_emitted += 1
            if len(req.tokens) >= req.max_new_tokens:
                req.finished = True
                results[req.rid] = req.tokens
                self.slots.free(req.slot)
            else:
                still.append(req)
        return still

    def _stall(self, metrics, t_last) -> float:
        now = time.perf_counter()
        metrics.stalls_s.append(now - t_last)
        return now

    # -- the loop -------------------------------------------------------
    def run(self, trace=()) -> tuple[dict, ServeMetrics]:
        """Serve until the queue drains. Returns ({rid: [token ids]},
        metrics); committed-resize records accrue on the controller."""
        events = [e for e in sort_trace(list(trace)) if isinstance(e, ResizeEvent)]
        ei = 0
        metrics = ServeMetrics()
        results: dict[int, list[int]] = {}
        self._t0 = time.perf_counter()
        t_last = self._t0

        while len(self.queue):
            # wave boundary: fire due events; a ready resize with no
            # generation in flight commits params-only (nothing to migrate)
            ei = self._fire_due(events, ei)
            if self.ctrl.resize_ready:
                self.ctrl.commit(None, None, cut_step=self.global_step)
                metrics.commits += 1
            wave = plan_admission(self.queue, self.slots, now_s=self.clock)
            metrics.waves += 1
            live = list(wave)
            batch = self._assemble_batch(wave)

            # prefill writes the prompt into the cache; its last-token
            # logits are the wave's first emission
            logits, cache, cross = self.ctrl.active.update_fn(self.ctrl.params, batch)
            cur = np.argmax(np.asarray(logits[:, -1]), axis=-1)[:, None]
            live = self._emit(live, cur, results, metrics)
            t_last = self._stall(metrics, t_last)
            self.global_step += 1

            step_in_wave = 0
            while live:
                self._tick()
                ei = self._fire_due(events, ei)
                if self.ctrl.resize_ready:
                    # the cut: old world decoded up to here, the new world
                    # continues this very wave token-for-token
                    cache, cross = self.ctrl.commit(
                        cache, cross, cut_step=self.global_step
                    )
                    metrics.commits += 1
                pos = np.int32(self.ctrl.prompt_len + step_in_wave)
                args = (self.ctrl.params, cache, cur.astype(np.int32), pos) + (
                    (cross,) if self.ctrl.cfg.family == "encdec" else ()
                )
                logits, cache = self.ctrl.active.step_fn(*args)
                cur = np.argmax(np.asarray(logits[:, -1]), axis=-1)[:, None]
                live = self._emit(live, cur, results, metrics)
                t_last = self._stall(metrics, t_last)
                step_in_wave += 1
                self.global_step += 1

        metrics.wall_s = time.perf_counter() - self._t0
        metrics.goodput_tok_s = (
            metrics.tokens_emitted / metrics.wall_s if metrics.wall_s > 0 else 0.0
        )
        metrics.p99_stall_s = _p99(metrics.stalls_s)
        metrics.max_stall_s = max(metrics.stalls_s, default=0.0)
        metrics.dropped = self.slots.evictions
        metrics.requests_served = len(results)
        return results, metrics
