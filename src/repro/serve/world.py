"""Serving world construction — the decode analogue of ``core/shadow.py``'s
``build_train_world``, returning the same :class:`WorldHandle` so serving
worlds are first-class citizens of the warm :class:`WorldPool`:

  * ``step_fn``   — AOT-compiled batched decode step (one token per slot)
  * ``update_fn`` — AOT-compiled prefill (wave admission)
  * ``shardings`` — role-derived layouts for params/cache/cross, plus the
    by-name map the reshard executor targets at commit

Serving worlds are pp=1 (decode is a single-stage scan); tp/dp/ep vary
across resizes. Built inside a ShadowBuilder thread during Prepare, or
served warm from the pool.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.shadow import WorldHandle
from repro.serve.cache_view import serve_state_specs, target_shardings_by_name
from repro.utils.pytree import tree_from_paths, tree_paths

__all__ = ["build_serve_world"]


def _sharding_tree(by_name: dict, prefix: str, like) -> dict:
    """Per-leaf sharding pytree for ``like`` from the by-name map."""
    return tree_from_paths(
        {p: by_name[f"{prefix}/{p}"] for p in tree_paths(like)}, like
    )


def build_serve_world(
    cfg: ModelConfig,
    parallel: ParallelConfig,
    n_slots: int,
    prompt_len: int,
    max_seq: int,
    devices=None,
    cache_dtype=jnp.float32,
    frames_len: int = 16,
    aot: bool = True,
) -> WorldHandle:
    """Synchronous serving-world construction (the shadow thread's body)."""
    from repro.distribution.sharding import make_elastic_mesh
    from repro.models import kvcache
    from repro.models import model as M

    assert parallel.pp == 1, "serving worlds are single-stage (pp=1)"
    timings: dict = {}
    t0 = time.perf_counter()
    mesh = make_elastic_mesh(parallel, devices=devices)
    timings["mesh_s"] = time.perf_counter() - t0

    cross_len = frames_len if cfg.family == "encdec" else 0
    specs = serve_state_specs(
        cfg, n_slots, max_seq, cache_dtype=cache_dtype, cross_len=cross_len
    )
    by_name = target_shardings_by_name(specs, mesh)
    rep = NamedSharding(mesh, P())

    aparams = M.abstract_params(cfg)
    acache = M.abstract_cache(cfg, n_slots, max_seq, dtype=cache_dtype)
    psh = _sharding_tree(by_name, "params", aparams)
    csh = _sharding_tree(by_name, "cache", acache)
    xsh = None
    across = None
    if cfg.family == "encdec":
        across = jax.eval_shape(
            lambda: kvcache.init_cross_kv(cfg, n_slots, cross_len, cache_dtype)
        )
        xsh = _sharding_tree(by_name, "cross", across)

    if cfg.family == "encdec":
        decode_fn = jax.jit(
            lambda p, c, t, pos, x: M.decode_step(cfg, p, c, t, pos, x),
            in_shardings=(psh, csh, rep, rep, xsh),
            out_shardings=(rep, csh),
        )
    else:
        decode_fn = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos),
            in_shardings=(psh, csh, rep, rep),
            out_shardings=(rep, csh),
        )
    prefill_fn = jax.jit(
        lambda p, b: M.prefill(cfg, p, b, cache_dtype=cache_dtype, max_seq=max_seq),
        in_shardings=(psh, rep),
        out_shardings=(rep, csh, xsh),
    )

    step_fn, update_fn = decode_fn, prefill_fn
    if aot:
        atok = jax.ShapeDtypeStruct((n_slots, 1), jnp.int32)
        apos = jax.ShapeDtypeStruct((), jnp.int32)
        dargs = (aparams, acache, atok, apos) + (
            (across,) if cfg.family == "encdec" else ()
        )
        abatch = {"tokens": jax.ShapeDtypeStruct((n_slots, prompt_len), jnp.int32)}
        if cfg.family == "encdec":
            abatch["frames"] = jax.ShapeDtypeStruct(
                (n_slots, frames_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        t0 = time.perf_counter()
        lowered_d = decode_fn.lower(*dargs)  # mock-warmup analogue
        lowered_p = prefill_fn.lower(aparams, abatch)
        timings["lower_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        step_fn = lowered_d.compile()  # communicator-setup analogue
        update_fn = lowered_p.compile()
        timings["compile_s"] = time.perf_counter() - t0

    return WorldHandle(
        parallel=parallel,
        mesh=mesh,
        step_fn=step_fn,
        shardings={
            "by_name": by_name,
            "params": psh,
            "cache": csh,
            "cross": xsh,
            "replicated": rep,
        },
        timings=timings,
        update_fn=update_fn,
        plan_bundle=specs,
    )
