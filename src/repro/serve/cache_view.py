"""Resource view of the serving state (params + KV/SSD cache).

The serving analogue of ``core/resource_view.py``: every decode-cache leaf
(``models/kvcache.py`` layout) gets a :class:`TensorSpec` so cache state is
planned and moved by the SAME intersection-planner → ReshardEngine pipeline
as parameters — including delta classification, so a tp-preserving resize
adopts resident cache shards instead of re-streaming them.

Role assignment (the cache-migration invariant, DESIGN.md §16): the batch
(slot) axis carries role ``none`` — the cache is replicated across the
non-tp mesh factors, mirroring ``param_shardings(serving=True)`` which
replicates the embed dim. This is what makes residency reachable: with no
``dp`` role anywhere in the serving state, a resize that preserves the tp
degree classifies every cell resident (identical views on surviving
ranks), so the commit moves zero bytes. A ``dp``-split batch axis would
make full residency impossible for any world-size-changing resize.

Physical shardings are derived from the SAME roles (:func:`role_sharding`),
so the planner's classification and the device layout cannot disagree —
``LiveExecutor._adopt_resident`` then aliases buffers instead of copying.
"""

from __future__ import annotations

from typing import Any, Optional

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.intersection import TransferPlan, plan_transfer
from repro.core.resource_view import TensorSpec, build_tensor_specs
from repro.utils.pytree import tree_from_paths, tree_paths

__all__ = [
    "ROLE_AXIS",
    "cache_tensor_specs",
    "named_serve_leaves",
    "rebuild_serve_state",
    "role_sharding",
    "serve_plan",
    "serve_state_specs",
    "target_shardings_by_name",
]

# spec role -> mesh axis (make_elastic_mesh axis names)
ROLE_AXIS = {"pp": "pipe", "tp": "model", "dp": "data", "ep": "expert", "none": None}


def _dt(dtype) -> str:
    return np.dtype(dtype).name


def cache_tensor_specs(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    cache_dtype="float32",
    cross_len: int = 0,
) -> list[TensorSpec]:
    """Specs for the decode cache pytree (+ cross-attention KV for encdec).

    Shapes mirror ``kvcache.init_cache``/``init_cross_kv`` exactly; names
    (``cache/pos{j}/k``, ``cross/pos{j}/k``) carry the ``/pos{j}/`` marker
    the planner's layer-granular streaming keys on, so cache cells land in
    the same global layer ids as the params of that block position.
    """
    from repro.models import ssm as ssm_mod
    from repro.models.kvcache import cache_capacity
    from repro.models.transformer import block_program, n_periods

    prog = block_program(cfg)
    np_ = n_periods(cfg)
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    T = cache_capacity(cfg, max_seq)
    specs: list[TensorSpec] = []
    for j, (mixer, _) in enumerate(prog):
        if mixer == "attn":
            for leaf in ("k", "v"):
                specs.append(
                    TensorSpec(
                        name=f"cache/pos{j}/{leaf}",
                        shape=(np_, batch, T, kh, hd),
                        dtype=_dt(cache_dtype),
                        roles=("pp", "none", "none", "tp", "none"),
                        stage_scope="stages",
                        collection="cache",
                    )
                )
        else:
            _, h, n, conv_ch = ssm_mod.ssm_dims(cfg)
            specs.append(
                TensorSpec(
                    name=f"cache/pos{j}/ssd",
                    shape=(np_, batch, h, ssm_mod.SSM_HEAD_DIM, n),
                    dtype="float32",
                    roles=("pp", "none", "tp", "none", "none"),
                    stage_scope="stages",
                    collection="cache",
                )
            )
            specs.append(
                TensorSpec(
                    name=f"cache/pos{j}/conv",
                    shape=(np_, batch, ssm_mod.CONV_WIDTH - 1, conv_ch),
                    dtype="float32",
                    roles=("pp", "none", "none", "tp"),
                    stage_scope="stages",
                    collection="cache",
                )
            )
    if cfg.family == "encdec":
        assert cross_len > 0, "encdec serve state needs the encoder length"
        for j in range(len(prog)):
            for leaf in ("k", "v"):
                specs.append(
                    TensorSpec(
                        name=f"cross/pos{j}/{leaf}",
                        shape=(np_, batch, cross_len, kh, hd),
                        dtype=_dt(cache_dtype),
                        roles=("pp", "none", "none", "tp", "none"),
                        stage_scope="stages",
                        collection="cross",
                    )
                )
    return specs


def serve_state_specs(
    cfg: ModelConfig,
    batch: int,
    max_seq: int,
    cache_dtype="float32",
    cross_len: int = 0,
) -> list[TensorSpec]:
    """Params + cache (+ cross-KV) — the full migratable serving state.

    Params come from the training resource view with ``include_optimizer=
    False``; serving param specs carry no ``dp`` role, so params are fully
    resident under any tp/pp-preserving resize, like the cache.
    """
    return build_tensor_specs(cfg, include_optimizer=False) + cache_tensor_specs(
        cfg, batch, max_seq, cache_dtype=cache_dtype, cross_len=cross_len
    )


def role_sharding(spec: TensorSpec, mesh: Mesh) -> NamedSharding:
    """Physical sharding derived from the spec's roles, with the standard
    divisibility fallback (mirroring ``_spec_for_axes``): a dim the mesh
    axis does not divide evenly is replicated — the planner still uses
    balanced splits there, and the executor operates on global arrays, so
    only the zero-copy fast path (not correctness) is at stake."""
    parts = []
    for d, role in enumerate(spec.roles):
        ax = ROLE_AXIS[role]
        if ax is not None and spec.shape[d] % mesh.shape[ax] != 0:
            ax = None
        parts.append(ax)
    return NamedSharding(mesh, P(*parts))


def target_shardings_by_name(
    specs: list[TensorSpec], mesh: Mesh
) -> dict[str, NamedSharding]:
    return {s.name: role_sharding(s, mesh) for s in specs}


def serve_plan(
    cfg: ModelConfig,
    specs: list[TensorSpec],
    cfg_src: ParallelConfig,
    cfg_dst: ParallelConfig,
    allowed_src=None,
) -> TransferPlan:
    """Intersection plan for a serving resize — one plan covers params and
    cache together, so both stream through one engine pass at commit."""
    from repro.models.transformer import block_program

    return plan_transfer(
        specs,
        cfg_src,
        cfg_dst,
        source_policy="nearest",
        layer_granular=True,
        num_positions=len(block_program(cfg)),
        allowed_src=allowed_src,
    )


def named_serve_leaves(
    params: Any, cache: Optional[Any] = None, cross_kv: Optional[Any] = None
) -> dict[str, Any]:
    """Flatten live serving state into the resource view's tensor names.

    ``cache=None`` covers wave-boundary commits: no generation in flight,
    so only params migrate."""
    named: dict[str, Any] = {}
    for path, leaf in tree_paths(params).items():
        named[f"params/{path}"] = leaf
    for path, leaf in tree_paths(cache or {}).items():
        named[f"cache/{path}"] = leaf
    if cross_kv is not None:
        for path, leaf in tree_paths(cross_kv).items():
            named[f"cross/{path}"] = leaf
    return named


def rebuild_serve_state(
    named: dict[str, Any], params_like: Any, cache_like: Any = None, cross_like: Any = None
):
    """Inverse of :func:`named_serve_leaves`. Returns (params, cache, cross)."""
    params = tree_from_paths(
        {p: named[f"params/{p}"] for p in tree_paths(params_like)}, params_like
    )
    cache = None
    if cache_like is not None:
        cache = tree_from_paths(
            {p: named[f"cache/{p}"] for p in tree_paths(cache_like)}, cache_like
        )
    cross = None
    if cross_like is not None:
        cross = tree_from_paths(
            {p: named[f"cross/{p}"] for p in tree_paths(cross_like)}, cross_like
        )
    return params, cache, cross
