"""Elastic decode serving (DESIGN.md §16).

Continuous-batching serve loop over a pool-warmed :class:`WorldHandle`,
with live mid-generation resizes: the KV/SSD cache pytree is planned and
streamed by the same intersection-planner → ReshardEngine pipeline as
parameters, so in-flight requests survive topology changes token-for-token
instead of being dropped and re-prefilled.
"""

from repro.serve.cache_view import (
    cache_tensor_specs,
    named_serve_leaves,
    rebuild_serve_state,
    role_sharding,
    serve_plan,
    serve_state_specs,
    target_shardings_by_name,
)
from repro.serve.controller import LiveServeController, ServeRecord
from repro.serve.driver import demo_batch, serve_once
from repro.serve.loop import ServeMetrics, ServeSession
from repro.serve.slots import plan_admission, Request, RequestQueue, SlotAllocator
from repro.serve.world import build_serve_world

__all__ = [
    "LiveServeController",
    "Request",
    "RequestQueue",
    "ServeMetrics",
    "ServeRecord",
    "ServeSession",
    "SlotAllocator",
    "build_serve_world",
    "cache_tensor_specs",
    "demo_batch",
    "named_serve_leaves",
    "plan_admission",
    "rebuild_serve_state",
    "role_sharding",
    "serve_once",
    "serve_plan",
    "serve_state_specs",
    "target_shardings_by_name",
]
