"""AdamW + cosine schedule + global-norm clipping in pure JAX.

(optax is not available in this container; this implementation matches the
standard decoupled-weight-decay AdamW.) Optimizer moments (mu, nu) mirror the
parameter tree and inherit its logical sharding axes — they are first-class
tensors in the Abstract Resource View, so LiveR reshapes them alongside the
parameters (the paper's App. A.2.1 formalization includes optimizer states
explicitly).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def optimizer_logical_axes(param_axes):
    """Optimizer state axes mirror the param axes; count is replicated."""
    return {"mu": param_axes, "nu": param_axes, "count": ()}


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * scale


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)
    )
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = cosine_lr(cfg, count)

    gnorm = global_norm(grads)
    clip_scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * clip_scale, grads
    )

    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    new_mu = jax.tree_util.tree_map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, opt_state["mu"], grads
    )
    new_nu = jax.tree_util.tree_map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g),
        opt_state["nu"],
        grads,
    )

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, new_mu, new_nu)
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
