from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_lr,
    global_norm,
    optimizer_logical_axes,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_lr",
    "global_norm",
    "optimizer_logical_axes",
]
