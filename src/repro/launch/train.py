"""Elastic training launcher.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python -m repro.launch.train \\
        --arch qwen3-1.7b --reduced --dp 2 --tp 2 --steps 60 \\
        --resize 20:dp2,tp4 --resize 40:dp1,tp4

Each ``--resize STEP:SPEC`` schedules a live reconfiguration request at that
step; the switch lands at the first iteration boundary after the shadow
world is ready (invariant I3). ``--failstop STEP:SPEC`` injects an
unannounced failure handled via checkpoint fallback (invariant I4).
"""

from __future__ import annotations

import argparse
import json
import time


def parse_parallel(spec: str):
    """'dp2,tp4' -> ParallelConfig; 'auto8' -> 8 (device count; the
    topology search picks the layout — paper §2.3(D) integration)."""
    from repro.configs.base import ParallelConfig

    if spec.startswith("auto"):
        return int(spec[4:])
    kv = {}
    for part in spec.split(","):
        k = part.rstrip("0123456789")
        v = int(part[len(k):])
        kv[k] = v
    return ParallelConfig(**kv)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--pp", type=int, default=1)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none", choices=["none", "int8_ef"])
    ap.add_argument("--overlap", default="stop_copy", choices=["stop_copy", "stream"],
                    help="reconfiguration transfer mode: stop-copy pause or "
                    "overlapped layer streaming with split-step commit")
    ap.add_argument("--stream-k", type=int, default=4,
                    help="layers pre-copied per iteration boundary (overlap=stream)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=25)
    ap.add_argument("--resize", action="append", default=[], metavar="STEP:SPEC")
    ap.add_argument("--failstop", default=None, metavar="STEP:SPEC")
    ap.add_argument("--out", default=None, help="write run record JSON here")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import ParallelConfig
    from repro.core.controller import LiveRController
    from repro.optim import AdamWConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    parallel = ParallelConfig(dp=args.dp, pp=args.pp, tp=args.tp)
    opt = AdamWConfig(
        learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
        total_steps=args.steps,
    )
    print(f"[train] {cfg.name} {parallel.describe()} seq={args.seq} "
          f"batch={args.batch} steps={args.steps}", flush=True)
    ctrl = LiveRController(
        cfg, parallel, opt, seq_len=args.seq, global_batch=args.batch,
        ckpt_dir=args.ckpt_dir, ckpt_interval=args.ckpt_interval,
        microbatches=args.microbatches, compression=args.compression,
        overlap=args.overlap, stream_k=args.stream_k,
    )
    resizes = sorted(
        (int(s.split(":")[0]), parse_parallel(s.split(":")[1])) for s in args.resize
    )
    failstop = None
    if args.failstop:
        st, spec = args.failstop.split(":")
        failstop = (int(st), parse_parallel(spec))

    losses = []
    t0 = time.perf_counter()
    while ctrl.step < args.steps:
        while resizes and resizes[0][0] <= ctrl.step:
            _, target = resizes.pop(0)
            if isinstance(target, int):  # auto<N>: search picks the layout
                from repro.core.topology_search import best_target

                target = best_target(
                    cfg, target, args.batch, args.seq,
                    current=ctrl.world.parallel, transition_weight=1e-9,
                )
                print(f"[search] chose {target.describe()} for the new world",
                      flush=True)
            print(f"[event] step {ctrl.step}: resize -> {target.describe()} "
                  "(shadow prepare in background)", flush=True)
            ctrl.request_resize(target)
        if failstop and failstop[0] == ctrl.step:
            print(f"[event] step {ctrl.step}: FAIL-STOP -> "
                  f"{failstop[1].describe()}", flush=True)
            rec = ctrl.fail_stop_recover(failstop[1])
            print(f"[event] recovered via {rec.mode} at step {ctrl.step} "
                  f"in {rec.total_pause_s:.2f}s", flush=True)
            failstop = None
        before = len(ctrl.records)
        losses += ctrl.train_steps(1)
        if len(ctrl.records) > before:
            r = ctrl.records[-1]
            print(f"[switch] step {ctrl.step}: {r.src} -> {r.dst} "
                  f"pause={r.total_pause_s*1e3:.1f}ms "
                  f"(prepare {r.prepare_s:.1f}s overlapped, "
                  f"moved {r.moved_bytes/1e6:.1f}MB)", flush=True)
        if ctrl.step % 10 == 0:
            print(f"  step {ctrl.step:5d} loss={losses[-1]:.4f} "
                  f"world={ctrl.world.parallel.describe()}", flush=True)

    wall = time.perf_counter() - t0
    print(f"[done] {args.steps} steps in {wall:.1f}s; "
          f"goodput={ctrl.ledger.goodput*100:.2f}% "
          f"pause_total={ctrl.ledger.pause_seconds:.3f}s "
          f"reconfigs={len(ctrl.records)}", flush=True)
    if args.out:
        rec = {
            "arch": cfg.name,
            "losses": losses,
            "goodput": ctrl.ledger.goodput,
            "pause_seconds": ctrl.ledger.pause_seconds,
            "reconfigs": [
                {
                    "src": r.src, "dst": r.dst, "mode": r.mode,
                    "prepare_s": r.prepare_s, "pause_s": r.total_pause_s,
                    "moved_bytes": r.moved_bytes,
                }
                for r in ctrl.records
            ],
            "iteration_times": ctrl.iteration_times,
        }
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)


if __name__ == "__main__":
    main()
