import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

Lowers + compiles every (architecture × input-shape) cell against the
production mesh — 16×16 single-pod and 2×16×16 multi-pod — and records
memory analysis, cost analysis and collective bytes for the roofline table.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import because jax locks the device count at first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --sweep --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --sweep --multi-pod

``--sweep`` spawns one subprocess per cell (isolation: a single cell's
failure or memory growth cannot poison the rest) and caches results as JSON.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


def _lower_cell(cfg, shape, mesh, opts: dict):
    """Build + lower + compile one cell; returns (compiled, aux_info)."""
    import jax

    from repro.models import model as M
    from repro.optim import AdamWConfig, adamw_init

    aparams = M.abstract_params(cfg)
    if shape.kind == "train":
        from repro.distribution.step import jit_train_step

        chips = int(mesh.devices.size)
        dp_total = chips // 16
        microbatches = opts.get(
            "microbatches", max(1, shape.global_batch // (dp_total * 2))
        )
        jitted, _ = jit_train_step(
            cfg,
            mesh,
            AdamWConfig(),
            shape.global_batch,
            microbatches=microbatches,
            remat=opts.get("remat", "full"),
            hint_version=opts.get("hints"),
            grad_accum=opts.get("grad_accum", "explicit"),
        )
        aopt = jax.eval_shape(lambda: adamw_init(aparams))
        abatch = M.input_specs(cfg, shape)
        args = (aparams, aopt, abatch)
        used = {"microbatches": microbatches}
    elif shape.kind == "prefill":
        from repro.distribution.step import jit_prefill_step

        jitted, _ = jit_prefill_step(
            cfg, mesh, shape.global_batch, shape.seq_len,
            hint_version=opts.get("hints"),
        )
        args = (aparams, M.input_specs(cfg, shape))
        used = {}
    else:
        from repro.distribution.step import jit_decode_step

        jitted, _ = jit_decode_step(
            cfg, mesh, shape.global_batch, shape.seq_len,
            serve_params=opts.get("serve_params", "fsdp"),
        )
        specs = M.input_specs(cfg, shape)
        args = [aparams, specs["cache"], specs["tokens"], specs["pos"]]
        if cfg.family == "encdec":
            args.append(specs["cross_kv"])
        args = tuple(args)
        used = {}
    return jitted, args, used


def _probe_costs(cfg, shape, mesh, opts: dict) -> dict:
    """3-probe linear cost model: XLA cost analysis counts a while body once,
    so we compile tiny UNROLLED variants (N periods ∈ {1,2}, microbatches M ∈
    {1,2}) and recover  X(N,M) = M·(N·body + per_mb) + step_out  exactly for
    flops / bytes / per-kind collective bytes."""
    import dataclasses

    from repro.models.transformer import block_program, n_periods
    from repro.roofline.analysis import collective_bytes_from_hlo

    period = len(block_program(cfg))
    n_full = n_periods(cfg)

    def probe(k_periods: int, m: int) -> dict:
        pcfg = dataclasses.replace(
            cfg,
            num_layers=period * k_periods,
            encoder_layers=k_periods if cfg.encoder_layers else 0,
        )
        shape_opts = dict(opts)
        shape_opts["microbatches"] = m
        os.environ["REPRO_SCAN_UNROLL"] = "1"
        try:
            jitted, args, _ = _lower_cell(pcfg, shape, mesh, shape_opts)
            compiled = jitted.lower(*args).compile()
        finally:
            os.environ.pop("REPRO_SCAN_UNROLL", None)
        cost = compiled.cost_analysis() or {}
        per = collective_bytes_from_hlo(compiled.as_text())
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            **{f"coll_{k}": float(v) for k, v in per.items()},
        }

    is_train = shape.kind == "train"
    x11 = probe(1, 1)
    x21 = probe(2, 1)
    # M-independence check: per-microbatch work is linear in tokens, so
    # flops/bytes are invariant to the accumulation factor (the x12 probe
    # validates this per cell; the tiny per-microbatch accumulate adds and
    # per-step optimizer work live in x11 already).
    x12 = probe(1, 2) if is_train else None

    chips = int(mesh.devices.size)
    dp_total = chips // 16
    m_full = (
        opts.get("microbatches", max(1, shape.global_batch // (dp_total * 2)))
        if is_train
        else 1
    )

    # X(N) = x11 + (N-1) * body ;  body = x21 - x11
    out = {}
    for key in x11:
        body = x21[key] - x11[key]
        out[key] = max(x11[key] + (n_full - 1) * body, 0.0)
    out["probe_model"] = {
        "n_periods": n_full, "microbatches": m_full,
        "x11": x11, "x21": x21, "x12": x12,
    }
    return out


def _run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
              save_hlo: bool = False, opts: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.models import model as M
    from repro.optim import AdamWConfig, adamw_init
    from repro.roofline.analysis import roofline_terms

    opts = opts or {}
    cfg = get_config(arch)
    if opts.get("param_dtype"):
        import dataclasses

        cfg = dataclasses.replace(cfg, param_dtype=opts["param_dtype"])
    shape = SHAPES[shape_name]
    mesh_desc = "pod2x16x16" if multi_pod else "pod16x16"
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_desc,
            "status": "skipped", "reason": reason,
        }

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    t_all = time.perf_counter()

    n_active = M.analytic_param_count(cfg, active_only=True)
    n_total = M.analytic_param_count(cfg)
    if shape.kind == "train":
        model_flops = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_active * shape.global_batch

    # 1) full compile: proves the cell lowers/fits; memory analysis
    jitted, args, used_opts = _lower_cell(cfg, shape, mesh, opts)
    t0 = time.perf_counter()
    lowered = jitted.lower(*args)
    lower_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    mem_dict = {}
    if mem is not None:
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(mem, k, None)
            if v is not None:
                mem_dict[k] = int(v)
    hlo = compiled.as_text()

    # 2) cost probes (trip-count-exact flops/bytes/collectives).
    # cost_analysis numbers are for the per-partition (per-chip) module;
    # scale by chip count so the roofline formulas (which divide by chips)
    # see global totals.
    probed = _probe_costs(cfg, shape, mesh, opts)
    cost_for_report = {
        "flops": probed["flops"] * chips,
        "bytes accessed": probed["bytes"] * chips,
    }
    report = roofline_terms(
        arch, shape_name, mesh_desc, chips, cost_for_report, "", model_flops
    )
    report.per_collective = {
        k[len("coll_"):]: v * chips for k, v in probed.items() if k.startswith("coll_")
    }
    report.collective_bytes = int(sum(report.per_collective.values()))

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_desc,
        "status": "ok",
        "chips": chips,
        "params_total": n_total,
        "params_active": n_active,
        "lower_s": lower_s,
        "compile_s": compile_s,
        "total_s": time.perf_counter() - t_all,
        "memory_analysis": mem_dict,
        "probe_model": probed["probe_model"],
        "opts": {**opts, **used_opts},
        **report.to_dict(),
    }
    if save_hlo and out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_desc}"
        with open(os.path.join(out_dir, f"hlo_{tag}.txt"), "w") as f:
            f.write(hlo)
    return result


def _result_path(out_dir: str, arch: str, shape: str, mesh_desc: str, tag: str = "") -> str:
    suffix = f"_{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}_{shape}_{mesh_desc}{suffix}.json")


def sweep(out_dir: str, multi_pod: bool, archs=None, shapes=None, force=False) -> None:
    from repro.configs import ASSIGNED, SHAPES

    os.makedirs(out_dir, exist_ok=True)
    archs = archs or list(ASSIGNED)
    shapes = shapes or list(SHAPES)
    mesh_desc = "pod2x16x16" if multi_pod else "pod16x16"
    todo = []
    for a in archs:
        for s in shapes:
            p = _result_path(out_dir, a, s, mesh_desc)
            if force or not os.path.exists(p):
                todo.append((a, s, p))
    print(f"[sweep] {len(todo)} cells to run ({mesh_desc})", flush=True)
    for i, (a, s, p) in enumerate(todo):
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", a, "--shape", s, "--out", out_dir,
        ]
        if multi_pod:
            cmd.append("--multi-pod")
        print(f"[sweep {i+1}/{len(todo)}] {a} x {s} ({mesh_desc})", flush=True)
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True)
        dt = time.time() - t0
        if r.returncode != 0:
            err = {
                "arch": a, "shape": s, "mesh": mesh_desc, "status": "error",
                "stderr": r.stderr[-4000:], "seconds": dt,
            }
            with open(p, "w") as f:
                json.dump(err, f, indent=2)
            print(f"  ERROR after {dt:.0f}s: {r.stderr.splitlines()[-1] if r.stderr else '?'}", flush=True)
        else:
            print(f"  done in {dt:.0f}s", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--opts", default="{}", help="JSON dict: microbatches/remat/...")
    args = ap.parse_args()

    if args.sweep:
        archs = [args.arch] if args.arch else None
        shapes = [args.shape] if args.shape else None
        sweep(args.out, args.multi_pod, archs=archs, shapes=shapes, force=args.force)
        return

    assert args.arch and args.shape, "--arch and --shape required (or --sweep)"
    mesh_desc = "pod2x16x16" if args.multi_pod else "pod16x16"
    try:
        res = _run_cell(
            args.arch, args.shape, args.multi_pod, args.out,
            save_hlo=args.save_hlo, opts=json.loads(args.opts),
        )
    except Exception:
        res = {
            "arch": args.arch, "shape": args.shape, "mesh": mesh_desc,
            "status": "error", "stderr": traceback.format_exc()[-4000:],
        }
    os.makedirs(args.out, exist_ok=True)
    path = _result_path(args.out, args.arch, args.shape, mesh_desc, args.tag)
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps({k: v for k, v in res.items() if k not in ("per_collective",)}, indent=2))
    if res["status"] == "error":
        print(res.get("stderr", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
