"""Batched decode serving launcher (prefill + autoregressive decode loop).

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \\
        --batch 4 --prompt-len 32 --gen 16

Thin front-end over :func:`repro.serve.driver.serve_once`; the elastic
serving path (resizes, cache migration) is exercised by
``benchmarks/bench_serve_goodput.py``.
"""

from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.serve.driver import serve_once

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    out = serve_once(
        cfg,
        batch=args.batch,
        prompt_len=args.prompt_len,
        gen=args.gen,
        temperature=args.temperature,
    )
    toks = out["tokens"]
    print(f"[prefill] {args.batch}x{args.prompt_len} tokens in {out['prefill_s']:.2f}s")
    print(f"[decode] {args.gen} steps x batch {args.batch} in {out['decode_s']:.2f}s "
          f"({args.gen*args.batch/out['decode_s']:.1f} tok/s incl. first-step compile)")
    print("[sample] first request tokens:", [int(t) for t in toks[0][:12]])


if __name__ == "__main__":
    main()
