"""Batched decode serving launcher (prefill + autoregressive decode loop).

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \\
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.base import ParallelConfig
    from repro.distribution.sharding import make_elastic_mesh
    from repro.models import model as M

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    horizon = args.prompt_len + args.gen
    rng = jax.random.key(0)
    params = M.init_params(cfg, rng)
    tokens = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.key(2), (args.batch, 16, cfg.d_model), jnp.float32
        )

    t0 = time.perf_counter()
    logits, cache, cross = M.prefill(cfg, params, batch, max_seq=horizon)
    logits.block_until_ready()
    prefill_s = time.perf_counter() - t0
    print(f"[prefill] {args.batch}x{args.prompt_len} tokens in {prefill_s:.2f}s")

    decode = jax.jit(
        lambda p, c, t, pos, x: M.decode_step(cfg, p, c, t, pos, x)
        if cfg.family == "encdec"
        else M.decode_step(cfg, p, c, t, pos)
    )
    out_tokens = []
    cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.perf_counter()
    for i in range(args.gen):
        pos = jnp.int32(args.prompt_len + i)
        logits, cache = decode(params, cache, cur, pos, cross)
        if args.temperature > 0:
            key = jax.random.fold_in(jax.random.key(7), i)
            cur = jax.random.categorical(key, logits[:, -1] / args.temperature)[:, None]
        else:
            cur = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(cur)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    toks = jnp.concatenate(out_tokens, axis=1)
    print(f"[decode] {args.gen} steps x batch {args.batch} in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s incl. first-step compile)")
    print("[sample] first request tokens:", [int(t) for t in toks[0][:12]])


if __name__ == "__main__":
    main()
