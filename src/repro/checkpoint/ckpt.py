"""Manifest-based checkpointing with load-time resharding.

* ``save_checkpoint`` writes one ``.npy`` per logical tensor + a JSON
  manifest (step, shapes, dtypes). Tensors are written in *global* logical
  layout, so loading under ANY parallel configuration is a pure slicing
  problem — this load-time resharding is exactly what UCP/ByteCheckpoint
  provide and is our checkpoint-reshape (UCP) baseline.
* ``load_checkpoint`` memory-maps the files and ``device_put``s each tensor
  with the target sharding (XLA slices per device; no host-side full copy
  beyond the mmap window).
* ``AsyncCheckpointer`` snapshots to host in the caller's thread (bounded by
  one tensor at a time) and writes in a daemon thread — durable-checkpoint
  cadence for LiveR's fail-stop fallback (invariant I4) without pausing
  training for disk I/O.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.pytree import tree_paths, tree_from_paths

MANIFEST = "manifest.json"


def _sanitize(path: str) -> str:
    return path.replace("/", "__")


def save_checkpoint(
    ckpt_dir: str, step: int, state: Any, extra: Optional[dict] = None
) -> float:
    """Synchronous save. Returns seconds spent."""
    t0 = time.perf_counter()
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    flat = tree_paths(state)
    manifest = {"step": step, "tensors": {}, "extra": extra or {}}
    for path, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = _sanitize(path) + ".npy"
        np.save(os.path.join(tmp_dir, fname), arr)
        manifest["tensors"][path] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "file": fname,
        }
    with open(os.path.join(tmp_dir, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)  # atomic publish
    return time.perf_counter() - t0


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def load_checkpoint(
    ckpt_dir: str,
    like: Any,
    target_shardings: Any = None,
    step: Optional[int] = None,
) -> tuple[Any, int, float]:
    """Load (with load-time resharding when ``target_shardings`` is given).

    Returns (state, step, seconds).
    """
    t0 = time.perf_counter()
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints under {ckpt_dir}"
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(step_dir, MANIFEST)) as f:
        manifest = json.load(f)
    flat_like = tree_paths(like)
    flat_sh = tree_paths(target_shardings) if target_shardings is not None else None
    out = {}
    for path, leaf in flat_like.items():
        meta = manifest["tensors"][path]
        arr = np.load(os.path.join(step_dir, meta["file"]), mmap_mode="r")
        arr = arr.astype(leaf.dtype) if str(arr.dtype) != str(leaf.dtype) else arr
        if flat_sh is not None:
            out[path] = jax.device_put(np.asarray(arr), flat_sh[path])
        else:
            out[path] = jax.numpy.asarray(np.asarray(arr))
    state = tree_from_paths(out, like)
    return state, step, time.perf_counter() - t0


class AsyncCheckpointer:
    """Overlapped checkpointing: snapshot-to-host inline (one tensor in
    flight), disk write in a background thread."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_save_seconds: Optional[float] = None

    def save(self, step: int, state: Any, extra: Optional[dict] = None) -> None:
        self.wait()
        # snapshot: device -> host, leaf-streamed
        flat = tree_paths(state)
        host = {p: np.asarray(jax.device_get(l)) for p, l in flat.items()}

        def _write():
            t0 = time.perf_counter()
            step_dir = os.path.join(self.ckpt_dir, f"step_{step:08d}")
            tmp_dir = step_dir + ".tmp"
            try:
                os.makedirs(tmp_dir, exist_ok=True)
                manifest = {"step": step, "tensors": {}, "extra": extra or {}}
                for path, arr in host.items():
                    fname = _sanitize(path) + ".npy"
                    np.save(os.path.join(tmp_dir, fname), arr)
                    manifest["tensors"][path] = {
                        "shape": list(arr.shape),
                        "dtype": str(arr.dtype),
                        "file": fname,
                    }
                with open(os.path.join(tmp_dir, MANIFEST), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(step_dir):
                    shutil.rmtree(step_dir)
                os.rename(tmp_dir, step_dir)
                self.last_save_seconds = time.perf_counter() - t0
            except BaseException as e:  # surfaced at the next save()/wait()
                self._error = e
                shutil.rmtree(tmp_dir, ignore_errors=True)

        # non-daemon: a daemon writer killed at interpreter exit leaves a
        # truncated .tmp dir and no published step; Python joins
        # non-daemon threads, so the atomic rename always completes
        self._thread = threading.Thread(target=_write, daemon=False)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight write; re-raise its failure here (not in the
        writer thread, where it would vanish). A raised error means the step
        being written is NOT durable — an older published step_* may be."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint write to {self.ckpt_dir} failed; the "
                "latest published step (if any) is older"
            ) from err
