"""repro: a JAX reproduction + extension of LiveR (live reconfiguration for
elastic model training). See DESIGN.md for the system inventory."""

__version__ = "1.0.0"
