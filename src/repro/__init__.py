"""repro: a JAX reproduction + extension of LiveR (live reconfiguration for
elastic model training). See DESIGN.md for the system inventory."""

import jax as _jax

# Sharding-invariant RNG: with the legacy (non-partitionable) threefry
# lowering, `jax.random.*` under jit(out_shardings=...) draws DIFFERENT
# values depending on the mesh the output lands on — which silently breaks
# every cross-world parity property this project is built on (a world
# initialized under dp2xtp2 must equal one initialized under dp2xpp2xtp2).
# Partitionable threefry is bit-deterministic regardless of partitioning.
_jax.config.update("jax_threefry_partitionable", True)

__version__ = "1.1.0"
