"""Jit-ready kernel entry points used by the model code.

Dispatch policy: on TPU backends the Pallas kernels run natively; on CPU
(this container) the mathematically identical pure-jnp references execute
instead — Pallas interpret mode is reserved for the kernel unit tests
(it is a Python-level interpreter, far too slow for full models).
Set ``REPRO_FORCE_PALLAS_INTERPRET=1`` to force the Pallas path in
interpret mode (used by integration tests to exercise kernel plumbing).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.reshard_pack import (
    pack_rows_pallas,
    relayout_rows_pallas,
    scatter_rows_pallas,
    unpack_rows_pallas,
)
from repro.kernels.reshard_quant import (
    dequant_scatter_rows_pallas,
    pack_quant_rows_pallas,
)
from repro.kernels.ssd_scan import ssd_intra_chunk_pallas


def _use_pallas() -> tuple[bool, bool]:
    """(use_pallas, interpret)."""
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET") == "1":
        return True, True
    return jax.default_backend() == "tpu", False


# ---------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal=True, window=0, scale=None):
    use, interp = _use_pallas()
    s, t, d = q.shape[1], k.shape[1], q.shape[-1]
    aligned = s % 128 == 0 and t % 128 == 0 and d % 8 == 0
    if use and aligned:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale, interpret=interp
        )
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def decode_attention(q, k, v, mask, scale):
    """Single-token attention against a KV cache (matvec-shaped; XLA's fused
    path is already bandwidth-optimal, no kernel needed)."""
    return _ref.decode_attention_ref(q, k, v, mask, scale)


def rmsnorm(x, scale, eps: float = 1e-6):
    use, interp = _use_pallas()
    if use and x.shape[-1] % 128 == 0:
        return rmsnorm_pallas(x, scale, eps=eps, interpret=interp)
    return _ref.rmsnorm_ref(x, scale, eps)


# ---------------------------------------------------------------------------
# SSD scan: pallas intra-chunk + jnp inter-chunk recurrence
# ---------------------------------------------------------------------------


def _ssd_inter(cum, Cc, S, chunk_decay, init_state, y_intra_shape):
    """Inter-chunk recurrence shared by kernel and ref paths.

    cum: (b,nc,q,h); Cc: (b,nc,q,n); S: (b,nc,h,p,n); chunk_decay: (b,nc,h).
    Returns (y_inter (b,nc,q,h,p), final_state (b,h,p,n)).
    """
    b, nc, q, h = cum.shape
    p = S.shape[3]

    def step(carry, inputs):
        S_c, dec_c = inputs
        h_new = dec_c[:, :, None, None] * carry + S_c
        return h_new, carry

    final, h_prevs = jax.lax.scan(
        step, init_state, (S.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    h_prevs = h_prevs.swapaxes(0, 1)  # (b,nc,h,p,n)
    state_decay_in = jnp.exp(cum)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, state_decay_in, h_prevs)
    return y_inter, final


def ssd_scan(x, dt, A, B, C, chunk, init_state=None):
    """Chunked SSD scan. Shapes as in ref.ssd_scan_ref; returns (y, final).

    Pads the sequence up to a chunk multiple (dt=0 padding is a no-op:
    zero contribution, unit decay) and crops the output.
    """
    use, interp = _use_pallas()
    b, s, h, p = x.shape
    n = B.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    if not use:
        y, final = _ref.ssd_scan_ref(x, dt, A, B, C, chunk, init_state)
        return (y[:, :s] if pad else y), final

    sp = s + pad
    nc, q = sp // chunk, chunk
    a = dt.reshape(b, nc, q, h) * A[None, None, None, :]
    cum = jnp.cumsum(a, axis=2)  # within-chunk inclusive cumsum
    cum_flat = cum.reshape(b, sp, h)

    y_intra, S = ssd_intra_chunk_pallas(
        x, dt, cum_flat, B, C, chunk, interpret=interp
    )
    chunk_decay = jnp.exp(cum[:, :, -1, :])
    Cc = C.reshape(b, nc, q, n).astype(jnp.float32)
    y_inter, final = _ssd_inter(cum, Cc, S, chunk_decay, init_state, None)
    y = y_intra.reshape(b, nc, q, h, p) + y_inter
    y = y.reshape(b, sp, h, p)
    return (y[:, :s] if pad else y), final


# ---------------------------------------------------------------------------
# Reshard staging-buffer pack/unpack
# ---------------------------------------------------------------------------


def _starts_aligned(row_starts, block_rows: int) -> bool:
    """Block-alignment of the offset table, tracer-safe: block_rows == 1 is
    always aligned; a traced table with block_rows > 1 cannot be checked at
    dispatch time and conservatively falls back to the reference path."""
    if block_rows == 1:
        return True
    if isinstance(row_starts, jax.core.Tracer):
        return False
    import numpy as np

    return bool(np.all(np.asarray(row_starts) % block_rows == 0))


def pack_rows(src, row_starts, block_rows: int):
    use, interp = _use_pallas()
    aligned = (
        src.shape[0] % block_rows == 0
        and src.shape[1] % 128 == 0
        and _starts_aligned(row_starts, block_rows)
    )
    if use and aligned:
        return pack_rows_pallas(
            src, jnp.asarray(row_starts, jnp.int32), block_rows, interpret=interp
        )
    return _ref.pack_rows_ref(src, jnp.asarray(row_starts, jnp.int32), block_rows)


def unpack_rows(buf, row_starts, block_rows: int, out_rows: int):
    use, interp = _use_pallas()
    aligned = (
        out_rows % block_rows == 0
        and buf.shape[1] % 128 == 0
        and _starts_aligned(row_starts, block_rows)
    )
    if use and aligned:
        return unpack_rows_pallas(
            buf, jnp.asarray(row_starts, jnp.int32), block_rows, out_rows, interpret=interp
        )
    return _ref.unpack_rows_ref(
        buf, jnp.asarray(row_starts, jnp.int32), block_rows, out_rows
    )


def relayout_rows(dst, src, row_starts, block_rows: int):
    """On-device relayout for the classified plan IR's "local" cells: copy
    row blocks of ``src`` into ``dst`` (treated as donated) at the same
    global offsets, in one fused gather→scatter with no staging buffer.
    Rows not named by ``row_starts`` keep their existing bytes; duplicate
    starts resolve last-wins on both paths."""
    use, interp = _use_pallas()
    aligned = (
        dst.shape[0] % block_rows == 0
        and src.shape[0] % block_rows == 0
        and dst.shape[1] % 128 == 0
        and src.shape[1] == dst.shape[1]
        and _starts_aligned(row_starts, block_rows)
    )
    if use and aligned:
        return relayout_rows_pallas(
            dst, src, jnp.asarray(row_starts, jnp.int32), block_rows, interpret=interp
        )
    return _ref.relayout_rows_ref(
        dst, src, jnp.asarray(row_starts, jnp.int32), block_rows
    )


def pack_quant_rows(src, row_starts, block_rows: int, fmt: str):
    """Gather + per-tile quantize row blocks for the compressed wire format.

    Returns ``(qbuf (nb*block_rows, C), scales (nb, 1) float32)``. One tile
    = one row-block; the sidecar carries one symmetric scale per tile.
    Deterministic: the same source rows always produce the same payload and
    scales, so a dirty-layer re-stream lands bitwise-identical bytes.
    """
    use, interp = _use_pallas()
    aligned = (
        src.shape[0] % block_rows == 0
        and src.shape[1] % 128 == 0
        and _starts_aligned(row_starts, block_rows)
    )
    if use and aligned:
        return pack_quant_rows_pallas(
            src, jnp.asarray(row_starts, jnp.int32), block_rows, fmt,
            interpret=interp,
        )
    return _ref.pack_quant_rows_ref(
        src, jnp.asarray(row_starts, jnp.int32), block_rows, fmt
    )


def dequant_scatter_rows(dst, buf, scales, row_starts, block_rows: int):
    """Dequantize + overwrite-scatter quantized tiles into ``dst`` (donated).

    The compressed-wire counterpart of ``scatter_rows``: rows not named by
    ``row_starts`` keep their bytes, duplicate starts last-wins, and because
    dequant is a deterministic elementwise map, re-applying the same payload
    is idempotent.
    """
    use, interp = _use_pallas()
    aligned = (
        dst.shape[0] % block_rows == 0
        and dst.shape[1] % 128 == 0
        and _starts_aligned(row_starts, block_rows)
    )
    if use and aligned:
        return dequant_scatter_rows_pallas(
            dst, buf, scales, jnp.asarray(row_starts, jnp.int32), block_rows,
            interpret=interp,
        )
    return _ref.dequant_scatter_rows_ref(
        dst, buf, scales, jnp.asarray(row_starts, jnp.int32), block_rows
    )


def scatter_rows(dst, buf, row_starts, block_rows: int):
    """Overwrite-scatter buffer blocks into ``dst`` (treated as donated).

    The idempotent counterpart of ``pack_rows``: rows not named by
    ``row_starts`` keep their existing bytes, and re-applying the same
    scatter is a no-op — the property the dirty-layer re-stream depends on.
    Duplicate starts resolve last-wins on both paths.
    """
    use, interp = _use_pallas()
    aligned = (
        dst.shape[0] % block_rows == 0
        and dst.shape[1] % 128 == 0
        and _starts_aligned(row_starts, block_rows)
    )
    if use and aligned:
        return scatter_rows_pallas(
            dst, buf, jnp.asarray(row_starts, jnp.int32), block_rows, interpret=interp
        )
    return _ref.scatter_rows_ref(
        dst, buf, jnp.asarray(row_starts, jnp.int32), block_rows
    )
