"""Pallas TPU kernels for the streaming resharder's staging-buffer assembly.

The hot loop of LiveR's layer-streaming protocol (paper Algorithm 1, lines
13–17) gathers the planned row-ranges of a source shard into the contiguous
staging buffer (pack) and scatters received buffer blocks into the new
parameter storage (unpack / scatter). On TPU these are bandwidth-bound
strided copies; doing them as one Pallas kernel with scalar-prefetched
offsets avoids one HBM round trip per slice versus a concat-of-dynamic-
slices graph.

Uses ``PrefetchScalarGridSpec``: the row-offset table is prefetched into
SMEM and consumed by the BlockSpec index maps, so the copy schedule is
data-dependent without host round trips.

``scatter_rows`` is the overwrite-semantics counterpart of ``unpack_rows``:
instead of scattering into a zeroed output it scatters into an existing
destination carried through ``input_output_aliases`` (the destination is
donated, untouched blocks keep their bytes). Overwrite makes re-streaming a
dirty layer idempotent — the invariant the live re-sync path depends on —
where an accumulate scatter would compound onto stale pre-copied values.

Oracles: :func:`repro.kernels.ref.pack_rows_ref` / ``unpack_rows_ref`` /
``scatter_rows_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(starts_ref, src_ref, o_ref):
    del starts_ref  # consumed by the index maps
    o_ref[...] = src_ref[...]


def pack_rows_pallas(
    src: jax.Array,  # (R, C)
    row_starts: jax.Array,  # (nb,) int32 — block starts, multiples allowed anywhere
    block_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Gather nb blocks of ``block_rows`` rows into (nb*block_rows, C)."""
    nb = row_starts.shape[0]
    C = src.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(
                (block_rows, C),
                lambda i, starts: (starts[i] // block_rows, 0),
            ),
        ],
        out_specs=pl.BlockSpec((block_rows, C), lambda i, starts: (i, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb * block_rows, C), src.dtype),
        interpret=interpret,
    )(row_starts, src)


def unpack_rows_pallas(
    buf: jax.Array,  # (nb*block_rows, C)
    row_starts: jax.Array,  # (nb,) int32
    block_rows: int,
    out_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Scatter buffer blocks into a zeroed (out_rows, C) array.

    Note: out blocks not covered by any row_start keep whatever the
    uninitialized output holds, so the wrapper masks with a zero base via
    input_output_aliasing in ops.py; here we require full coverage or accept
    donation of a pre-zeroed destination.
    """
    nb = row_starts.shape[0]
    C = buf.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i, starts: (i, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
        ),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((out_rows, C), buf.dtype),
        interpret=interpret,
    )(row_starts, buf)


def relayout_rows_pallas(
    dst: jax.Array,  # (R, C) — donated; aliased into the output
    src: jax.Array,  # (R, C) — same global shape, different layout
    row_starts: jax.Array,  # (nb,) int32
    block_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Fused gather→scatter for the classified plan IR's "local" cells: copy
    blocks of ``src`` into ``dst`` at the same row offsets in ONE kernel —
    the pack and scatter index maps composed, with no intermediate staging
    buffer and no second HBM round trip. ``dst`` is aliased to the output
    (``input_output_aliases``) so untouched blocks keep their bytes and
    re-applying is idempotent, exactly like ``scatter_rows``."""
    nb = row_starts.shape[0]
    C = dst.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(
                (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
            ),
            pl.BlockSpec(
                (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
        ),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        # flattened input index 2 (starts, src, dst) -> output 0
        input_output_aliases={2: 0},
        interpret=interpret,
    )(row_starts, src, dst)


def _scatter_kernel(starts_ref, buf_ref, dst_ref, o_ref):
    del starts_ref, dst_ref  # starts: index maps; dst: aliased into the output
    o_ref[...] = buf_ref[...]


def scatter_rows_pallas(
    dst: jax.Array,  # (R, C) — donated; aliased into the output
    buf: jax.Array,  # (nb*block_rows, C)
    row_starts: jax.Array,  # (nb,) int32
    block_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Overwrite-scatter buffer blocks into ``dst`` at the given row offsets.

    ``dst`` is aliased to the output (``input_output_aliases``), so blocks
    not named by ``row_starts`` keep their existing bytes — no zero base,
    no full-destination rewrite. Duplicate starts resolve last-wins (the
    grid is sequential), matching the jnp oracle's fori_loop order. The
    caller must treat ``dst`` as donated.
    """
    nb = row_starts.shape[0]
    C = dst.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i, starts: (i, 0)),
            pl.BlockSpec(
                (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
        ),
    )
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        # flattened input index 2 (starts, buf, dst) -> output 0
        input_output_aliases={2: 0},
        interpret=interpret,
    )(row_starts, buf, dst)
