"""Pallas TPU kernels for the compressed wire format of the streaming resharder.

The reshard data plane (paper Algorithm 1; ``reshard_pack.py``) moves raw
bytes: pack gathers planned row-blocks into the staging buffer, scatter
overwrites them into the destination shard. After the delta planner (PR 6)
the bytes that still cross the wire are dominated by optimizer moments,
which tolerate aggressive formats — so these kernels fuse symmetric
quantization into the pack (bf16/fp32 → int8 or fp8-e4m3, one per-tile
scale per row-block carried in a float32 sidecar array) and the matching
dequantization into the overwrite-scatter. A tile is one ``block_rows``
row-block, i.e. one grid step of the pack kernel; the sidecar has one
scale per tile.

Quantization is symmetric around zero with a per-tile scale::

    scale = max(absmax(tile), eps) / qmax        # eps floor: all-zero tiles
    int8:      q = clip(round(x / scale), -127, 127)
    fp8-e4m3:  q = cast_fp8(x / scale)           # |x/scale| <= 448 by construction

and dequant is ``q * scale`` cast back to the destination dtype. Both
directions are deterministic elementwise maps, so streaming the same tile
twice produces bitwise-identical destination bytes — the idempotence
invariant the dirty-layer re-stream path depends on survives compression.

``dequant_scatter_rows`` composes with ``scatter_rows``'s overwrite
semantics: the destination is donated and aliased into the output
(``input_output_aliases``), untouched rows keep their bytes, duplicate
starts resolve last-wins on the sequential grid.

This module is also the home of the int8 symmetric-quant math that used to
live in ``distribution/compress.py`` (per-tensor :func:`quantize_int8` /
:func:`dequantize_int8` and the error-feedback round trip
:func:`compress_decompress_with_ef`): the gradient-compression path and the
wire format now share one quantizer definition, and the per-tensor
functions double as the scalar oracle the kernel tests check against.

Oracles: :func:`repro.kernels.ref.pack_quant_rows_ref` /
``dequant_scatter_rows_ref``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Smallest representable scale floor: keeps all-zero (and fully denormal)
# tiles from dividing by zero; such tiles quantize to 0 and dequantize to 0.
QUANT_EPS = 1e-12

# np.finfo(float8_e4m3fn) raises on some numpy versions — hardcode the max.
FP8_E4M3_MAX = 448.0

WIRE_QMAX = {"int8": 127.0, "fp8_e4m3": FP8_E4M3_MAX}
WIRE_QDTYPE = {"int8": jnp.int8, "fp8_e4m3": jnp.float8_e4m3fn}
# float32 per-tile scale carried alongside the quantized payload
SIDECAR_BYTES_PER_TILE = 4


def wire_itemsize(fmt: str) -> int:
    """Bytes per element of the quantized payload (both formats are 1B)."""
    return jnp.dtype(WIRE_QDTYPE[fmt]).itemsize


def _quantize_tile(x: jax.Array, fmt: str) -> tuple[jax.Array, jax.Array]:
    """Quantize one tile (any shape) → (q, scale ()-float32). Shared by the
    kernel bodies and the jnp oracle so both paths are the same arithmetic.

    The scale is ``absmax * (1/qmax)`` with the reciprocal folded to a
    float32 constant, NOT ``absmax / qmax``: XLA strength-reduces division
    by a constant to a reciprocal multiply only in some fusion contexts, so
    the divide form computes 1-ULP-different scales between the Pallas
    interpreter and the jnp oracle. Multiply form is bitwise-stable."""
    xf = x.astype(jnp.float32)
    qmax = WIRE_QMAX[fmt]
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), QUANT_EPS) * jnp.float32(1.0 / qmax)
    y = xf / scale
    if fmt == "int8":
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:
        q = y.astype(jnp.float8_e4m3fn)
    return q, scale


def _dequantize_tile(q: jax.Array, scale: jax.Array, out_dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(out_dtype)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def _make_quant_kernel(fmt: str):
    def kernel(starts_ref, src_ref, q_ref, scale_ref):
        del starts_ref  # consumed by the index maps
        q, scale = _quantize_tile(src_ref[...], fmt)
        q_ref[...] = q
        scale_ref[0, 0] = scale

    return kernel


def pack_quant_rows_pallas(
    src: jax.Array,  # (R, C)
    row_starts: jax.Array,  # (nb,) int32
    block_rows: int,
    fmt: str,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Gather + quantize nb row-blocks: ((nb*block_rows, C) q, (nb, 1) f32).

    One grid step per tile: the block is gathered through the scalar-
    prefetched offset table exactly like ``pack_rows_pallas``, its absmax
    reduced in-register, and the quantized payload plus sidecar scale
    written in the same pass — no second HBM round trip over the staged
    bytes to compute scales.
    """
    nb = row_starts.shape[0]
    C = src.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(
                (block_rows, C),
                lambda i, starts: (starts[i] // block_rows, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, C), lambda i, starts: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, starts: (i, 0)),
        ],
    )
    return pl.pallas_call(
        _make_quant_kernel(fmt),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nb * block_rows, C), WIRE_QDTYPE[fmt]),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(row_starts, src)


def _make_dequant_scatter_kernel(out_dtype):
    def kernel(starts_ref, buf_ref, scale_ref, dst_ref, o_ref):
        del starts_ref, dst_ref  # starts: index maps; dst: aliased output
        o_ref[...] = _dequantize_tile(buf_ref[...], scale_ref[0, 0], out_dtype)

    return kernel


def dequant_scatter_rows_pallas(
    dst: jax.Array,  # (R, C) — donated; aliased into the output
    buf: jax.Array,  # (nb*block_rows, C) quantized payload
    scales: jax.Array,  # (nb, 1) float32 sidecar
    row_starts: jax.Array,  # (nb,) int32
    block_rows: int,
    interpret: bool = False,
) -> jax.Array:
    """Dequantize + overwrite-scatter tiles into ``dst`` at the row offsets.

    The compressed-wire counterpart of ``scatter_rows_pallas``: same
    aliased-destination overwrite semantics (untouched rows keep their
    bytes, duplicate starts last-wins), with the per-tile dequant fused in
    front of the store instead of materializing a dequantized staging
    buffer first.
    """
    nb = row_starts.shape[0]
    C = dst.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, C), lambda i, starts: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, starts: (i, 0)),
            pl.BlockSpec(
                (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_rows, C), lambda i, starts: (starts[i] // block_rows, 0)
        ),
    )
    return pl.pallas_call(
        _make_dequant_scatter_kernel(dst.dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        # flattened input index 3 (starts, buf, scales, dst) -> output 0
        input_output_aliases={3: 0},
        interpret=interpret,
    )(row_starts, buf, scales, dst)


# ---------------------------------------------------------------------------
# Per-tensor int8 quantization + error feedback (ex distribution/compress.py)
# ---------------------------------------------------------------------------


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: (q int8, scale ()-f32).

    The whole-tensor special case of the wire format's per-tile quantizer
    (one tile = the tensor); kept as the gradient-compression entry point
    and the scalar oracle for the kernel tests.
    """
    q, scale = _quantize_tile(g, "int8")
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return _dequantize_tile(q, scale, jnp.float32)


def compress_decompress_with_ef(grads, opt_state):
    """Int8 round trip with error feedback carried in ``opt_state['ef']``.

    Each leaf adds its residual from the previous step before quantizing
    and stores the new residual, so the quantization error is re-injected
    instead of lost (beyond-paper extension, DESIGN.md §8).
    """
    ef = opt_state["ef"]

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq, corrected - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    new_opt = dict(opt_state)
    new_opt["ef"] = new_e
    return new_g, new_opt
