"""Pallas TPU fused RMSNorm.

Grid over row blocks; each step normalizes a ``(block_rows, d)`` tile held in
VMEM (one pass: square-reduce + rsqrt + scale — avoids the extra HBM round
trip of the unfused mean/var + mul sequence). d is the model dimension
(always a multiple of 128 for the assigned archs).

Oracle: :func:`repro.kernels.ref.rmsnorm_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params as _compiler_params


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,  # (..., d)
    scale: jax.Array,  # (d,)
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for dim in orig_shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a multiple of block_rows
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        compiler_params=_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
