"""Pallas TPU kernel for the Mamba-2 SSD *intra-chunk* block.

The SSD chunked algorithm splits into (a) a quadratic-within-chunk part —
``(C·Bᵀ ∘ L) · X`` plus the per-chunk state contribution — which dominates
FLOPs and is what this kernel computes, and (b) a cheap O(num_chunks)
inter-chunk recurrence handled in plain JAX by the wrapper in ``ops.py``.

Grid: ``(batch, heads, num_chunks)``, one (chunk × head_dim) tile per step.
All operands for one grid step fit VMEM: with chunk=128, head_dim=64,
d_state=128 fp32 the working set is ≈ 0.4 MB ≪ 16 MB VMEM, and the two
matmuls (q×q @ q×p and n×q @ q×p) feed the MXU with 128-aligned dims.

Oracle: :func:`repro.kernels.ref.ssd_scan_ref` (intra-chunk terms).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params as _compiler_params


def _ssd_chunk_kernel(
    x_ref,  # (1, chunk, 1, p)
    dt_ref,  # (1, chunk, 1)
    cum_ref,  # (1, chunk, 1)   cumsum(dt*A) within chunk
    b_ref,  # (1, chunk, n)
    c_ref,  # (1, chunk, n)
    y_ref,  # (1, chunk, 1, p)  intra-chunk output
    s_ref,  # (1, 1, 1, p, n)   chunk state contribution
    *,
    chunk: int,
):
    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (q, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (q,)
    cum = cum_ref[0, :, 0].astype(jnp.float32)  # (q,)
    B = b_ref[0].astype(jnp.float32)  # (q, n)
    C = c_ref[0].astype(jnp.float32)  # (q, n)

    # decay matrix L[t,s] = exp(cum_t - cum_s) for s <= t
    diff = cum[:, None] - cum[None, :]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(si <= ti, jnp.exp(diff), 0.0)

    CB = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, q)
    M = CB * L * dt[None, :]
    y = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (q, p)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state contribution: S = sum_s exp(cum_last - cum_s) dt_s x_s ⊗ B_s
    w = jnp.exp(cum[-1] - cum) * dt  # (q,)
    xw = x * w[:, None]  # (q, p)
    S = jax.lax.dot_general(
        xw, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (p, n)
    s_ref[0, 0, 0] = S.astype(s_ref.dtype)


def ssd_intra_chunk_pallas(
    x: jax.Array,  # (b, s, h, p)
    dt: jax.Array,  # (b, s, h) float32
    cum: jax.Array,  # (b, s, h) float32 within-chunk cumsum of dt*A
    B: jax.Array,  # (b, s, n) float32
    C: jax.Array,  # (b, s, n) float32
    chunk: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra (b,s,h,p) f32, S (b,nc,h,p,n) f32)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    grid = (b, h, nc)

    kernel = functools.partial(_ssd_chunk_kernel, chunk=chunk)
    y, S = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, hi, ci: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(x, dt, cum, B, C)
    return y, S
