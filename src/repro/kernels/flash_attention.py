"""Pallas TPU flash attention (forward) with causal/sliding-window masking
and GQA head mapping.

Grid layout: ``(batch, q_heads, num_q_blocks, num_k_blocks)`` with the
k-block dimension innermost ("arbitrary" semantics) so the VMEM scratch
accumulators (running max / denominator / output block) persist across the
online-softmax reduction — the canonical TPU flash pattern. Block shapes are
chosen so q/k/v tiles are MXU-aligned: ``(block_q, head_dim)`` ×
``(block_k, head_dim)`` with head_dim padded to a multiple of 128 by the
wrapper if needed.

The oracle is :func:`repro.kernels.ref.flash_attention_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params as _compiler_params

NEG_INF = -1e30


def _fa_kernel(
    q_ref,  # (1, block_q, 1, d)
    k_ref,  # (1, block_k, 1, d)
    v_ref,  # (1, block_k, 1, d)
    o_ref,  # (1, block_q, 1, d)
    m_scr,  # (block_q, 1) f32 scratch
    l_scr,  # (block_q, 1) f32 scratch
    acc_scr,  # (block_q, d) f32 scratch
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bq, bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _flush():
        denom = jnp.where(l_scr[...] == 0.0, 1.0, l_scr[...])
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (b, s, h, d)
    k: jax.Array,  # (b, t, kh, d)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    rep = h // kh
    if scale is None:
        scale = d**-0.5
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0, (s, t, block_q, block_k)
    grid = (b, h, s // block_q, t // block_k)
    q_offset = t - s  # right-aligned queries (prefill continuation)

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        q_offset=q_offset,
    )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec(
                (1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, d), lambda bi, hi, qi, ki: (bi, ki, hi // rep, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda bi, hi, qi, ki: (bi, qi, hi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
