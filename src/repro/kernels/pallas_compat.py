"""Version compatibility for the Pallas TPU API surface we use.

The kernels target the current documented API (``pltpu.CompilerParams``);
older jaxlibs (<=0.4.x) expose the same dataclass as ``TPUCompilerParams``.
Everything else we rely on (``PrefetchScalarGridSpec``, BlockSpec index
maps, ``interpret=``) is stable across the versions this repo supports.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def compiler_params(**kwargs):
    """Build TPU compiler params, dropping kwargs the installed version
    doesn't know (e.g. ``dimension_semantics`` is accepted by both, but
    future fields may not be)."""
    if CompilerParams is None:  # pragma: no cover - pallas without TPU ext
        return None
    try:
        return CompilerParams(**kwargs)
    except TypeError:
        known = {
            k: v
            for k, v in kwargs.items()
            if k in getattr(CompilerParams, "__dataclass_fields__", {})
        }
        return CompilerParams(**known)
