"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for kernel tests (interpret=True vs ref) and the
CPU execution path of ``ops.py`` (this container has no TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def flash_attention_ref(
    q: jax.Array,  # (b, s, h, d)
    k: jax.Array,  # (b, t, kh, d)
    v: jax.Array,  # (b, t, kh, d)
    causal: bool = True,
    window: int = 0,
    scale: float | None = None,
) -> jax.Array:
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    rep = h // kh
    if scale is None:
        scale = d**-0.5
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32) * scale, kf)
    qpos = jnp.arange(s)[:, None] + (t - s)  # right-aligned when t != s
    kpos = jnp.arange(t)[None, :]
    if causal:
        mask = kpos <= qpos
    else:
        mask = jnp.ones((s, t), bool)
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vf)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (b, 1, h, d)
    k: jax.Array,  # (b, T, kh, d)
    v: jax.Array,
    mask: jax.Array,  # broadcastable to (b, 1, 1, T)
    scale: float,
) -> jax.Array:
    b, _, h, d = q.shape
    kh = k.shape[2]
    rep = h // kh
    kf = jnp.repeat(k.astype(jnp.float32), rep, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), rep, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32) * scale, kf)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD chunked scan
# ---------------------------------------------------------------------------


def ssd_scan_ref(
    x: jax.Array,  # (b, s, h, p) float
    dt: jax.Array,  # (b, s, h)  float32, post-softplus
    A: jax.Array,  # (h,)       float32, negative
    B: jax.Array,  # (b, s, n)  float32
    C: jax.Array,  # (b, s, n)  float32
    chunk: int,
    init_state: jax.Array | None = None,  # (b, h, p, n)
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (b,s,h,p) float32, final_state (b,h,p,n) float32)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, q = s // chunk, chunk
    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)

    a = dtc * A[None, None, None, :]  # (b,nc,q,h) <= 0
    cum = jnp.cumsum(a, axis=2)  # inclusive cumsum

    # --- intra-chunk (quadratic within chunk) -----------------------------
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,t,s,h)
    tri = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,nc,t,s)
    M = CB[..., None] * L * dtc[:, :, None, :, :]  # weight at source step s
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", M, xf)

    # --- chunk state contributions ----------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,q,h)
    S = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", decay_to_end * dtc, Bc, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,h)

    # --- inter-chunk recurrence --------------------------------------------
    h0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(carry, inputs):
        S_c, dec_c = inputs  # (b,h,p,n), (b,h)
        h_prev = carry
        h_new = dec_c[:, :, None, None] * h_prev + S_c
        return h_new, h_prev  # emit the *incoming* state for this chunk

    final, h_prevs = jax.lax.scan(
        step, h0, (S.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    h_prevs = h_prevs.swapaxes(0, 1)  # (b,nc,h,p,n)

    state_decay_in = jnp.exp(cum)  # decay from chunk start to step t
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, state_decay_in, h_prevs)

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, final


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Reshard pack/unpack (staging-buffer assembly)
# ---------------------------------------------------------------------------


def pack_rows_ref(src: jax.Array, row_starts: jax.Array, block_rows: int) -> jax.Array:
    """Gather ``len(row_starts)`` blocks of ``block_rows`` contiguous rows of
    ``src`` into a dense output (the paper's staging-buffer assemble loop).

    src: (R, C); row_starts: (nb,) int32; out: (nb*block_rows, C).
    """
    nb = row_starts.shape[0]

    def take(start):
        return jax.lax.dynamic_slice_in_dim(src, start, block_rows, axis=0)

    blocks = jax.vmap(take)(row_starts)  # (nb, block_rows, C)
    return blocks.reshape(nb * block_rows, src.shape[1])


def unpack_rows_ref(
    buf: jax.Array, row_starts: jax.Array, block_rows: int, out_rows: int
) -> jax.Array:
    """Inverse of pack_rows: scatter buffer blocks into a (out_rows, C) zero
    array at the given row offsets."""
    nb = row_starts.shape[0]
    out = jnp.zeros((out_rows, buf.shape[1]), buf.dtype)
    blocks = buf.reshape(nb, block_rows, buf.shape[1])

    def body(i, acc):
        return jax.lax.dynamic_update_slice_in_dim(
            acc, blocks[i], row_starts[i], axis=0
        )

    return jax.lax.fori_loop(0, nb, body, out)


def relayout_rows_ref(
    dst: jax.Array, src: jax.Array, row_starts: jax.Array, block_rows: int
) -> jax.Array:
    """On-device relayout: gather blocks of ``src`` at ``row_starts`` and
    overwrite-scatter them into ``dst`` at the SAME row offsets (both arrays
    are global views of one tensor; "local" plan cells move bytes between
    two layouts of the same global coordinates). Composition of
    ``pack_rows_ref`` and ``scatter_rows_ref`` with a shared offset table;
    duplicate starts resolve last-wins like the scatter."""
    nb = row_starts.shape[0]

    def take(start):
        return jax.lax.dynamic_slice_in_dim(src, start, block_rows, axis=0)

    blocks = jax.vmap(take)(row_starts)  # (nb, block_rows, C)

    def body(i, acc):
        return jax.lax.dynamic_update_slice_in_dim(
            acc, blocks[i], row_starts[i], axis=0
        )

    return jax.lax.fori_loop(0, nb, body, dst)


def pack_quant_rows_ref(
    src: jax.Array,
    row_starts: jax.Array,
    block_rows: int,
    fmt: str,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for ``pack_quant_rows_pallas``: gather ``len(row_starts)``
    row-blocks and quantize each tile symmetrically around zero with its own
    scale. Returns ((nb*block_rows, C) quantized, (nb, 1) float32 scales).

    The arithmetic is written out independently of the kernel body so
    interpret-vs-ref parity is a real check: ``scale = max(absmax, eps) *
    (1/qmax)`` (reciprocal folded to a float32 constant — the divide form
    is not bitwise-stable across compilation contexts); int8
    rounds-to-nearest then clips, fp8-e4m3 casts (|x/scale| <= 448 by
    construction). All-zero tiles hit the eps floor and quantize to exact
    zeros.
    """
    from repro.kernels.reshard_quant import QUANT_EPS, WIRE_QMAX

    nb = row_starts.shape[0]
    qmax = WIRE_QMAX[fmt]

    def take(start):
        return jax.lax.dynamic_slice_in_dim(src, start, block_rows, axis=0)

    blocks = jax.vmap(take)(row_starts).astype(jnp.float32)  # (nb, br, C)
    absmax = jnp.max(jnp.abs(blocks), axis=(1, 2))  # (nb,)
    scales = jnp.maximum(absmax, QUANT_EPS) * jnp.float32(1.0 / qmax)
    y = blocks / scales[:, None, None]
    if fmt == "int8":
        q = jnp.clip(jnp.round(y), -qmax, qmax).astype(jnp.int8)
    else:
        q = y.astype(jnp.float8_e4m3fn)
    return q.reshape(nb * block_rows, src.shape[1]), scales[:, None]


def dequant_scatter_rows_ref(
    dst: jax.Array,
    buf: jax.Array,
    scales: jax.Array,
    row_starts: jax.Array,
    block_rows: int,
) -> jax.Array:
    """Oracle for ``dequant_scatter_rows_pallas``: dequantize each tile with
    its sidecar scale and overwrite-scatter into ``dst`` (rows not named by
    ``row_starts`` keep their values; duplicate starts last-wins via the
    sequential fori_loop, matching the kernel's sequential grid)."""
    nb = row_starts.shape[0]
    blocks = buf.reshape(nb, block_rows, buf.shape[1]).astype(jnp.float32)
    deq = (blocks * scales.reshape(nb)[:, None, None]).astype(dst.dtype)

    def body(i, acc):
        return jax.lax.dynamic_update_slice_in_dim(
            acc, deq[i], row_starts[i], axis=0
        )

    return jax.lax.fori_loop(0, nb, body, dst)


def scatter_rows_ref(
    dst: jax.Array, buf: jax.Array, row_starts: jax.Array, block_rows: int
) -> jax.Array:
    """Overwrite-scatter buffer blocks into an existing destination.

    Unlike ``unpack_rows_ref`` the base is the caller's ``dst``, so rows not
    named by ``row_starts`` keep their current values and re-applying the
    same scatter is idempotent (the dirty-layer re-stream invariant).
    Duplicate starts resolve last-wins (sequential fori_loop), matching the
    Pallas kernel's sequential grid.
    """
    nb = row_starts.shape[0]
    blocks = buf.reshape(nb, block_rows, buf.shape[1])

    def body(i, acc):
        return jax.lax.dynamic_update_slice_in_dim(
            acc, blocks[i], row_starts[i], axis=0
        )

    return jax.lax.fori_loop(0, nb, body, dst)
