"""Pallas TPU kernels for the compute hot-spots, each with a jit'd wrapper
(ops.py) and a pure-jnp oracle (ref.py). Validated with interpret=True on
CPU; native on TPU backends."""

from repro.kernels import ref

# ops imported lazily by callers (``from repro.kernels import ops``) to keep
# import costs off modules that only need the oracles.
__all__ = ["ref", "ops"]
