"""Deterministic, elastically-resharding synthetic LM data pipeline
(supports the paper's iteration-boundary consistent cut, invariant I3).

Design requirement from LiveR: when the DP degree changes mid-run, the
*global* token stream must be unaffected — only its partitioning across data
ranks changes. We get this by keying every sample counter-style on
``(seed, step, sample_index)`` with a Philox generator, so

    global_batch(step)  is identical for every (dp, pp, tp) decomposition,

and a data-parallel rank's shard is just a slice of it. The iterator state is
exactly ``step`` (checkpointable in O(1); remapped across resizes trivially —
this is the data-plane analogue of the paper's Abstract Resource View).

A Markov "structured" mode gives learnable structure (loss visibly decreases
in the examples); "uniform" mode is for pure-throughput benchmarks.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class DataState:
    step: int = 0


class SyntheticLM:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        mode: str = "structured",  # structured | uniform
    ):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.mode = mode
        # fixed random Markov transition offsets for structured mode
        base = np.random.Generator(np.random.Philox(key=seed))
        self._mults = base.integers(1, 64, size=16)
        self._adds = base.integers(0, vocab_size, size=16)

    # -- core: per-sample counter-based generation ------------------------
    def _sample(self, step: int, idx: int) -> np.ndarray:
        g = np.random.Generator(
            np.random.Philox(key=self.seed + 1, counter=[0, 0, step, idx])
        )
        if self.mode == "uniform":
            return g.integers(0, self.vocab_size, size=self.seq_len, dtype=np.int32)
        # structured: piecewise-affine Markov chain with noise
        pattern = int(g.integers(0, 16))
        x = np.empty(self.seq_len, np.int32)
        x[0] = g.integers(0, self.vocab_size)
        mult, add = int(self._mults[pattern]), int(self._adds[pattern])
        noise = g.integers(0, 4, size=self.seq_len)
        for t in range(1, self.seq_len):
            x[t] = (x[t - 1] * mult + add + noise[t]) % self.vocab_size
        return x

    def global_batch_at(self, step: int) -> np.ndarray:
        return np.stack([self._sample(step, i) for i in range(self.global_batch)])

    def shard_at(self, step: int, dp_rank: int, dp_size: int) -> np.ndarray:
        """The dp_rank-th slice of the global batch — identical global stream
        for every dp_size (elastic invariant, tested)."""
        assert self.global_batch % dp_size == 0, (self.global_batch, dp_size)
        per = self.global_batch // dp_size
        return np.stack(
            [self._sample(step, dp_rank * per + i) for i in range(per)]
        )

    # -- iterator protocol -------------------------------------------------
    def batches(self, state: DataState):
        while True:
            yield self.global_batch_at(state.step)
            state.step += 1
