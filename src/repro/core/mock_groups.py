"""Mock Process Groups (paper §4.5) — JAX adaptation.

The paper intercepts NCCL collectives so cold ranks can finish heavyweight
*local* initialization (model construction, JIT compilation, autotuning)
without blocking hot ranks. The JAX analogue: trace + lower the target-world
step functions against an ``AbstractMesh`` — the entire Python-side pipeline
(model construction, jaxpr tracing, StableHLO lowering, sharding inference)
executes with *zero* device participation; only the final ``compile()``
(the communicator-construction analogue) binds concrete devices, and that
runs in the Shadow World's background thread (core/shadow.py).

The symmetry break is identical to the paper's: local work is decoupled from
global coordination, so active devices never wait on cold-start latency.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import jax
from jax.sharding import AbstractMesh, Mesh, NamedSharding


@dataclass
class MockWarmupResult:
    lowered: Any  # jax.stages.Lowered against the abstract mesh
    lower_seconds: float
    hlo_bytes: int


def abstract_of(mesh: Mesh) -> AbstractMesh:
    sizes, names = tuple(mesh.devices.shape), tuple(mesh.axis_names)
    try:
        return AbstractMesh(sizes, names)  # jax >= 0.5: (axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))  # 0.4.x: shape_tuple


def _retarget(sharding_tree: Any, amesh: AbstractMesh) -> Any:
    """Rebuild a NamedSharding tree onto the abstract mesh."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(amesh, s.spec) if isinstance(s, NamedSharding) else s,
        sharding_tree,
        is_leaf=lambda s: isinstance(s, NamedSharding),
    )


def mock_warmup(
    fn: Callable,
    mesh: Mesh,
    in_shardings: Any,
    abstract_args: tuple,
    out_shardings: Any = None,
    donate_argnums: tuple = (),
    static_argnums: tuple = (),
) -> MockWarmupResult:
    """Run the 'mock process group' warmup: full trace+lower on an abstract
    stand-in of the target mesh. No device is touched.
    """
    amesh = abstract_of(mesh)
    t0 = time.perf_counter()
    lowered = None
    # Prefer the fully device-free AbstractMesh path; jaxlibs without
    # AbstractMesh lowering support (<=0.4.x raises "_device_assignment is
    # not implemented") fall back to lowering against the concrete mesh —
    # still trace+lower only: no executable is loaded and no collective or
    # device computation runs, which is the property the mock warmup needs.
    for target in (amesh, mesh):
        in_sh = _retarget(in_shardings, target)
        out_sh = _retarget(out_shardings, target) if out_shardings is not None else None
        jitted = jax.jit(
            fn,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=donate_argnums,
            static_argnums=static_argnums,
        )
        traced = jitted.trace(*abstract_args)
        try:
            try:
                lowered = traced.lower()
            except ValueError:
                # device-less lowering must name its target platform explicitly
                lowered = traced.lower(lowering_platforms=(jax.default_backend(),))
            break
        except (ValueError, NotImplementedError):
            if target is mesh:
                raise
            continue
    dt = time.perf_counter() - t0
    try:
        hlo_bytes = len(lowered.as_text())
    except Exception:  # pragma: no cover
        hlo_bytes = 0
    return MockWarmupResult(lowered=lowered, lower_seconds=dt, hlo_bytes=hlo_bytes)
