"""Layer-Streaming Resharding — simulated-rank front-end (paper §4.6.2,
Algorithm 1).

The protocol itself (layer ordering, staging-budget chunking, Theorem 1
accounting) lives in :mod:`repro.reshard.engine`; this module keeps the
multi-rank simulation fixtures (``RankStore`` shard stores) and the
historical ``execute_plan`` entry point, now a thin wrapper that runs the
shared :class:`~repro.reshard.engine.ReshardEngine` with a
:class:`~repro.reshard.executors.SimExecutor` — the same engine the live
jax.Array path uses, so byte accounting agrees across backends by
construction.

Each simulated rank owns only its shard; no full tensor is ever
materialized. Used by the correctness/property tests and the byte-level
benchmarks.
"""

from __future__ import annotations

import numpy as np

from repro.configs.base import ParallelConfig
from repro.core.intersection import TransferPlan
from repro.core.resource_view import TensorSpec, view_of
from repro.reshard.engine import (
    DEFAULT_STAGING_BYTES,
    ReshardEngine,
    StreamStats,
)
from repro.reshard.executors import SimExecutor

__all__ = [
    "DEFAULT_STAGING_BYTES",
    "RankStore",
    "StreamStats",
    "allocate_destination",
    "execute_plan",
    "materialize_rank",
]


class RankStore:
    """Per-rank shard storage: {collection/tensor-path: ndarray shard}."""

    def __init__(self, rank: int):
        self.rank = rank
        self.shards: dict[str, np.ndarray] = {}

    def bytes(self) -> int:
        return sum(a.nbytes for a in self.shards.values())


def materialize_rank(
    specs: list[TensorSpec],
    cfg: ParallelConfig,
    rank: int,
    global_state: dict[str, np.ndarray],
) -> RankStore:
    """Build one rank's shard store by slicing the global reference state
    (test fixture — production state is born sharded)."""
    store = RankStore(rank)
    for spec in specs:
        v = view_of(spec, cfg, rank)
        if v is None:
            continue
        sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
        store.shards[spec.name] = np.ascontiguousarray(global_state[spec.name][sl])
    return store


def allocate_destination(
    specs: list[TensorSpec], cfg: ParallelConfig, rank: int
) -> RankStore:
    """Pre-allocated (zeroed) destination parameter storage for a rank —
    required for training regardless, hence not counted as transfer overhead
    (Theorem 1, item 2)."""
    store = RankStore(rank)
    for spec in specs:
        v = view_of(spec, cfg, rank)
        if v is None:
            continue
        store.shards[spec.name] = np.zeros(v.shape(), dtype=spec.dtype)
    return store


def execute_plan(
    plan: TransferPlan,
    src_stores: dict[int, RankStore],
    dst_stores: dict[int, RankStore],
    staging_bytes: int = DEFAULT_STAGING_BYTES,
    zero_copy_local: bool = True,
    wire_policy=None,
) -> StreamStats:
    """Run Algorithm 1 over simulated ranks via the shared engine.

    ``wire_policy`` (None = lossless) prices remote chunks in their
    compressed wire format for the staging budget and the wire/logical
    byte counters, matching the live path's accounting."""
    engine = ReshardEngine(
        plan,
        SimExecutor(src_stores, dst_stores, wire_policy=wire_policy),
        staging_bytes=staging_bytes,
        zero_copy_local=zero_copy_local,
        wire_policy=wire_policy,
    )
    return engine.run()
