"""Layer-Streaming Resharding executor (paper §4.6.2, Algorithm 1).

A faithful multi-rank implementation of the protocol: transfers execute one
layer at a time through a fixed-size staging buffer ``B``; the buffer is
reused across layers; a barrier separates layers. Peak extra memory per rank
is instrumented and *asserted* to stay ≤ B + metadata — the executable form
of Theorem 1 (Bounded Memory During Resharding).

Each simulated rank owns only its shard (``RankStore``); no full tensor is
ever materialized. Used by the correctness/property tests, the byte-level
benchmarks, and as the semantics reference for the live-path resharder
(core/reshard.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs.base import ParallelConfig
from repro.core.intersection import TransferPlan, TransferTask
from repro.core.resource_view import TensorSpec, view_of

DEFAULT_STAGING_BYTES = 512 * 1024 * 1024  # paper default B = 512 MB


class RankStore:
    """Per-rank shard storage: {collection/tensor-path: ndarray shard}."""

    def __init__(self, rank: int):
        self.rank = rank
        self.shards: dict[str, np.ndarray] = {}

    def bytes(self) -> int:
        return sum(a.nbytes for a in self.shards.values())


def materialize_rank(
    specs: list[TensorSpec],
    cfg: ParallelConfig,
    rank: int,
    global_state: dict[str, np.ndarray],
) -> RankStore:
    """Build one rank's shard store by slicing the global reference state
    (test fixture — production state is born sharded)."""
    store = RankStore(rank)
    for spec in specs:
        v = view_of(spec, cfg, rank)
        if v is None:
            continue
        sl = tuple(slice(lo, hi) for lo, hi in v.bounds)
        store.shards[spec.name] = np.ascontiguousarray(global_state[spec.name][sl])
    return store


def allocate_destination(
    specs: list[TensorSpec], cfg: ParallelConfig, rank: int
) -> RankStore:
    """Pre-allocated (zeroed) destination parameter storage for a rank —
    required for training regardless, hence not counted as transfer overhead
    (Theorem 1, item 2)."""
    store = RankStore(rank)
    for spec in specs:
        v = view_of(spec, cfg, rank)
        if v is None:
            continue
        store.shards[spec.name] = np.zeros(v.shape(), dtype=spec.dtype)
    return store


@dataclass
class StreamStats:
    layers_streamed: int = 0
    network_bytes: int = 0
    local_bytes: int = 0
    peak_staging_bytes: int = 0
    barriers: int = 0
    chunks: int = 0
    per_layer_bytes: dict[int, int] = field(default_factory=dict)

    def assert_bounded(self, budget: int) -> None:
        assert self.peak_staging_bytes <= budget, (
            f"staging {self.peak_staging_bytes} exceeded budget {budget} "
            "(Theorem 1 violated)"
        )


def _chunk_task(task: TransferTask, budget: int) -> list[TransferTask]:
    """Split a task whose payload exceeds the staging budget into sub-slices
    along its largest dim (paper §5: fixed-size chunks, default 512 MB)."""
    if task.nbytes <= budget:
        return [task]
    shape = task.shape()
    d = int(np.argmax(shape))
    per_row = task.nbytes // shape[d]
    rows = max(1, budget // per_row)
    out = []
    lo, hi = task.bounds[d]
    start = lo
    while start < hi:
        end = min(start + rows, hi)
        bounds = list(task.bounds)
        bounds[d] = (start, end)
        frac = (end - start) / shape[d]
        out.append(
            TransferTask(
                tensor=task.tensor,
                collection=task.collection,
                src_rank=task.src_rank,
                dst_rank=task.dst_rank,
                bounds=tuple(bounds),
                src_offset=tuple(
                    o + (start - lo if i == d else 0)
                    for i, o in enumerate(task.src_offset)
                ),
                dst_offset=tuple(
                    o + (start - lo if i == d else 0)
                    for i, o in enumerate(task.dst_offset)
                ),
                nbytes=task.nbytes * (end - start) // shape[d],
                layer=task.layer,
            )
        )
        start = end
    return out


def execute_plan(
    plan: TransferPlan,
    src_stores: dict[int, RankStore],
    dst_stores: dict[int, RankStore],
    staging_bytes: int = DEFAULT_STAGING_BYTES,
    zero_copy_local: bool = True,
) -> StreamStats:
    """Run Algorithm 1 over simulated ranks.

    For each layer ℓ (ascending; -1 = non-layer state first): source ranks
    "send" the planned slices; each destination rank receives them into its
    staging buffer (≤ ``staging_bytes`` in flight, flushed by assembling
    into the destination shard), then a barrier ends the layer.
    """
    stats = StreamStats()
    layers = plan.layers()
    for layer in layers:
        tasks = plan.by_layer(layer)
        # group by destination rank — each dst drains its own staging buffer
        by_dst: dict[int, list[TransferTask]] = {}
        for t in tasks:
            by_dst.setdefault(t.dst_rank, []).append(t)
        for dst_rank, dtasks in by_dst.items():
            dst = dst_stores[dst_rank]
            staging_used = 0
            for task in dtasks:
                if task.local and zero_copy_local:
                    _apply_copy(src_stores[task.src_rank], dst, task)
                    stats.local_bytes += task.nbytes
                    continue
                for chunk in _chunk_task(task, staging_bytes):
                    stats.chunks += 1
                    if staging_used + chunk.nbytes > staging_bytes:
                        # flush: everything staged so far is assembled into
                        # the destination shard; buffer is reused
                        staging_used = 0
                    staging_used += chunk.nbytes
                    stats.peak_staging_bytes = max(
                        stats.peak_staging_bytes, staging_used
                    )
                    _apply_copy(src_stores[chunk.src_rank], dst, chunk)
                    stats.network_bytes += chunk.nbytes
            stats.per_layer_bytes[layer] = (
                stats.per_layer_bytes.get(layer, 0)
                + sum(t.nbytes for t in dtasks)
            )
        stats.barriers += 1
        stats.layers_streamed += 1
    return stats


def _apply_copy(src: RankStore, dst: RankStore, task: TransferTask) -> None:
    shape = task.shape()
    ssl = tuple(slice(o, o + s) for o, s in zip(task.src_offset, shape))
    dsl = tuple(slice(o, o + s) for o, s in zip(task.dst_offset, shape))
    dst.shards[task.tensor][dsl] = src.shards[task.tensor][ssl]
