"""Shadow World construction (paper §4.4 'Parallel Worlds').

While the Active World keeps training, a background thread (the Companion
Manager's worker) builds the target world. The JAX mapping of the paper's
Prepare phase:

  1. mesh construction over the target device set   (process-group analogue)
  2. ``lower()`` — trace + StableHLO + sharding inference. Device-free: this
     IS the mock-process-group warmup (local work, no coordination); the
     standalone abstract-mesh variant lives in core/mock_groups.py.
  3. ``compile()`` — XLA compilation + executable load onto the target
     devices (the NCCL-communicator-setup + JIT-warmup analogue).

All three run off the critical path; §6.3's steady-state-interference
experiment is reproduced in benchmarks/bench_interference.py.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ParallelConfig


@dataclass
class WorldHandle:
    """Everything the training loop needs from a world: the communicator
    analogue (mesh) + pre-compiled executable + shardings."""

    parallel: ParallelConfig
    mesh: Mesh
    step_fn: Callable  # compiled train step (jax.stages.Compiled)
    shardings: Any  # (param_sh, opt_sh, batch_sh)
    gen_id: int = -1
    timings: dict = field(default_factory=dict)
    # split-step commit executables (overlapped reconfiguration): the
    # optimizer-only step for THIS world (compiled in the shadow thread so
    # it never touches the critical path), and a grads-only step compiled
    # on demand for the world being left.
    update_fn: Optional[Callable] = None
    grad_fn: Optional[Callable] = None
    # (src ParallelConfig, specs, TransferPlan) computed during Prepare so
    # the commit pause never pays the planning cost
    plan_bundle: Any = None
    released: bool = False

    def release(self) -> None:
        """Drop the executables/mesh/sharding references so device memory
        (compiled programs and their embedded constants) is reclaimable
        immediately instead of whenever GC finds the handle. Idempotent;
        a released handle must never be trained on or pooled again."""
        self.step_fn = None
        self.update_fn = None
        self.grad_fn = None
        self.shardings = None
        self.mesh = None
        self.plan_bundle = None
        self.released = True


class ShadowBuilder:
    """Builds a WorldHandle in a background thread; poll ``ready`` — the
    Companion Manager thread of the paper's §4.5.1.

    ``on_discard`` is invoked exactly once with the completed handle when
    the builder was abandoned — from the worker thread if the abandon
    preceded completion, from ``abandon()`` itself otherwise. The default
    releases the world's device memory (an orphaned build used to pin its
    mesh + executables until GC); the controller overrides it to deposit
    the world into the warm :class:`~repro.core.world_pool.WorldPool`.
    """

    def __init__(
        self,
        build_fn: Callable[[], WorldHandle],
        gen_id: int,
        on_discard: Optional[Callable[[WorldHandle], None]] = None,
    ):
        self._build_fn = build_fn
        self.gen_id = gen_id
        self._result: Optional[WorldHandle] = None
        self._error: Optional[BaseException] = None
        self._done = threading.Event()
        # non-daemon: a daemon thread killed inside an XLA compile at
        # interpreter exit segfaults/aborts the process; Python joins
        # non-daemon threads cleanly (exit waits out an in-flight build
        # instead of crashing)
        self._thread = threading.Thread(target=self._run, daemon=False)
        # stamped when the worker thread starts, NOT at construction:
        # callers (the warm pool above all) routinely construct builders
        # well before starting them, and stamping in __init__ silently
        # inflated prepare_total_s by the construction→start gap
        self.started_at: Optional[float] = None
        self.abandoned = False
        self._on_discard = on_discard
        self._discard_lock = threading.Lock()
        self._discarded = False

    def start(self) -> "ShadowBuilder":
        self._thread.start()
        return self

    def _run(self) -> None:
        self.started_at = time.perf_counter()
        try:
            handle = self._build_fn()
            handle.gen_id = self.gen_id
            handle.timings["prepare_total_s"] = time.perf_counter() - self.started_at
            self._result = handle
        except BaseException as e:  # surfaced on result()
            self._error = e
        finally:
            self._done.set()
        self._maybe_discard()

    @property
    def ready(self) -> bool:
        return self._done.is_set()

    def _maybe_discard(self) -> None:
        with self._discard_lock:
            if not self.abandoned or self._discarded or self._result is None:
                return
            self._discarded = True
            handle = self._result
        if self._on_discard is not None:
            self._on_discard(handle)
        else:
            handle.release()

    def abandon(self) -> None:
        """Retarget/cancel semantics (paper §7 'Concurrent reconfiguration
        events'): the worker thread cannot be killed mid-``compile()``, so
        the builder is marked abandoned and its world discarded on
        completion (``on_discard`` — release or pool deposit; it no longer
        lingers until GC). The controller may start a fresh builder
        immediately — the stale thread only ever writes into this object."""
        self.abandoned = True
        if self._done.is_set():
            self._maybe_discard()

    def result(self, timeout: Optional[float] = None) -> WorldHandle:
        if not self._done.wait(timeout):
            raise TimeoutError("shadow world not ready")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


def abstract_batch(cfg: ModelConfig, global_batch: int, seq_len: int) -> dict:
    """Abstract batch pytree for AOT lowering (and its compile-time shape
    contract). ``frames`` resolves the configured compute dtype through
    ``jnp.dtype`` — the old two-entry ``{"bfloat16","float32"}`` literal
    map raised KeyError for every other configured dtype (float16, fp8
    experiments, ...)."""
    import jax.numpy as jnp

    abatch = {"tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32)}
    if cfg.family == "encdec":
        abatch["frames"] = jax.ShapeDtypeStruct(
            (global_batch, seq_len, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    return abatch


def _abstract_opt(cfg: ModelConfig, aparams, compression: str):
    """Abstract optimizer state matching ``adamw_init`` (+ error-feedback
    buffers under int8_ef compression)."""
    import jax.numpy as jnp

    from repro.optim import adamw_init

    aopt = jax.eval_shape(lambda: adamw_init(aparams))
    if compression == "int8_ef":
        aopt = dict(aopt)
        aopt["ef"] = jax.tree_util.tree_map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), aparams
        )
    return aopt


def build_update_world_fn(
    cfg: ModelConfig,
    mesh: Mesh,
    parallel: ParallelConfig,
    opt_cfg,
    compression: str = "none",
    aot: bool = True,
):
    """Optimizer-only executable for the split-step commit of ``mesh``'s
    world. Factored out of :func:`build_train_world` so a warm pool hit
    whose cached handle predates split-step mode can backfill ``update_fn``
    without re-running the full Prepare."""
    from repro.distribution.step import jit_update_step
    from repro.models.model import abstract_params

    jitted_u, _ = jit_update_step(
        cfg, mesh, opt_cfg, compression=compression, parallel=parallel
    )
    if not aot:
        return jitted_u
    aparams = abstract_params(cfg)
    aopt = _abstract_opt(cfg, aparams, compression)
    agrads = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), aparams
    )
    return jitted_u.lower(agrads, aopt, aparams).compile()


def build_train_world(
    cfg: ModelConfig,
    parallel: ParallelConfig,
    opt_cfg,
    global_batch: int,
    seq_len: int,
    microbatches: int = 1,
    devices=None,
    compression: str = "none",
    aot: bool = True,
    hint_version: str | None = None,
    split_step: bool = False,
) -> WorldHandle:
    """Synchronous world construction (the shadow thread's body)."""
    from repro.distribution.sharding import make_elastic_mesh
    from repro.distribution.step import jit_train_step
    from repro.models.model import abstract_params

    timings: dict = {}
    t0 = time.perf_counter()
    mesh = make_elastic_mesh(parallel, devices=devices)
    timings["mesh_s"] = time.perf_counter() - t0

    if parallel.pp > 1:
        from repro.distribution.pipeline import jit_pipeline_train_step

        jitted, shardings = jit_pipeline_train_step(
            cfg, mesh, parallel, opt_cfg, global_batch, max(microbatches, parallel.pp)
        )
    else:
        jitted, shardings = jit_train_step(
            cfg,
            mesh,
            opt_cfg,
            global_batch,
            microbatches=microbatches,
            compression=compression,
            hint_version=hint_version,
        )

    step_fn = jitted
    if aot:
        aparams = abstract_params(cfg)
        aopt = _abstract_opt(cfg, aparams, compression)
        abatch = abstract_batch(cfg, global_batch, seq_len)
        t0 = time.perf_counter()
        lowered = jitted.lower(aparams, aopt, abatch)  # mock-warmup analogue
        timings["lower_s"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        step_fn = lowered.compile()  # communicator-setup analogue
        timings["compile_s"] = time.perf_counter() - t0

    update_fn = None
    if split_step:
        # optimizer-only executable for the split-step commit: compiled
        # here, in the shadow thread, so the commit pause never pays it
        t0 = time.perf_counter()
        update_fn = build_update_world_fn(
            cfg, mesh, parallel, opt_cfg, compression=compression, aot=aot
        )
        timings["update_compile_s"] = time.perf_counter() - t0

    return WorldHandle(
        parallel=parallel,
        mesh=mesh,
        step_fn=step_fn,
        shardings=shardings,
        timings=timings,
        update_fn=update_fn,
    )
