"""Shared record fields for the classified plan IR (DESIGN.md §13).

``ReconfigRecord`` (controller), ``OverlapReport`` (session), and
``EventOutcome`` (trace scheduler) all surface the same reuse accounting;
before this mixin each re-declared ``reused_layers`` independently and the
definitions drifted. ``kw_only`` keeps the inheriting dataclasses free to
declare required positional fields of their own.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class ReuseRecordMixin:
    # layers whose bytes were NOT re-streamed: resident layers plus layers
    # adopted from a prior in-flight session on retarget
    reused_layers: int = field(default=0, kw_only=True)
    # layers fully resident under the classified plan (subset of reused)
    resident_layers: int = field(default=0, kw_only=True)
    # plan bytes that never crossed a wire because they were already in place
    skipped_bytes: int = field(default=0, kw_only=True)
