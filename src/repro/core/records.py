"""Shared record fields for the classified plan IR (DESIGN.md §13).

``ReconfigRecord`` (controller), ``OverlapReport`` (session), and
``EventOutcome`` (trace scheduler) all surface the same reuse accounting;
before this mixin each re-declared ``reused_layers`` independently and the
definitions drifted. ``kw_only`` keeps the inheriting dataclasses free to
declare required positional fields of their own.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class ReuseRecordMixin:
    # layers whose bytes were NOT re-streamed: resident layers plus layers
    # adopted from a prior in-flight session on retarget
    reused_layers: int = field(default=0, kw_only=True)
    # layers fully resident under the classified plan (subset of reused)
    resident_layers: int = field(default=0, kw_only=True)
    # plan CELLS classified resident — the unit skipped_bytes accrues in. A
    # partially-resident layer contributes cells (and bytes) here without
    # counting in resident_layers, so the accounting identity is
    # ``skipped_bytes > 0 iff resident_cells > 0``, NOT resident_layers
    resident_cells: int = field(default=0, kw_only=True)
    # plan bytes that never crossed a wire because they were already in place
    skipped_bytes: int = field(default=0, kw_only=True)
    # compressed wire format (DESIGN.md §14): bytes the plan says streamed
    # vs bytes that physically crossed the wire under the wire policy
    # (quantized payload + sidecar scales); equal when lossless
    logical_bytes: int = field(default=0, kw_only=True)
    wire_bytes: int = field(default=0, kw_only=True)


def reuse_identity_ok(rec) -> bool:
    """The reuse-accounting identity every emitted record must satisfy.

    ``skipped_bytes`` accrues per resident CELL, so bytes can be skipped on
    a plan with zero fully-resident layers (a partially-resident layer).
    The invariant that cannot drift is therefore cell-level: bytes were
    skipped iff some cell was resident. Works on any record carrying the
    :class:`ReuseRecordMixin` fields (ReconfigRecord, OverlapReport,
    EventOutcome) or a dict serialization of one.
    """
    if isinstance(rec, dict):
        skipped, cells = rec.get("skipped_bytes", 0), rec.get("resident_cells", 0)
    else:
        skipped, cells = rec.skipped_bytes, rec.resident_cells
    return (skipped > 0) == (cells > 0)
