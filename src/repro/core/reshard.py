"""Live-path resharder over jax.Arrays (paper §4.6.2 on the live worlds).

Both entry points execute a :class:`TransferPlan` through the shared
:class:`~repro.reshard.engine.ReshardEngine` + LiveExecutor — the same
protocol code the simulated-rank oracle runs, so chunking, staging bounds
and byte accounting cannot diverge between the two paths:

  * :func:`live_reshard_planned` — the controller's path: an intersection
    plan (core/intersection.py) computed from the model's resource view
    drives layer-ordered streaming of named state collections.
  * :func:`live_reshard` — plan-less pytree fallback (checkpoint resume,
    ad-hoc relayouts): synthesizes a one-task-per-leaf plan (each leaf its
    own streaming "layer") and runs the same engine, so oversized leaves
    are chunked by the shared chunker rather than a private loop.

Memory: the plan-less path with ``donate=True`` frees each source leaf as
its layer lands, so peak stays ~1x state + staging (invariant I2). The
plan-driven controller path keeps both worlds' storage resident until the
pointer swap — that is the paper's Active/Shadow coexistence, and the
destination storage is required for training regardless (Theorem 1,
item 2); the *transfer* overhead beyond it is still bounded by the
staging budget. On TPU pods the underlying ``device_put``/pack/unpack
lower to ICI DMA copies computed from exactly the kind of
shard-intersection the planner emits.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.intersection import TransferPlan, TransferTask, plan_transfer
from repro.core.resource_view import TensorSpec, build_tensor_specs
from repro.reshard.engine import (
    DEFAULT_STAGING_BYTES,
    ReshardEngine,
    StreamStats,
)
from repro.reshard.executors import LiveExecutor
from repro.utils.pytree import tree_from_paths, tree_paths

__all__ = [
    "DEFAULT_STAGING_BYTES",
    "ReshardReport",
    "live_reshard",
    "live_reshard_planned",
    "named_state_leaves",
    "plan_state_transfer",
    "rebuild_state",
]


@dataclass
class ReshardReport:
    leaves: int = 0
    chunked_leaves: int = 0
    moved_bytes: int = 0
    seconds: float = 0.0
    max_inflight_bytes: int = 0
    stats: Optional[StreamStats] = None


def _leaf_bytes(x) -> int:
    return int(math.prod(x.shape)) * np.dtype(x.dtype).itemsize


# ---------------------------------------------------------------------------
# Plan-less pytree path (fallback: checkpoint resume, ad-hoc relayout)
# ---------------------------------------------------------------------------


def live_reshard(
    state: Any,
    target_shardings: Any,
    staging_bytes: int = DEFAULT_STAGING_BYTES,
    donate: bool = True,
) -> tuple[Any, ReshardReport]:
    """Reshard a pytree of jax.Arrays to new shardings, leaf-streamed.

    Returns (new_state, report). Leaves already laid out as requested are
    passed through untouched (delta optimization). Each remaining leaf is
    a one-task streaming layer of a synthetic plan; the shared engine
    chunks oversized leaves to the staging budget. With ``donate=True``
    (default) each source leaf's device buffers are freed as soon as its
    layer lands — peak memory stays ~1x state + staging; the caller must
    not touch the input tree again. ``donate=False`` keeps sources intact
    (fallback safety: the Active World's storage must stay valid until
    commit — invariant I4).
    """
    flat, treedef = jax.tree_util.tree_flatten(state)
    flat_sh = treedef.flatten_up_to(target_shardings)
    report = ReshardReport()
    t0 = time.perf_counter()

    specs: list[TensorSpec] = []
    tasks: list[TransferTask] = []
    move_sh: dict[str, Any] = {}
    out_leaves: dict[int, Any] = {}
    for i, (leaf, sh) in enumerate(zip(flat, flat_sh)):
        report.leaves += 1
        if getattr(leaf, "sharding", None) == sh:
            out_leaves[i] = leaf  # delta optimization: zero-copy no-op
            continue
        name = f"leaf{i}"
        shape = tuple(int(d) for d in leaf.shape)
        nbytes = _leaf_bytes(leaf)
        specs.append(
            TensorSpec(
                name=name,
                shape=shape,
                dtype=str(leaf.dtype),
                roles=("none",) * len(shape),
                stage_scope="all",
                collection="state",
            )
        )
        # src 0 -> dst 1: fictitious ranks; "non-local" so the engine runs
        # the chunked staging path (rank identity is meaningless here)
        tasks.append(
            TransferTask(
                tensor=name,
                collection="state",
                src_rank=0,
                dst_rank=1,
                bounds=tuple((0, d) for d in shape),
                src_offset=(0,) * len(shape),
                dst_offset=(0,) * len(shape),
                nbytes=nbytes,
                layer=i,  # one streaming layer per leaf
            )
        )
        move_sh[name] = sh
        if nbytes > staging_bytes and leaf.ndim >= 1 and shape[0] > 1:
            report.chunked_leaves += 1

    if tasks:
        plan = TransferPlan(tasks=tasks, cfg_src=None, cfg_dst=None)
        spec_map = {s.name: s for s in specs}
        src = {t.tensor: flat[int(t.tensor[4:])] for t in tasks}
        executor = LiveExecutor(
            spec_map, src, move_sh, staging_bytes, free_sources=donate
        )
        engine = ReshardEngine(plan, executor, staging_bytes=staging_bytes)
        stats = engine.run()
        t1 = time.perf_counter()
        executor.block_until_ready()
        stats.drain_seconds += time.perf_counter() - t1
        for t in tasks:
            out_leaves[int(t.tensor[4:])] = executor.results()[t.tensor]
        report.moved_bytes += stats.network_bytes + stats.local_bytes
        report.max_inflight_bytes = stats.peak_staging_bytes
        report.stats = stats

    report.seconds = time.perf_counter() - t0
    out = [out_leaves[i] for i in range(len(flat))]
    return jax.tree_util.tree_unflatten(treedef, out), report


# ---------------------------------------------------------------------------
# Plan-driven path (the controller's live transfer)
# ---------------------------------------------------------------------------


def plan_state_transfer(
    cfg: ModelConfig,
    cfg_src: ParallelConfig,
    cfg_dst: ParallelConfig,
    source_policy: str = "nearest",
    allowed_src=None,
) -> tuple[list[TensorSpec], TransferPlan]:
    """Specs + intersection plan for the live training state.

    ``zero_sharding=False``: the live runtime shards optimizer moments like
    parameters (distribution/sharding.py), not ZeRO-split, so the plan's
    byte accounting matches what actually moves.

    ``allowed_src`` restricts sources to a survivor set (peer recovery,
    DESIGN.md §15); cells nobody in the set can donate come back as
    ``kind == "lost"``.
    """
    from repro.models.transformer import block_program

    specs = build_tensor_specs(cfg, include_optimizer=True, zero_sharding=False)
    plan = plan_transfer(
        specs,
        cfg_src,
        cfg_dst,
        source_policy=source_policy,
        layer_granular=True,
        num_positions=len(block_program(cfg)),
        allowed_src=allowed_src,
    )
    return specs, plan


def named_state_leaves(params: Any, opt_state: Any) -> tuple[dict[str, Any], dict]:
    """Flatten live training state into the resource view's tensor names.

    Returns (named leaves spanning params/mu/nu, leftovers) — leftovers
    (step count, error-feedback buffers, …) are not in the resource view
    and reshard through the plan-less fallback.
    """
    named: dict[str, Any] = {}
    for path, leaf in tree_paths(params).items():
        named[f"params/{path}"] = leaf
    extras: dict = {}
    for coll, sub in opt_state.items():
        if coll in ("mu", "nu"):
            for path, leaf in tree_paths(sub).items():
                named[f"{coll}/{path}"] = leaf
        else:
            extras[coll] = sub
    return named, extras


def rebuild_state(
    named: dict[str, Any], params_like: Any, opt_like: Any, extras: dict
) -> tuple[Any, Any]:
    """Inverse of named_state_leaves."""
    p_paths = {p: named[f"params/{p}"] for p in tree_paths(params_like)}
    params = tree_from_paths(p_paths, params_like)
    opt: dict[str, Any] = {}
    for coll, sub in opt_like.items():
        if coll in ("mu", "nu"):
            opt[coll] = tree_from_paths(
                {p: named[f"{coll}/{p}"] for p in tree_paths(sub)}, sub
            )
        else:
            opt[coll] = extras[coll]
    return params, opt


def live_reshard_planned(
    specs: list[TensorSpec],
    plan: TransferPlan,
    named_leaves: dict[str, Any],
    target_shardings: dict[str, Any],
    staging_bytes: int = DEFAULT_STAGING_BYTES,
    layers: Optional[list[int]] = None,
    wire_policy=None,
    wire_bw_bytes_s: float | None = None,
) -> tuple[dict[str, Any], StreamStats]:
    """Execute an intersection plan on live jax.Arrays via the shared
    engine. Returns (destination leaves by tensor name, stats).

    ``wire_policy`` (None = lossless) selects the per-collection wire
    format for remote chunks; ``wire_bw_bytes_s`` enables the executor's
    emulated-interconnect timing (benchmarks only)."""
    spec_map = {s.name: s for s in specs}
    executor = LiveExecutor(
        spec_map, named_leaves, target_shardings, staging_bytes,
        wire_policy=wire_policy, wire_bw_bytes_s=wire_bw_bytes_s,
    )
    engine = ReshardEngine(
        plan, executor, staging_bytes=staging_bytes, wire_policy=wire_policy
    )
    stats = engine.run(layers)
    t1 = time.perf_counter()
    executor.block_until_ready()
    stats.drain_seconds += time.perf_counter() - t1
    return executor.results(), stats
