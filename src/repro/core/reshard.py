"""Live-path resharder over jax.Arrays (paper §4.6.2 on the live worlds).

Moves the training state from the Active World's mesh/shardings to the
Shadow World's, one leaf (layer) at a time, with donation — so peak extra
device memory is bounded by the largest in-flight chunk rather than a second
full state copy (invariant I2). Leaves exceeding the staging budget are
streamed in sub-chunks along their largest dim, assembled into the
(pre-required) destination storage — the jax.Array realization of
Algorithm 1; byte-level semantics are validated against core/streaming.py.

On TPU pods ``jax.device_put`` between shardings lowers to ICI DMA copies
computed from exactly the kind of shard-intersection the planner emits; the
plan (core/intersection.py) is still computed for byte accounting and for
the scheduling benchmarks.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_STAGING_BYTES = 512 * 1024 * 1024


@dataclass
class ReshardReport:
    leaves: int = 0
    chunked_leaves: int = 0
    moved_bytes: int = 0
    seconds: float = 0.0
    max_inflight_bytes: int = 0


def _leaf_bytes(x) -> int:
    return int(math.prod(x.shape)) * np.dtype(x.dtype).itemsize


def live_reshard(
    state: Any,
    target_shardings: Any,
    staging_bytes: int = DEFAULT_STAGING_BYTES,
    donate: bool = True,
) -> tuple[Any, ReshardReport]:
    """Reshard a pytree of jax.Arrays to new shardings, leaf-streamed.

    Returns (new_state, report). Sources are deleted as soon as their leaf
    lands (bounded memory); set donate=False to keep sources (fallback
    safety: the Active World's storage must stay intact until commit —
    invariant I4 — so the controller only donates after the switch point).
    """
    flat, treedef = jax.tree_util.tree_flatten(state)
    flat_sh = treedef.flatten_up_to(target_shardings)
    report = ReshardReport()
    t0 = time.perf_counter()
    out = []
    for leaf, sh in zip(flat, flat_sh):
        nbytes = _leaf_bytes(leaf)
        # delta optimization: identical sharding => zero-copy no-op task
        if getattr(leaf, "sharding", None) == sh:
            out.append(leaf)
            report.leaves += 1
            continue
        if nbytes > staging_bytes and leaf.ndim >= 1 and leaf.shape[0] > 1:
            new, inflight = _reshard_chunked(leaf, sh, staging_bytes)
            report.chunked_leaves += 1
        else:
            # donate=True lets the runtime free/reuse source buffers safely
            # (manual delete() would destroy buffers device_put aliased)
            new = jax.device_put(leaf, sh, donate=donate)
            inflight = nbytes
        new.block_until_ready()
        report.leaves += 1
        report.moved_bytes += nbytes
        report.max_inflight_bytes = max(report.max_inflight_bytes, inflight)
        out.append(new)
    report.seconds = time.perf_counter() - t0
    return jax.tree_util.tree_unflatten(treedef, out), report


def _reshard_chunked(leaf, sharding, staging_bytes: int):
    """Stream one oversized leaf through dim-0 chunks of ≤ staging bytes."""
    n0 = leaf.shape[0]
    per_row = _leaf_bytes(leaf) // n0
    rows = max(1, staging_bytes // per_row)

    # allocate destination storage directly with the target sharding
    target = jax.jit(lambda: jnp.zeros(leaf.shape, leaf.dtype), out_shardings=sharding)()

    update = jax.jit(
        lambda tgt, chunk, start: jax.lax.dynamic_update_slice_in_dim(
            tgt, chunk, start, axis=0
        ),
        donate_argnums=(0,),
        out_shardings=sharding,
    )
    start = 0
    max_inflight = 0
    while start < n0:
        end = min(start + rows, n0)
        chunk = leaf[start:end]  # sliced on the source mesh
        chunk = jax.device_put(chunk, _chunk_sharding(sharding))
        target = update(target, chunk, start)
        max_inflight = max(max_inflight, per_row * (end - start))
        start = end
    target.block_until_ready()
    return target, max_inflight


def _chunk_sharding(sharding):
    """Chunk rows move with the target's non-dim0 layout; dim0 unsharded
    (chunks are smaller than the dim0 partition in general)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    if isinstance(sharding, NamedSharding):
        spec = list(sharding.spec) if sharding.spec else []
        if spec:
            spec[0] = None
        return NamedSharding(sharding.mesh, P(*spec))
    return sharding
