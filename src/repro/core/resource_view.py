"""Abstract Resource View (paper §4.6.1, App. A.2).

Training state is modeled as *logical tensors* (name, shape, dtype) plus a
sharding specification per parallel configuration — fully decoupled from
physical rank/device assignment. The view function ``V(T, C, r)`` (Def. A.1)
returns the hyper-rectangular index region of tensor ``T`` owned by rank
``r`` under configuration ``C``, or ``None`` when the rank holds no part of
it (e.g. wrong pipeline stage).

Dim roles:
  "pp"   — the stacked-layers axis, split contiguously across pipeline stages
  "tp"   — tensor-parallel split
  "ep"   — expert-parallel split (expert-stacked tensors)
  "dp"   — ZeRO split of optimizer moments across data-parallel ranks
  "none" — unsplit

Tensors without an "ep"/"dp" role are replicated across those mesh factors;
replication is what makes DP scale-out degenerate to a broadcast pattern and
scale-in to a discard (App. A.2.3) — the same geometry handles all of it.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.utils.pytree import axes_paths, tree_paths

# logical axes eligible for tensor-parallel splitting, in preference order
TP_AXES = (
    "ffn",
    "heads",
    "kv_heads",
    "vocab",
    "inner",
    "expert_in",
    "state",
    "ssm_heads",
    "embed",
)


@dataclass(frozen=True)
class TensorSpec:
    """A logical tensor of the training state."""

    name: str  # param-tree path, e.g. "params/blocks/pos0/mixer/wq"
    shape: tuple[int, ...]
    dtype: str
    roles: tuple[str, ...]  # per-dim role, len == len(shape)
    stage_scope: str = "stages"  # "stages" | "first" | "last" | "all"
    collection: str = "params"  # "params" | "mu" | "nu" | "step"

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * np.dtype(self.dtype).itemsize

    def layer_dim(self) -> Optional[int]:
        return self.roles.index("pp") if "pp" in self.roles else None


# ---------------------------------------------------------------------------
# Splitting geometry
# ---------------------------------------------------------------------------


def split_bounds(size: int, parts: int, idx: int) -> tuple[int, int]:
    """Balanced contiguous split (equal when divisible)."""
    base, rem = divmod(size, parts)
    lo = idx * base + min(idx, rem)
    hi = lo + base + (1 if idx < rem else 0)
    return lo, hi


def split_points(size: int, parts: int) -> list[int]:
    return [split_bounds(size, parts, i)[0] for i in range(parts)] + [size]


@dataclass(frozen=True)
class View:
    """Hyper-rectangle: per-dim [lo, hi)."""

    bounds: tuple[tuple[int, int], ...]

    def intersect(self, other: "View") -> Optional["View"]:
        out = []
        for (a0, a1), (b0, b1) in zip(self.bounds, other.bounds):
            lo, hi = max(a0, b0), min(a1, b1)
            if lo >= hi:
                return None
            out.append((lo, hi))
        return View(tuple(out))

    @property
    def size(self) -> int:
        return int(math.prod(h - l for l, h in self.bounds))

    def shape(self) -> tuple[int, ...]:
        return tuple(h - l for l, h in self.bounds)


def _role_factor_idx(
    role: str, cfg: ParallelConfig, coords: tuple[int, int, int, int]
) -> tuple[int, int]:
    dp_i, pp_i, ep_i, tp_i = coords
    return {
        "pp": (cfg.pp, pp_i),
        "tp": (cfg.tp, tp_i),
        "ep": (cfg.ep, ep_i),
        "dp": (cfg.dp, dp_i),
        "none": (1, 0),
    }[role]


def view_of(spec: TensorSpec, cfg: ParallelConfig, rank: int) -> Optional[View]:
    """The paper's V(T, C, r)."""
    coords = cfg.rank_coords(rank)
    dp_i, pp_i, ep_i, tp_i = coords
    if spec.stage_scope == "first" and pp_i != 0:
        return None
    if spec.stage_scope == "last" and pp_i != cfg.pp - 1:
        return None
    bounds = []
    for size, role in zip(spec.shape, spec.roles):
        parts, idx = _role_factor_idx(role, cfg, coords)
        bounds.append(split_bounds(size, parts, idx))
    return View(tuple(bounds))


def replica_sources(
    spec: TensorSpec, cfg: ParallelConfig, view: View
) -> list[int]:
    """All ranks of ``cfg`` whose view equals ``view`` (replicas).

    Used by the planner to pick a source among DP (and EP, for non-expert
    tensors) replicas — the topology-aware source-selection hook.
    """
    out = []
    for r in range(cfg.world_size):
        v = view_of(spec, cfg, r)
        if v is not None and v.bounds == view.bounds:
            out.append(r)
    return out


# ---------------------------------------------------------------------------
# Building the resource view of a model + optimizer
# ---------------------------------------------------------------------------


def _pick_tp_dim(axes: tuple[str, ...]) -> Optional[int]:
    for ax_name in TP_AXES:
        for d, a in enumerate(axes):
            if a == ax_name:
                return d
    return None


def _pick_zero_dim(roles: list[str], shape: tuple[int, ...]) -> Optional[int]:
    """Largest unsplit dim (greedy per-tensor ZeRO-1)."""
    best = None
    for d, r in enumerate(roles):
        if r == "none":
            if best is None or shape[d] > shape[best]:
                best = d
    return best


def build_tensor_specs(
    cfg: ModelConfig,
    include_optimizer: bool = True,
    zero_sharding: bool = True,
) -> list[TensorSpec]:
    """Logical tensors of (params [+ AdamW moments]) for ``cfg``.

    Roles are assigned from the model's logical axes only — the spec list is
    valid under ANY ParallelConfig (a role names *which* factor splits a dim;
    the view function applies the factor's degree from the config, with
    balanced splits when not divisible). One description, many
    configurations: the decoupling the Abstract Resource View requires.
    """
    from repro.models.model import abstract_params, param_logical_axes

    params = tree_paths(abstract_params(cfg))
    axes = axes_paths(param_logical_axes(cfg))
    specs: list[TensorSpec] = []
    for path, leaf in params.items():
        ax = axes[path]
        shape = tuple(int(x) for x in leaf.shape)
        roles = ["none"] * len(shape)
        scope = "stages"
        if ax and ax[0] == "layers":
            roles[0] = "pp"
        else:
            # non-layer tensors: embed -> first stage, head/final_norm -> last
            scope = "first" if path.startswith("embed") else "last"
        # expert dim
        for d, a in enumerate(ax):
            if a == "expert" and roles[d] == "none":
                roles[d] = "ep"
        # one tp dim
        free_axes = tuple(
            a if roles[d] == "none" else "_" for d, a in enumerate(ax)
        )
        tp_d = _pick_tp_dim(free_axes)
        if tp_d is not None:
            roles[tp_d] = "tp"
        specs.append(
            TensorSpec(
                name=f"params/{path}",
                shape=shape,
                dtype=str(leaf.dtype),
                roles=tuple(roles),
                stage_scope=scope,
                collection="params",
            )
        )
        if include_optimizer:
            for coll in ("mu", "nu"):
                oroles = list(roles)
                if zero_sharding:
                    zd = _pick_zero_dim(oroles, shape)
                    if zd is not None:
                        oroles[zd] = "dp"
                specs.append(
                    TensorSpec(
                        name=f"{coll}/{path}",
                        shape=shape,
                        dtype="float32",
                        roles=tuple(oroles),
                        stage_scope=scope,
                        collection=coll,
                    )
                )
    return specs


def layer_of_spec(spec: TensorSpec, period: int) -> int:
    """Coarse layer id for streaming order: stacked tensors stream per
    period-slice; non-layer tensors get layer -1 (embeddings, head)."""
    return -1 if spec.layer_dim() is None else 0


def total_state_bytes(specs: list[TensorSpec]) -> int:
    return sum(s.nbytes for s in specs)
