"""Generation state machine (paper §4.5.1, Fig. 4).

States: Stable → Prepare → Ready → Switch → Cleanup → Stable. Each world
configuration carries a monotonic generation id; at most two generations
coexist (invariant I2) and stale references to an old generation are
rejected after the switch. Thread-safe: the Companion Manager's background
thread drives Prepare→Ready while the training loop polls.
"""

from __future__ import annotations

import enum
import threading
from dataclasses import dataclass, field
from typing import Optional


class GenState(enum.Enum):
    STABLE = "stable"
    PREPARE = "prepare"
    READY = "ready"
    SWITCH = "switch"
    CLEANUP = "cleanup"


_ALLOWED = {
    GenState.STABLE: {GenState.PREPARE},
    GenState.PREPARE: {GenState.READY, GenState.STABLE},  # STABLE = cancel
    GenState.READY: {GenState.SWITCH, GenState.STABLE},  # STABLE = cancel
    GenState.SWITCH: {GenState.CLEANUP},
    GenState.CLEANUP: {GenState.STABLE},
}


class InvalidTransition(RuntimeError):
    pass


class StaleGeneration(RuntimeError):
    pass


@dataclass
class Generation:
    gen_id: int
    description: str = ""
    payload: object = None  # world handle (mesh + compiled step + shardings)


class GenerationMachine:
    """Tracks the active and (at most one) shadow generation."""

    def __init__(self):
        self._lock = threading.RLock()
        self._state = GenState.STABLE
        self._active = Generation(gen_id=0, description="initial")
        self._shadow: Optional[Generation] = None
        self._next_id = 1
        self.history: list[tuple[str, int]] = [("stable", 0)]

    # -- introspection ----------------------------------------------------
    @property
    def state(self) -> GenState:
        with self._lock:
            return self._state

    @property
    def active(self) -> Generation:
        with self._lock:
            return self._active

    @property
    def shadow(self) -> Optional[Generation]:
        with self._lock:
            return self._shadow

    def generations_alive(self) -> int:
        with self._lock:
            return 1 + (self._shadow is not None)

    # -- transitions -------------------------------------------------------
    def _to(self, new: GenState) -> None:
        if new not in _ALLOWED[self._state]:
            raise InvalidTransition(f"{self._state.value} -> {new.value}")
        self._state = new
        self.history.append((new.value, self._active.gen_id))

    def begin_prepare(self, description: str = "") -> Generation:
        with self._lock:
            self._to(GenState.PREPARE)
            assert self._shadow is None, "invariant I2: at most two generations"
            self._shadow = Generation(gen_id=self._next_id, description=description)
            self._next_id += 1
            return self._shadow

    def mark_ready(self, gen_id: int, payload: object = None) -> None:
        with self._lock:
            self._check_shadow(gen_id)
            if payload is not None:
                self._shadow.payload = payload
            self._to(GenState.READY)

    def begin_switch(self, gen_id: int) -> Generation:
        with self._lock:
            self._check_shadow(gen_id)
            self._to(GenState.SWITCH)
            return self._shadow

    def commit_switch(self, gen_id: int) -> Generation:
        """Atomic swap: shadow becomes active; old world enters Cleanup."""
        with self._lock:
            self._check_shadow(gen_id)
            if self._state != GenState.SWITCH:
                raise InvalidTransition(f"commit from {self._state.value}")
            old = self._active
            self._active = self._shadow
            self._shadow = None
            self._to(GenState.CLEANUP)
            return old

    def finish_cleanup(self) -> None:
        with self._lock:
            self._to(GenState.STABLE)

    def cancel(self) -> None:
        """Abandon a pending shadow (e.g. target topology became stale,
        paper §7 'Concurrent reconfiguration events')."""
        with self._lock:
            if self._state not in (GenState.PREPARE, GenState.READY):
                raise InvalidTransition(f"cancel from {self._state.value}")
            self._shadow = None
            self._to(GenState.STABLE)

    def _check_shadow(self, gen_id: int) -> None:
        if self._shadow is None or self._shadow.gen_id != gen_id:
            raise StaleGeneration(
                f"generation {gen_id} is not the pending shadow "
                f"(shadow={self._shadow.gen_id if self._shadow else None})"
            )
