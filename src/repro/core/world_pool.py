"""Speculative warm world pool (DESIGN.md §12).

Every resize that reaches the controller cold pays a full Prepare —
``build_train_world``'s lower + compile — even when the job has already
visited the target configuration (spot capacity oscillates between a few
world sizes) or when an idle gap gave us time to build it ahead of the
warning. DynaTrain and ElasWave (PAPERS.md) both show that pre-building
likely target configurations off the critical path is what makes fast
parallelism switching pay off under *repeated* elasticity events; this
module is that cache.

A :class:`WorldPool` holds completed :class:`~repro.core.shadow.WorldHandle`s
keyed by everything that shapes the compiled executables — model config,
``ParallelConfig``, the device-set fingerprint, batch/sequence shapes,
compression and hint versions (``LiveRController.pool_key``). Warm worlds
enter the pool from three producers:

  * **retired active worlds** — after a commit, the old world's mesh and
    executables are still valid for its configuration; resizing back is
    the single most common elasticity pattern (walk-down then walk-up);
  * **abandoned shadows** — a retargeted/cancelled builder's world
    completes in its orphaned thread and would otherwise pin device memory
    until GC; the pool keeps it warm instead (bounded, LRU-released);
  * **speculative prefetch** — ``LiveRController.prefetch_world`` builds
    the topology search's likely next targets while the controller is idle
    (driven by ``repro.elastic.scheduler.PrefetchPolicy``).

Consumers: ``request_resize``/``retarget_resize`` ``take()`` a matching
world and skip straight past lower+compile to transfer planning (the
record's ``warm_hit`` flag feeds the ``DeadlineEstimator``'s separate
warm/cold prepare estimates), and ``fail_stop_recover`` uses a warm world
the way it uses residual shadow work.

Ownership discipline: ``take`` transfers ownership OUT of the pool (the
handle is about to become the live shadow/active world — the pool must
never release it underneath the controller); ``put`` transfers ownership
IN (eviction calls :meth:`WorldHandle.release`, dropping the executable,
mesh and sharding references so device memory is reclaimable immediately
rather than at GC's leisure). The pool is thread-safe: abandoned builders
deposit from their daemon threads while the training loop takes/puts.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.shadow import WorldHandle

# a pool key is an opaque hashable tuple built by the owning controller
PoolKey = tuple


@dataclass
class PoolStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    evictions: int = 0
    duplicate_puts: int = 0

    def to_dict(self) -> dict:
        return dict(self.__dict__)


class WorldPool:
    """LRU cache of warm :class:`WorldHandle`s with explicit release.

    ``capacity`` bounds how many compiled worlds stay resident — each entry
    pins its executables (and their device constants), so the pool is the
    memory/latency knob: 2–3 covers the walk-down/walk-up oscillation that
    dominates spot traces.
    """

    def __init__(self, capacity: int = 2):
        assert capacity >= 1, "a zero-capacity pool is just a release()"
        self.capacity = capacity
        self._lock = threading.RLock()
        self._entries: "OrderedDict[PoolKey, WorldHandle]" = OrderedDict()
        self.stats = PoolStats()

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def contains(self, key: PoolKey) -> bool:
        with self._lock:
            return key in self._entries

    def keys(self) -> list:
        with self._lock:
            return list(self._entries)

    def peek(self, key: PoolKey) -> Optional[WorldHandle]:
        """Borrow the resident handle without transferring ownership.

        Read-only uses (e.g. grabbing target shardings to pre-warm the
        transfer executables, DESIGN.md §15): the entry stays pooled, is
        not counted as a hit/miss, and may still be evicted later — the
        caller must not retain the handle past the borrow."""
        with self._lock:
            return self._entries.get(key)

    # -- consume ----------------------------------------------------------
    def take(self, key: PoolKey) -> Optional[WorldHandle]:
        """Remove and return the warm world for ``key``, or None.

        Ownership transfers to the caller: a taken world is about to become
        a live generation, and the pool must never ``release()`` it behind
        the controller's back (which LRU eviction would eventually do)."""
        with self._lock:
            handle = self._entries.pop(key, None)
            if handle is None:
                self.stats.misses += 1
            else:
                self.stats.hits += 1
            return handle

    # -- produce ----------------------------------------------------------
    def put(self, key: PoolKey, handle: WorldHandle) -> None:
        """Deposit a completed world; evicts (and releases) LRU overflow.

        A duplicate key keeps the resident entry — it is equivalent by
        construction of the key — and releases the incoming handle, so a
        retired world never silently pins a second copy of the same
        executables."""
        if handle is None or handle.released:
            return
        evicted: list[WorldHandle] = []
        with self._lock:
            existing = self._entries.get(key)
            if existing is not None:
                self._entries.move_to_end(key)
                self.stats.duplicate_puts += 1
                if existing is not handle:
                    evicted.append(handle)
            else:
                self._entries[key] = handle
                self.stats.puts += 1
                while len(self._entries) > self.capacity:
                    _, old = self._entries.popitem(last=False)
                    self.stats.evictions += 1
                    evicted.append(old)
        # release outside the lock: .delete()/dereference may be slow
        for h in evicted:
            h.release()

    # -- explicit invalidation --------------------------------------------
    def evict(self, key: PoolKey) -> bool:
        """Release and drop one entry (device-memory release is immediate,
        not deferred to GC). Returns True when something was evicted."""
        with self._lock:
            handle = self._entries.pop(key, None)
            if handle is not None:
                self.stats.evictions += 1
        if handle is None:
            return False
        handle.release()
        return True

    def invalidate(self, predicate: Callable[[PoolKey, WorldHandle], bool]) -> int:
        """Evict every entry matching ``predicate`` — the hook for device
        health: a real deployment drops pooled worlds whose fingerprint
        includes a failed device (this repo's host-device fingerprints
        never fail, so only tests and external integrations call this)."""
        with self._lock:
            doomed = [
                (k, h) for k, h in self._entries.items() if predicate(k, h)
            ]
            for k, _ in doomed:
                self._entries.pop(k)
                self.stats.evictions += 1
        for _, h in doomed:
            h.release()
        return len(doomed)

    def clear(self) -> int:
        return self.invalidate(lambda k, h: True)
