"""Downtime / goodput accounting (drives Figs. 6–8 benchmarks).

Goodput here = fraction of wall-clock × allocated-GPU area spent making
training progress (the paper's 'training efficiency'), counting
reconfiguration *downtime* against it — the quantity the analytic
``sim.liver_sim.volatility_run`` predicts and Figs. 7–8 plot.

Streamed pre-copy dispatch ("reshard_overlap" intervals) is steady-state
*interference*, not downtime: the paper measures it separately (Fig. 6d,
``benchmarks/bench_interference.py``) and its analytic goodput model
excludes it. On this container's host devices the transfer compute is
serial with training, so folding it into the goodput denominator would
double-count fig-6d overhead at a magnitude real interconnects never see
(documented deviation, DESIGN.md §11). It stays a first-class interval
kind — ``gpu_seconds("reshard_overlap")`` and the bench payloads report
it — it just isn't a pause.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Interval:
    start: float
    end: float
    kind: str  # "train" | "pause" | "idle" | "reshard_overlap"
    gpus: int


@dataclass
class GoodputLedger:
    intervals: list[Interval] = field(default_factory=list)

    def record(self, start: float, end: float, kind: str, gpus: int) -> None:
        assert end >= start
        self.intervals.append(Interval(start, end, kind, gpus))

    def gpu_seconds(self, kind: str | None = None) -> float:
        return sum(
            (iv.end - iv.start) * iv.gpus
            for iv in self.intervals
            if kind is None or iv.kind == kind
        )

    @property
    def goodput(self) -> float:
        """train / (train + downtime): pauses and idle count against
        goodput; streamed-transfer interference does not (module doc)."""
        down = self.gpu_seconds("pause") + self.gpu_seconds("idle")
        train = self.gpu_seconds("train")
        total = train + down
        return train / total if total else 0.0

    @property
    def pause_seconds(self) -> float:
        return sum(iv.end - iv.start for iv in self.intervals if iv.kind == "pause")

    def wasted_gpu_hours(self) -> float:
        return (self.gpu_seconds("pause") + self.gpu_seconds("idle")) / 3600.0
