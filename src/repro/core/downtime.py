"""Downtime / goodput accounting (drives Figs. 6–8 benchmarks).

Goodput here = fraction of wall-clock × allocated-GPU area spent making
training progress (the paper's 'training efficiency').
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Interval:
    start: float
    end: float
    kind: str  # "train" | "pause" | "idle"
    gpus: int


@dataclass
class GoodputLedger:
    intervals: list[Interval] = field(default_factory=list)

    def record(self, start: float, end: float, kind: str, gpus: int) -> None:
        assert end >= start
        self.intervals.append(Interval(start, end, kind, gpus))

    def gpu_seconds(self, kind: str | None = None) -> float:
        return sum(
            (iv.end - iv.start) * iv.gpus
            for iv in self.intervals
            if kind is None or iv.kind == kind
        )

    @property
    def goodput(self) -> float:
        total = self.gpu_seconds()
        return self.gpu_seconds("train") / total if total else 0.0

    @property
    def pause_seconds(self) -> float:
        return sum(iv.end - iv.start for iv in self.intervals if iv.kind == "pause")

    def wasted_gpu_hours(self) -> float:
        return (self.gpu_seconds("pause") + self.gpu_seconds("idle")) / 3600.0
