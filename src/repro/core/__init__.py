"""LiveR core: the paper's contribution.

  resource_view  — Abstract Resource View (logical tensors + view functions)
  intersection   — geometric intersection transfer planner (App. A.2)
  streaming      — simulated-rank front-end over the shared ReshardEngine
                   (repro.reshard — Algorithm 1 protocol + both backends)
  reshard        — live-path resharder over jax.Arrays (same engine)
  generations    — Stable/Prepare/Ready/Switch/Cleanup state machine
  mock_groups    — abstract-mesh warmup (mock process groups)
  shadow         — background Shadow World construction
  world_pool     — speculative warm world pool (cached WorldHandles)
  controller     — end-to-end LiveR controller + fail-stop fallback
  events         — elasticity event types
  downtime       — goodput/downtime accounting
"""

from repro.core.resource_view import TensorSpec, View, build_tensor_specs, view_of
from repro.core.intersection import TransferPlan, TransferTask, plan_transfer, verify_completeness
from repro.core.generations import GenerationMachine, GenState

_STREAMING_NAMES = ("execute_plan", "materialize_rank", "allocate_destination")


def __getattr__(name):  # lazy: streaming pulls in repro.reshard (the engine)
    if name in _STREAMING_NAMES:
        from repro.core import streaming

        return getattr(streaming, name)
    if name == "WorldPool":  # lazy: world_pool pulls in shadow (jax)
        from repro.core.world_pool import WorldPool

        return WorldPool
    raise AttributeError(name)

__all__ = [
    "TensorSpec", "View", "build_tensor_specs", "view_of",
    "TransferPlan", "TransferTask", "plan_transfer", "verify_completeness",
    "execute_plan", "materialize_rank", "allocate_destination",
    "GenerationMachine", "GenState", "WorldPool",
]
