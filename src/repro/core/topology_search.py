"""Target-topology search (the paper's §2.3(D) integration point).

LiveR solves the *execution* problem — transitioning between parallelism
configurations without stopping — and explicitly defers the *search* problem
("which configuration to choose") to an external system: "A natural
integration would have the search system determine the target (TP', PP',
DP') and LiveR execute the live transition."

This module is that search system: given a device count and a model config,
it enumerates feasible ``ParallelConfig``s (divisibility + per-chip memory)
and ranks them with a roofline-flavored step-time model (compute + the
structural TP/DP collective terms), optionally weighing the *transition
cost* from the current config (bytes moved under the intersection plan) so
frequent small resizes prefer nearby layouts — a liveness-aware refinement
the paper's discussion motivates.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16


@dataclass(frozen=True)
class Candidate:
    parallel: ParallelConfig
    step_time_s: float
    mem_per_chip: float
    transition_bytes: int = 0
    score: float = 0.0


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def feasible_configs(
    cfg: ModelConfig,
    world: int,
    global_batch: int,
    max_pp: int = 8,
) -> list[ParallelConfig]:
    """All (dp, pp, tp) with dp·pp·tp == world respecting divisibility:
    dp | global_batch, pp | n_periods, tp bounded by head/ffn divisibility."""
    from repro.models.transformer import n_periods

    np_ = n_periods(cfg)
    out = []
    for tp in _divisors(world):
        if cfg.d_ff and cfg.d_ff % tp != 0 and (cfg.num_heads * cfg.resolved_head_dim) % tp != 0:
            continue
        rest = world // tp
        for pp in _divisors(rest):
            if pp > max_pp or np_ % pp != 0:
                continue
            dp = rest // pp
            if global_batch % dp != 0:
                continue
            out.append(ParallelConfig(dp=dp, pp=pp, tp=tp))
    return out


def estimate_step_time(
    cfg: ModelConfig,
    parallel: ParallelConfig,
    global_batch: int,
    seq_len: int,
) -> tuple[float, float]:
    """(step seconds, param+opt bytes per chip) — napkin roofline model.

    compute: 6·N_active·D/(world·peak) with a pipeline-bubble factor;
    collective: Megatron-TP's ~4 activation collectives per layer over ICI +
    the DP gradient reduce.
    """
    from repro.models.model import analytic_param_count

    n_active = analytic_param_count(cfg, active_only=True)
    n_total = analytic_param_count(cfg)
    world = parallel.world_size
    tokens = global_batch * seq_len

    compute = 6.0 * n_active * tokens / (world * PEAK_FLOPS_BF16)
    # pipeline bubble (GPipe-ish): (pp-1)/(m + pp - 1), m = microbatches
    m = max(global_batch // parallel.dp, 1)
    bubble = (parallel.pp - 1) / (m + parallel.pp - 1)
    compute /= max(1e-9, 1.0 - bubble)

    # TP activation collectives: ~4 per layer, bytes = tokens/dp·d·2B, only
    # when tp > 1; DP gradient reduce-scatter+all-gather: 2·params·2B/world
    coll = 0.0
    if parallel.tp > 1:
        coll += 4 * cfg.num_layers * (tokens / max(parallel.dp, 1)) * cfg.d_model * 2 / ICI_BW / max(parallel.dp * parallel.pp, 1)
    if parallel.dp > 1:
        coll += 2 * n_total * 2 / (world * ICI_BW)

    # memory per chip: bf16 params + fp32 moments sharded over (tp·pp[·dp zeRO])
    state = n_total * (2 + 8) / (parallel.tp * parallel.pp * parallel.dp)
    act = (tokens / max(parallel.dp, 1) / m) * cfg.d_model * 2 * 4  # rough
    mem = state + act
    return compute + coll, mem


def search(
    cfg: ModelConfig,
    world: int,
    global_batch: int,
    seq_len: int,
    current: ParallelConfig | None = None,
    transition_weight: float = 0.0,
    hbm_bytes: float = HBM_BYTES,
    max_pp: int = 8,
) -> list[Candidate]:
    """Ranked feasible candidates (best first).

    transition_weight converts transition bytes (from the intersection
    planner, when ``current`` is given) into equivalent step-seconds so the
    search trades steady-state speed against reconfiguration cost.
    """
    from repro.core.intersection import plan_transfer
    from repro.core.resource_view import build_tensor_specs

    cands = []
    specs = build_tensor_specs(cfg) if (current and transition_weight) else None
    for par in feasible_configs(cfg, world, global_batch, max_pp=max_pp):
        t, mem = estimate_step_time(cfg, par, global_batch, seq_len)
        if mem > hbm_bytes:
            continue
        tb = 0
        if specs is not None and par != current:
            tb = plan_transfer(
                specs, current, par, layer_granular=False
            ).network_bytes
        score = t + transition_weight * tb
        cands.append(Candidate(par, t, mem, tb, score))
    return sorted(cands, key=lambda c: c.score)


def likely_next_targets(
    cfg: ModelConfig,
    current: ParallelConfig,
    max_world: int,
    global_batch: int,
    seq_len: int,
    k: int = 2,
    factors: tuple[float, ...] = (0.5, 2.0),
    max_pp: int = 8,
    transition_weight: float = 0.0,
) -> list[ParallelConfig]:
    """The warm pool's prefetch candidates (DESIGN.md §12).

    Elasticity events overwhelmingly halve or double capacity (spot
    reclaim takes a node group; walk-up returns it), so the likely next
    device counts are the walk-down/walk-up neighbors of the current
    world. For each neighbor count this returns the search's ranked
    feasible configurations, merged round-robin across counts (best of
    each neighbor first), deduplicated, excluding the current config,
    capped at ``k`` — the top-k targets a speculative
    ``prefetch_world`` should build while the controller is idle.
    """
    ranked: list[list[ParallelConfig]] = []
    seen_counts = {current.world_size}
    for f in factors:
        world = max(1, min(max_world, int(round(current.world_size * f))))
        if world in seen_counts:
            continue
        seen_counts.add(world)
        cands = search(
            cfg, world, global_batch, seq_len, current=current,
            transition_weight=transition_weight, max_pp=max_pp,
        )
        ranked.append([c.parallel for c in cands if c.parallel != current])
    out: list[ParallelConfig] = []
    depth = 0
    while len(out) < k and any(depth < len(r) for r in ranked):
        for r in ranked:
            if depth < len(r) and r[depth] not in out:
                out.append(r[depth])
                if len(out) >= k:
                    break
        depth += 1
    return out[:k]


def failover_target(
    cfg: ModelConfig,
    current: ParallelConfig,
    global_batch: int,
    max_pp: int = 8,
) -> Optional[ParallelConfig]:
    """The prefix-survivor standby: the world an unannounced fail-stop
    would recover into (DESIGN.md §15).

    Under prefix device allocation a failure takes the tail ranks, and
    the cheapest covered recovery target drops whole replica groups:
    one DP replica when ``dp > 1`` (survivors hold every shard locally),
    else half the tp (parity repairs the lost tp group), else half the
    pp. Keeping this one world warm in the pool bounds the fail-stop
    pause to the transfer itself — never a cold Prepare.
    """
    dp, pp, tp = current.dp, current.pp, current.tp
    candidates: list[ParallelConfig] = []
    if dp > 1:
        # largest feasible dp' < dp, same (pp, tp): one-replica-down
        # first, halving as the divisibility fallback
        for d in range(dp - 1, 0, -1):
            if global_batch % d == 0:
                candidates.append(ParallelConfig(dp=d, pp=pp, tp=tp))
                break
    elif tp > 1:
        candidates.append(ParallelConfig(dp=1, pp=pp, tp=tp // 2))
    elif pp > 1:
        candidates.append(ParallelConfig(dp=1, pp=pp // 2, tp=1))
    for cand in candidates:
        if cand in feasible_configs(
            cfg, cand.world_size, global_batch, max_pp=max_pp
        ):
            return cand
    return None


def best_target(
    cfg: ModelConfig,
    world: int,
    global_batch: int,
    seq_len: int,
    current: ParallelConfig | None = None,
    transition_weight: float = 0.0,
    max_pp: int = 8,
) -> ParallelConfig:
    cands = search(
        cfg, world, global_batch, seq_len, current, transition_weight,
        max_pp=max_pp,
    )
    if not cands:
        raise ValueError(
            f"no feasible topology for {cfg.name} at world={world} "
            f"(batch {global_batch})"
        )
    return cands[0].parallel
