"""Intersection-based transfer planning (paper §4.6.1, App. A.2.2).

For every (tensor, destination-rank) pair the planner cuts the destination
view by the source configuration's split points, producing grid *cells*;
each cell lies inside exactly one source view per replica group, so choosing
one replica yields a TransferTask with exact byte ranges. By construction the
cells tile every destination view exactly once — completeness (Eq. 1) and
exactly-once coverage hold structurally (and are property-tested).

Planning touches only sharding metadata — never tensor data — and runs on
CPU (the paper reports <1 s for 175B/96L/1024 ranks; see
benchmarks/bench_plan.py for ours).

Source-selection policies (the paper picks an arbitrary replica; the latter
two are this repo's beyond-paper extensions, see DESIGN.md §8):
  "first"    — lowest-rank replica (paper-faithful baseline)
  "balanced" — deterministic hash spreading source fan-out across replicas
  "nearest"  — prefer src == dst rank (zero-copy), then same-coordinate
               replicas (same node/pod under block device layouts), then
               balanced
"""

from __future__ import annotations

import bisect
import re
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

from repro.configs.base import ParallelConfig
from repro.core.resource_view import (
    TensorSpec,
    View,
    _role_factor_idx,
    split_bounds,
    split_points,
    view_of,
)

_POS_RE = re.compile(r"/pos(\d+)/")


@dataclass(frozen=True)
class TransferTask:
    tensor: str
    collection: str
    src_rank: int
    dst_rank: int
    bounds: tuple[tuple[int, int], ...]  # global coords of the moved region
    src_offset: tuple[int, ...]  # region origin within the source shard
    dst_offset: tuple[int, ...]  # region origin within the destination shard
    nbytes: int
    layer: int  # streaming group (global layer id; -1 = non-layer state)
    # cell class (DESIGN.md §13, §15):
    #   "resident" — src shard == dst shard on the same device: a no-op
    #   "local"    — same device, different layout: on-device relayout
    #   "remote"   — genuine cross-device transfer
    #   "lost"     — no allowed source rank holds this cell (survivor-
    #                constrained planning, DESIGN.md §15); src_rank == -1
    #                and the cell must be repaired (parity) or the plan
    #                abandoned before execution.
    # The default keeps hand-built synthetic tasks (plan-less live_reshard,
    # test fixtures) on the conservative full-transfer path.
    kind: str = "remote"

    @property
    def local(self) -> bool:
        return self.src_rank == self.dst_rank

    @property
    def resident(self) -> bool:
        return self.kind == "resident"

    def shape(self) -> tuple[int, ...]:
        return tuple(h - l for l, h in self.bounds)


@dataclass
class TransferPlan:
    tasks: list[TransferTask]
    cfg_src: ParallelConfig
    cfg_dst: ParallelConfig

    @property
    def network_bytes(self) -> int:
        return sum(t.nbytes for t in self.tasks if t.kind == "remote")

    @property
    def local_bytes(self) -> int:
        """On-device relayout bytes — excludes resident (in-place) cells."""
        return sum(t.nbytes for t in self.tasks if t.kind == "local")

    @property
    def resident_bytes(self) -> int:
        """Bytes already in place on the right device: never moved."""
        return sum(t.nbytes for t in self.tasks if t.kind == "resident")

    @property
    def lost_bytes(self) -> int:
        """Bytes with no surviving source under ``allowed_src`` planning."""
        return sum(t.nbytes for t in self.tasks if t.kind == "lost")

    def lost_tasks(self) -> list[TransferTask]:
        return [t for t in self.tasks if t.kind == "lost"]

    def kind_bytes(self) -> dict[str, int]:
        out = {"resident": 0, "local": 0, "remote": 0}
        for t in self.tasks:
            out[t.kind] = out.get(t.kind, 0) + t.nbytes
        return out

    def layers(self) -> list[int]:
        return sorted({t.layer for t in self.tasks})

    def resident_layers(self) -> list[int]:
        """Layers whose every cell is resident: nothing to stream at all."""
        moved = {t.layer for t in self.tasks if t.kind != "resident"}
        return sorted({t.layer for t in self.tasks} - moved)

    def by_layer(self, layer: int) -> list[TransferTask]:
        return [t for t in self.tasks if t.layer == layer]

    def per_rank_bytes(self) -> tuple[dict[int, int], dict[int, int]]:
        """(bytes sent per src rank, bytes received per dst rank) — network only."""
        tx: dict[int, int] = {}
        rx: dict[int, int] = {}
        for t in self.tasks:
            if t.local:
                continue
            tx[t.src_rank] = tx.get(t.src_rank, 0) + t.nbytes
            rx[t.dst_rank] = rx.get(t.dst_rank, 0) + t.nbytes
        return tx, rx


# ---------------------------------------------------------------------------


def _src_cuts_for_dim(
    spec: TensorSpec, dim: int, cfg_src: ParallelConfig
) -> list[int]:
    role = spec.roles[dim]
    parts = {"pp": cfg_src.pp, "tp": cfg_src.tp, "ep": cfg_src.ep, "dp": cfg_src.dp,
             "none": 1}[role]
    return split_points(spec.shape[dim], parts)


def _segments(lo: int, hi: int, cuts: list[int]) -> list[tuple[int, int]]:
    """Split [lo, hi) at the given sorted cut points (non-empty segments)."""
    pts = [lo] + [c for c in cuts if lo < c < hi] + [hi]
    return [
        (pts[i], pts[i + 1]) for i in range(len(pts) - 1) if pts[i + 1] > pts[i]
    ]


def _src_index_for(
    spec: TensorSpec, dim: int, cfg_src: ParallelConfig, lo: int
) -> int:
    cuts = _src_cuts_for_dim(spec, dim, cfg_src)
    return bisect.bisect_right(cuts, lo) - 1


def _itemsize(dtype: str) -> int:
    import numpy as np

    return np.dtype(dtype).itemsize


def _layer_id(
    spec: TensorSpec, cell_lo: int, num_positions: int
) -> int:
    """Global layer id of a unit stacked-axis slice starting at cell_lo."""
    m = _POS_RE.search(spec.name)
    j = int(m.group(1)) if m else 0
    return cell_lo * num_positions + j


def replica_candidates(
    spec: TensorSpec,
    cfg_src: ParallelConfig,
    bounds: tuple[tuple[int, int], ...],
) -> list[int]:
    """All source ranks whose view contains ``bounds`` (replica group).

    The roled dims of the cell fix one coordinate per parallel factor; the
    remaining (free) factors enumerate the replicas. This is the geometry
    ``_emit_cell`` uses to choose a source, exposed for the redundancy map
    (DESIGN.md §15): restricting this list to survivors tells recovery who
    can donate the cell.
    """
    fixed: dict[str, int] = {}
    for d, role in enumerate(spec.roles):
        if role == "none":
            continue
        fixed[role] = _src_index_for(spec, d, cfg_src, bounds[d][0])
    if spec.stage_scope == "first":
        fixed["pp"] = 0
    elif spec.stage_scope == "last":
        fixed["pp"] = cfg_src.pp - 1
    dp_r = [fixed["dp"]] if "dp" in fixed else range(cfg_src.dp)
    pp_r = [fixed["pp"]] if "pp" in fixed else range(cfg_src.pp)
    ep_r = [fixed["ep"]] if "ep" in fixed else range(cfg_src.ep)
    tp_r = [fixed["tp"]] if "tp" in fixed else range(cfg_src.tp)
    return [
        cfg_src.coords_rank(di, pi, ei, ti)
        for di in dp_r
        for pi in pp_r
        for ei in ep_r
        for ti in tp_r
    ]


def _pick_source(
    policy: str,
    candidates: list[int],
    dst_rank: int,
    cell_key: int,
    dst_coords: tuple[int, int, int, int],
    cfg_src: ParallelConfig,
) -> int:
    if len(candidates) == 1:
        return candidates[0]
    if policy == "first":
        return candidates[0]
    if policy == "nearest":
        if dst_rank in candidates:
            return dst_rank
        # same dp coordinate (same "node group" under blocked layouts)
        dp_i = dst_coords[0]
        same_dp = [r for r in candidates if cfg_src.rank_coords(r)[0] == dp_i]
        if same_dp:
            return same_dp[(cell_key + dst_rank) % len(same_dp)]
    # balanced
    return candidates[(cell_key * 1000003 + dst_rank) % len(candidates)]


def plan_transfer(
    specs: Iterable[TensorSpec],
    cfg_src: ParallelConfig,
    cfg_dst: ParallelConfig,
    source_policy: str = "nearest",
    layer_granular: bool = True,
    num_positions: int = 1,
    allowed_src: Optional[frozenset[int]] = None,
) -> TransferPlan:
    """Compute the full transfer plan between two configurations.

    layer_granular: additionally cut the stacked-layers dim into unit slices
    so execution can stream one *model layer* at a time (Algorithm 1);
    ``num_positions`` is the block-program period (for global layer ids).

    allowed_src: survivor-constrained planning (DESIGN.md §15) — only these
    source ranks may donate a cell. Cells whose whole replica group fell
    outside the set come back as ``kind == "lost"`` with ``src_rank == -1``;
    the caller must repair them (parity) or abandon the plan.
    """
    tasks: list[TransferTask] = []
    for spec in specs:
        itemsize = _itemsize(spec.dtype)
        ldim = spec.layer_dim()
        for dst_rank in range(cfg_dst.world_size):
            v_dst = view_of(spec, cfg_dst, dst_rank)
            if v_dst is None or v_dst.size == 0:
                # empty balanced-split remainder (dim smaller than factor)
                continue
            dst_coords = cfg_dst.rank_coords(dst_rank)
            # per-dim segments of the dst view cut by src split points
            per_dim: list[list[tuple[int, int]]] = []
            for d, (lo, hi) in enumerate(v_dst.bounds):
                cuts = _src_cuts_for_dim(spec, d, cfg_src)
                if layer_granular and d == ldim:
                    cuts = list(range(spec.shape[d] + 1))  # unit slices
                per_dim.append(_segments(lo, hi, cuts))
            # cartesian product of segments -> cells
            def rec(d: int, bounds: list[tuple[int, int]]):
                if d == len(per_dim):
                    _emit_cell(
                        tasks,
                        spec,
                        tuple(bounds),
                        cfg_src,
                        cfg_dst,
                        dst_rank,
                        dst_coords,
                        v_dst,
                        itemsize,
                        source_policy,
                        num_positions,
                        ldim,
                        allowed_src,
                    )
                    return
                for seg in per_dim[d]:
                    bounds.append(seg)
                    rec(d + 1, bounds)
                    bounds.pop()

            rec(0, [])
    return TransferPlan(tasks=tasks, cfg_src=cfg_src, cfg_dst=cfg_dst)


def _emit_cell(
    tasks: list[TransferTask],
    spec: TensorSpec,
    bounds: tuple[tuple[int, int], ...],
    cfg_src: ParallelConfig,
    cfg_dst: ParallelConfig,
    dst_rank: int,
    dst_coords: tuple[int, int, int, int],
    v_dst: View,
    itemsize: int,
    policy: str,
    num_positions: int,
    ldim: Optional[int],
    allowed_src: Optional[frozenset[int]] = None,
) -> None:
    candidates = replica_candidates(spec, cfg_src, bounds)
    nbytes = itemsize
    for lo, hi in bounds:
        nbytes *= hi - lo
    layer = -1
    if ldim is not None:
        layer = _layer_id(spec, bounds[ldim][0], num_positions)
    if allowed_src is not None:
        candidates = [r for r in candidates if r in allowed_src]
        if not candidates:
            # whole replica group died: record the hole, let recovery decide
            tasks.append(
                TransferTask(
                    tensor=spec.name,
                    collection=spec.collection,
                    src_rank=-1,
                    dst_rank=dst_rank,
                    bounds=bounds,
                    src_offset=tuple(0 for _ in bounds),
                    dst_offset=tuple(
                        b[0] - v[0] for b, v in zip(bounds, v_dst.bounds)
                    ),
                    nbytes=nbytes,
                    layer=layer,
                    kind="lost",
                )
            )
            return
    cell_key = hash(bounds) & 0x7FFFFFFF
    src_rank = _pick_source(policy, candidates, dst_rank, cell_key, dst_coords, cfg_src)
    v_src = view_of(spec, cfg_src, src_rank)
    assert v_src is not None
    # Classification (DESIGN.md §13). Under the prefix device allocation rank
    # r maps to devices[r] in both configs, so src_rank == dst_rank means the
    # same physical device. "resident" additionally requires the whole shard
    # view to be identical — then the cell's bytes sit at the same place in
    # the same buffer layout and nothing needs to happen.
    if src_rank != dst_rank:
        kind = "remote"
    elif v_src.bounds == v_dst.bounds:
        kind = "resident"
    else:
        kind = "local"
    tasks.append(
        TransferTask(
            tensor=spec.name,
            collection=spec.collection,
            src_rank=src_rank,
            dst_rank=dst_rank,
            bounds=bounds,
            src_offset=tuple(b[0] - v[0] for b, v in zip(bounds, v_src.bounds)),
            dst_offset=tuple(b[0] - v[0] for b, v in zip(bounds, v_dst.bounds)),
            nbytes=nbytes,
            layer=layer,
            kind=kind,
        )
    )


# ---------------------------------------------------------------------------
# Verification helpers (used by tests and by the executor's paranoia mode)
# ---------------------------------------------------------------------------


def verify_completeness(
    specs: Iterable[TensorSpec],
    plan: TransferPlan,
    cfg_dst: ParallelConfig,
) -> None:
    """Every destination view must be tiled exactly once (Eq. 1)."""
    by_key: dict[tuple[str, int], list[TransferTask]] = {}
    for t in plan.tasks:
        by_key.setdefault((t.tensor, t.dst_rank), []).append(t)
    for spec in specs:
        for r in range(cfg_dst.world_size):
            v = view_of(spec, cfg_dst, r)
            tasks = by_key.get((spec.name, r), [])
            if v is None:
                assert not tasks, f"{spec.name}: tasks for non-owning rank {r}"
                continue
            covered = sum(t.nbytes for t in tasks) // _itemsize(spec.dtype)
            assert covered == v.size, (
                f"{spec.name} dst {r}: covered {covered} != view {v.size}"
            )
            # pairwise disjoint
            for i, a in enumerate(tasks):
                va = View(a.bounds)
                for b in tasks[i + 1 :]:
                    assert va.intersect(View(b.bounds)) is None, (
                        f"overlap in {spec.name} dst {r}"
                    )
