"""LiveR controller (paper §4.3 end-to-end workflow, §4.7 switch).

Orchestrates the full reconfiguration lifecycle on live JAX state:

  trigger → Prepare (shadow thread: mesh + AOT compile)  [overlapped, I1]
          → Ready   (await iteration boundary)           [deterministic, I3]
          → Switch  (drain → live reshard → pointer swap) [the only pause]
          → Cleanup (free old world asynchronously)
          → Stable

plus the fail-stop fallback to durable checkpoints (invariant I4), the
stop-and-restart / checkpoint-reshape (UCP) baselines used by the
benchmarks, and the event-stream verbs the deadline scheduler drives
(DESIGN.md §10): per-request transfer-mode override, ``retarget_resize``
(supersede the in-flight reconfiguration, adopting its streamed state)
and ``escalate_commit`` (deadline-pressure stop-copy).
"""

from __future__ import annotations

import os
import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.downtime import GoodputLedger
from repro.core.generations import GenerationMachine, GenState
from repro.core.reshard import (
    DEFAULT_STAGING_BYTES,
    live_reshard,
    live_reshard_planned,
    named_state_leaves,
    plan_state_transfer,
    rebuild_state,
)
from repro.core.shadow import (
    ShadowBuilder,
    WorldHandle,
    abstract_batch,
    build_train_world,
    build_update_world_fn,
)
from repro.core.records import ReuseRecordMixin
from repro.core.world_pool import WorldPool
from repro.data import SyntheticLM
from repro.optim import AdamWConfig
from repro.reshard import OverlapSession
from repro.utils.pytree import tree_paths


@dataclass
class ReconfigRecord(ReuseRecordMixin):
    # reused_layers / resident_layers / skipped_bytes come from the shared
    # ReuseRecordMixin (classified plan IR, DESIGN.md §13)
    gen_id: int
    src: str
    dst: str
    prepare_s: float = 0.0
    drain_s: float = 0.0
    transfer_s: float = 0.0
    switch_s: float = 0.0
    total_pause_s: float = 0.0
    moved_bytes: int = 0
    # live | live_overlap | restart | ucp_restart | peer_recover | fallback
    mode: str = "live"
    # per-event disposition (DESIGN.md §10 fallback lattice):
    #   committed  — the reconfiguration completed via its requested path
    #   retargeted — superseded by a newer event before commit (its partial
    #                streamed state may have been adopted by the successor)
    #   fell_back  — completed, but via a downgraded path (stop-copy under
    #                deadline pressure, or checkpoint restore)
    #   aborted    — abandoned without completing
    outcome: str = "committed"
    # Prepare served from the warm world pool (or residual shadow work):
    # lower+compile skipped entirely. The DeadlineEstimator keeps separate
    # warm/cold prepare estimates keyed on this flag.
    warm_hit: bool = False
    # how Prepare was served: "cold" (full build) | "pool" | "residual" |
    # "speculative_join" (joined an in-flight prefetch — measures neither a
    # warm nor a cold Prepare, so both estimators exclude it)
    prepare_source: str = "cold"
    # plan-vs-live agreement (both sides from the one ReshardEngine path)
    plan_network_bytes: int = 0
    plan_local_bytes: int = 0
    executed_bytes: int = 0
    plan_s: float = 0.0  # planning time (0.0 when planned in the shadow thread)
    # overlapped-streaming phases (zero under stop-copy)
    precopy_s: float = 0.0
    precopy_bytes: int = 0
    resync_s: float = 0.0
    resync_bytes: int = 0
    update_s: float = 0.0
    dirty_layers: int = 0
    layers_total: int = 0
    # async data-plane attribution: host time issuing device programs vs
    # blocking for them, and cells that fell off the row-merge fast path
    # (a growing generic_cells count flags a slow-path regression)
    stream_dispatch_s: float = 0.0
    stream_drain_s: float = 0.0
    generic_cells: int = 0
    # resident_cells / skipped_bytes / wire_bytes / logical_bytes come from
    # the mixin; the tuned data-plane parameters this reconfig ran with
    # (None = the hand-set fallback constants, DESIGN.md §14)
    operating_point: Optional[dict] = None
    # peer recovery (DESIGN.md §15): how a fail-stop was sourced
    donors: int = 0  # distinct surviving ranks that donated cells
    lost_devices: int = 0  # ranks lost to the failure
    parity_bytes: int = 0  # bytes reconstructed from the XOR parity word


class LiveRController:
    def __init__(
        self,
        cfg: ModelConfig,
        parallel: ParallelConfig,
        opt_cfg: AdamWConfig,
        seq_len: int,
        global_batch: int,
        data: Optional[SyntheticLM] = None,
        ckpt_dir: Optional[str] = None,
        ckpt_interval: int = 50,
        staging_bytes: int = DEFAULT_STAGING_BYTES,
        devices=None,
        microbatches: int = 1,
        compression: str = "none",
        hint_version: str | None = None,
        seed: int = 0,
        overlap: str = "stop_copy",  # "stop_copy" | "stream"
        stream_k: int = 4,
        source_policy: str = "nearest",
        sync_compile: bool = False,
        world_pool: Optional[WorldPool] = None,
        max_spec_builds: int = 1,
        wire_policy=None,
        wire_bw_bytes_s: float | None = None,
        parity_every: int = 0,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.staging_bytes = staging_bytes
        self.devices = devices if devices is not None else jax.devices()
        self.microbatches = microbatches
        self.compression = compression
        self.hint_version = hint_version
        assert overlap in ("stop_copy", "stream"), overlap
        self.overlap = overlap
        # per-reconfiguration override (request_resize(..., overlap=...));
        # resets to the constructor default when the reconfig retires
        self._overlap_mode = overlap
        self.stream_k = stream_k
        self.source_policy = source_policy
        # compressed wire format (DESIGN.md §14): None = fully lossless.
        # Distinct from ``compression`` (gradient all-reduce int8+EF): the
        # wire policy shapes what the RESHARD stream sends, per collection.
        self.wire_policy = wire_policy
        # emulated interconnect bandwidth for the live executors (benchmarks
        # only; None on real hardware)
        self.wire_bw_bytes_s = wire_bw_bytes_s
        # per-reconfiguration tuned operating point (reshard.autotune),
        # installed by request_resize/retarget_resize; None = fallbacks
        self._operating_point = None
        # deterministic mode for parity tests / --check benchmark gates:
        # compile the split-step grad executable inline instead of in a
        # background thread, so the commit step index is reproducible
        self.sync_compile = sync_compile
        # streamed state captured from a superseded session at retarget,
        # consumed by the next _start_overlap_session
        self._reuse: Optional[tuple] = None
        self._session: Optional[OverlapSession] = None
        self._session_specs = None
        self._session_plan = None
        self._session_targets = None
        self._pending_rec: Optional[ReconfigRecord] = None
        self._commit_armed = False
        self._grad_builder = None
        self.machine = GenerationMachine()
        self.ledger = GoodputLedger()
        self.records: list[ReconfigRecord] = []
        self.iteration_times: list[float] = []
        self.step = 0
        self.data = data or SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed)
        self.ckpt_dir = ckpt_dir
        self.ckpt_interval = ckpt_interval
        self._ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self._builder: Optional[ShadowBuilder] = None
        # speculative warm world pool (DESIGN.md §12): retired/abandoned/
        # prefetched worlds keyed by pool_key; warm hits skip lower+compile
        self.world_pool = world_pool
        self.max_spec_builds = max_spec_builds
        self._spec_builders: dict[tuple, ShadowBuilder] = {}
        # transfer-executable prewarm (DESIGN.md §15): (src, dst) pairs
        # whose reshard compiles already ran off the critical path
        self._prewarmed_pairs: set = set()
        self._prewarm_thread: Optional[threading.Thread] = None
        self._prewarm_pair: Optional[tuple] = None
        self._inflight_target: Optional[ParallelConfig] = None
        # spare-shard scheme (DESIGN.md §15): refresh the XOR parity words
        # every N idle step boundaries so dp=1 worlds can reconstruct a
        # shard whose only owner died; 0 disables
        self.parity_every = parity_every
        self._parity = None

        # Active World (generation 0). With a pool, every world is built
        # split-step so its update_fn is already warm if it later serves a
        # streamed resize out of the pool.
        world = self._build_world(parallel, split_step=world_pool is not None)
        world.gen_id = 0
        self.machine.active.payload = world
        from repro.distribution.step import init_train_state

        self.params, self.opt_state = init_train_state(
            cfg, world.mesh, seed=seed, compression=compression
        )

    # ------------------------------------------------------------------
    @property
    def world(self) -> WorldHandle:
        return self.machine.active.payload

    def _device_subset(self, parallel: ParallelConfig):
        return self.devices[: parallel.world_size]

    def _build_world(self, target: ParallelConfig, split_step: bool) -> WorldHandle:
        return build_train_world(
            self.cfg,
            target,
            self.opt_cfg,
            self.global_batch,
            self.seq_len,
            microbatches=self.microbatches,
            devices=self._device_subset(target),
            compression=self.compression,
            hint_version=self.hint_version,
            split_step=split_step,
        )

    # ------------------------------------------------------------------
    # Warm world pool (DESIGN.md §12)
    # ------------------------------------------------------------------
    def pool_key(self, target: ParallelConfig) -> tuple:
        """Pool identity of the world this controller would build for
        ``target``: everything that shapes the compiled executables, plus
        the device-set fingerprint — a world is warm only for the exact
        devices its executables were loaded onto."""
        fingerprint = tuple(
            getattr(d, "id", i) for i, d in enumerate(self._device_subset(target))
        )
        return (
            self.cfg,
            target,
            fingerprint,
            self.global_batch,
            self.seq_len,
            self.microbatches,
            self.compression,
            self.hint_version,
        )

    def _refresh_pooled(
        self, handle: WorldHandle, mode: str, source: str = "pool"
    ) -> WorldHandle:
        """Revalidate a warm world for use as the pending shadow: backfill
        the split-step executable if this reconfiguration streams and the
        cached build predates split-step mode (pool-bound builds always
        split-step, so this is the rare path), and tag the timings so the
        ReconfigRecord/DeadlineEstimator can tell warm from cold."""
        assert not handle.released, "warm world was released while pooled"
        handle.timings = dict(handle.timings)
        handle.timings["warm_hit"] = source == "pool"
        handle.timings["prepare_source"] = source
        handle.plan_bundle = None  # src-dependent: always replanned below
        if mode == "stream" and handle.update_fn is None:
            t0 = time.perf_counter()
            handle.update_fn = build_update_world_fn(
                self.cfg, handle.mesh, handle.parallel, self.opt_cfg,
                compression=self.compression,
            )
            handle.timings["update_compile_s"] = time.perf_counter() - t0
        return handle

    def _discard_world(self, handle: WorldHandle) -> None:
        """An abandoned builder's completed world: keep it warm when a pool
        exists (bounded — LRU eviction releases it), release its device
        memory immediately otherwise. Runs on the orphaned build thread
        when the abandon preceded completion; the pool is thread-safe."""
        if self.world_pool is not None and not handle.released:
            handle.gen_id = -1
            handle.plan_bundle = None
            self.world_pool.put(self.pool_key(handle.parallel), handle)
        else:
            handle.release()

    def _retire_world(self, old_gen) -> None:
        """Post-switch cleanup of the outgoing generation. With a pool the
        old world stays warm — resizing back to a recently-left
        configuration is the dominant elasticity pattern (walk-down then
        walk-up) — otherwise the reference simply drops."""
        world, old_gen.payload = old_gen.payload, None
        if world is None or self.world_pool is None or world.released:
            return
        world.gen_id = -1
        world.plan_bundle = None
        self.world_pool.put(self.pool_key(world.parallel), world)

    def _harvest_spec_builders(self) -> None:
        """Deposit completed speculative builds into the pool. Build errors
        are swallowed: speculation must never take down training (the same
        target requested for real will rebuild — and re-raise — on the
        normal path)."""
        for key in [k for k, b in self._spec_builders.items() if b.ready]:
            builder = self._spec_builders.pop(key)
            try:
                handle = builder.result(0)
            except BaseException:
                continue
            self.world_pool.put(key, handle)

    def prefetch_world(self, target: ParallelConfig) -> bool:
        """Speculatively build ``target``'s world into the warm pool, off
        the critical path (daemon thread, same interference profile as a
        real Prepare). Never runs concurrently with a real reconfiguration
        — the one-live-shadow invariant I2 is about *generations*, which
        speculative builds never touch, but stacking compiles multiplies
        steady-state interference for no deadline benefit. Returns True
        when a build was started."""
        if self.world_pool is None or self.reconfig_pending:
            return False
        if target == self.world.parallel:
            return False
        key = self.pool_key(target)
        self._harvest_spec_builders()
        if self.world_pool.contains(key) or key in self._spec_builders:
            return False
        if len(self._spec_builders) >= self.max_spec_builds:
            return False
        self._spec_builders[key] = ShadowBuilder(
            lambda: self._build_world(target, split_step=True), gen_id=-1
        ).start()
        return True

    @staticmethod
    def _speculation_trace(msg: str) -> None:
        """Append one line to the file named by REPRO_PREWARM_TRACE (unset:
        no-op). Speculative threads swallow their failures by design — this
        is the only way to see what the speculation layer actually did."""
        path = os.environ.get("REPRO_PREWARM_TRACE")
        if not path:
            return
        try:
            with open(path, "a") as f:
                f.write(f"{time.perf_counter():.3f} {msg}\n")
        except OSError:
            pass

    def _derived_named_shardings(self, parallel: ParallelConfig) -> Optional[dict]:
        """Named state shardings a world under ``parallel`` WILL carry,
        derived from mesh + rules alone — no build, no compile (~ms).
        Lets the stream-ahead prewarm (§15) start at resize-request time
        instead of waiting for the shadow world. None when the layout
        can't be derived cheaply (pipeline worlds shard via the pipeline
        step builder)."""
        if parallel.pp > 1:
            return None
        from repro.distribution.sharding import make_elastic_mesh
        from repro.distribution.step import train_state_shardings

        try:
            mesh = make_elastic_mesh(parallel, devices=self.devices)
            ps, os_ = train_state_shardings(self.cfg, mesh)
        except BaseException:
            return None
        named = {}
        for p, sh in tree_paths(ps).items():
            named[f"params/{p}"] = sh
        for coll in ("mu", "nu"):
            for p, sh in tree_paths(os_[coll]).items():
                named[f"{coll}/{p}"] = sh
        return named

    def prewarm_failover_ahead(self) -> int:
        """During a resize, prewarm the transfer executables for
        (incoming world → pooled world) pairs — the incoming world's
        failover paths (§15). A window-0 event landing right after the
        commit otherwise pays the pair's cold compiles inside its pause:
        the pair is only knowable once the incoming world is, and that is
        knowable the moment the resize is requested — the state shardings
        it will carry are pure metadata (mesh + rules), no build needed.
        Returns prewarm threads started (≤1; one pair per tick)."""
        target = self._inflight_target
        if target is None or self.world_pool is None:
            return 0
        # same policy as the idle-tick loop: non-growing pairs only,
        # nearest size first (same-size retopology is the likeliest
        # window-0 target; grows come with windows and stream)
        needed = sorted(
            (
                key[1]
                for key in self.world_pool.keys()
                if key[1] != target
                and key[1].world_size <= target.world_size
                and (target, key[1]) not in self._prewarmed_pairs
            ),
            key=lambda p: target.world_size - p.world_size,
        )
        if not needed:
            return 0
        if self._prewarm_thread is not None and self._prewarm_thread.is_alive():
            return 0
        src_sh = self._derived_named_shardings(target)
        if src_sh is None:
            self._speculation_trace(f"ahead: no derived shardings for {target}")
            return 0
        started = 0
        for tgt in needed:
            if self.prewarm_transfer(
                tgt, src_parallel=target, src_shardings=src_sh
            ):
                started += 1
        return started

    def prewarm_transfer(
        self,
        target: ParallelConfig,
        src_parallel: Optional[ParallelConfig] = None,
        src_shardings: Optional[dict] = None,
    ) -> bool:
        """Compile the reshard executables for (current world → target)
        off the critical path, against a pooled world's shardings.

        A fail-stop recovery (§15) pays its transfer inside the pause, and
        a first-time (src, dst) pair spends most of that transfer in
        one-time pack/scatter/staging compiles — measured ~5× the warm
        transfer on the smoke workload. jax's jit cache is keyed on
        avals + shardings, so executing one throwaway transfer of the same
        plan against the same shardings warms every executable the real
        recovery will use (the recovery path is lossless, so the prewarm
        runs lossless too). Sources are throwaway zero arrays with the
        live leaves' avals + shardings — the train step donates the real
        buffers, so reading them from a background thread would race with
        training — and the results are discarded.

        With ``src_parallel``/``src_shardings`` the pair is
        (src world → target) instead of (current → target): the
        stream-ahead path (:meth:`prewarm_failover_ahead`) warms the
        incoming world's failover pairs while the resize toward it is
        still preparing/streaming. The live state will carry exactly
        those shardings after the commit; global shapes/dtypes are
        world-invariant. Returns True when a prewarm thread was started."""
        if self.world_pool is None:
            return False
        ahead = src_parallel is not None
        if not ahead and self.reconfig_pending:
            return False
        if src_parallel is None:
            src_parallel = self.world.parallel
        if target == src_parallel:
            return False
        pair = (src_parallel, target)
        if pair in self._prewarmed_pairs:
            return False
        if self._prewarm_thread is not None and self._prewarm_thread.is_alive():
            return False
        handle = self.world_pool.peek(self.pool_key(target))
        if handle is None or handle.released:
            return False
        self._speculation_trace(
            f"prewarm start {src_parallel.describe()}->{target.describe()} "
            f"ahead={ahead}"
        )
        self._prewarmed_pairs.add(pair)
        self._prewarm_pair = pair
        targets = self._named_target_shardings(handle)
        extra_sh = self._extra_shardings(handle)
        # Metadata-only snapshot: (name, shape, dtype, sharding) per leaf.
        # The arrays themselves must not escape to the thread — train steps
        # donate them, and a donated buffer read off-thread is a race.
        named, extras = named_state_leaves(self.params, self.opt_state)
        src_sh = (
            src_shardings
            if ahead
            else {n: a.sharding for n, a in named.items()}
        )
        named_meta = {
            n: (a.shape, a.dtype, src_sh[n]) for n, a in named.items()
        }
        if not ahead:
            extra_leaves, extra_treedef = jax.tree_util.tree_flatten(extras)
            extra_meta = [
                (a.shape, a.dtype, a.sharding) if hasattr(a, "sharding") else a
                for a in extra_leaves
            ]
        else:
            # extras (step count, error-feedback buffers) reshard through
            # the plan-less fallback whose programs are per-leaf trivial;
            # skip them rather than reconstruct their future shardings
            extra_leaves, extra_treedef, extra_meta = [], None, []

        def _zeros(shape, dtype, sharding):
            return jax.jit(
                lambda: jnp.zeros(shape, dtype), out_shardings=sharding
            )()

        def _warm() -> None:
            try:
                from repro.elastic.redundancy import (
                    balance_donors,
                    heal_plan,
                    survivors_for,
                )

                dummy_named = {
                    n: _zeros(*m) for n, m in named_meta.items()
                }
                # mirror fail_stop_recover's plan EXACTLY — the warned-rung
                # geometry (prefix complement of the target lost, sources
                # survivor-constrained, donors balanced). The jit cache is
                # keyed on the programs the plan's cells produce, so a
                # prewarm against any other plan warms nothing the
                # recovery pause will run.
                lost = tuple(
                    range(target.world_size, src_parallel.world_size)
                )
                survivors = survivors_for(
                    src_parallel, lost, target=target, devices_failed=False
                )
                specs, plan = plan_state_transfer(
                    self.cfg, src_parallel, target,
                    source_policy=self.source_policy,
                    allowed_src=survivors,
                )
                if plan.lost_tasks():
                    plan, _ = heal_plan(plan, specs)
                plan = balance_donors(plan, specs, survivors)
                live_reshard_planned(
                    specs, plan, dummy_named, targets,
                    staging_bytes=self.staging_bytes,
                    wire_policy=None,
                    wire_bw_bytes_s=self.wire_bw_bytes_s,
                )
                if extra_treedef is not None:
                    dummy_extras = jax.tree_util.tree_unflatten(
                        extra_treedef,
                        [
                            _zeros(*m) if isinstance(m, tuple) else m
                            for m in extra_meta
                        ],
                    )
                    live_reshard(
                        dummy_extras, extra_sh, staging_bytes=self.staging_bytes
                    )
                self._speculation_trace(
                    f"prewarm done {src_parallel.describe()}"
                    f"->{target.describe()} ahead={ahead}"
                )
            except BaseException:
                # speculation must never take down training; the real
                # transfer will compile (and surface errors) on its own
                self._speculation_trace(
                    f"prewarm FAILED {src_parallel.describe()}"
                    f"->{target.describe()} ahead={ahead}\n"
                    + traceback.format_exc()
                )

        # Non-daemon: a daemon thread killed inside an XLA compile at
        # interpreter exit aborts the process ("terminate called without
        # an active exception"); Python joins non-daemon threads cleanly.
        self._prewarm_thread = threading.Thread(
            target=_warm, name="transfer-prewarm", daemon=False
        )
        self._prewarm_thread.start()
        return True

    # ------------------------------------------------------------------
    # Prepare (background)
    # ------------------------------------------------------------------
    def request_resize(
        self,
        target: ParallelConfig,
        overlap: Optional[str] = None,
        operating_point=None,
    ) -> int:
        """Trigger: spawn Shadow World preparation. Non-blocking.

        ``overlap`` overrides the constructor's transfer mode for THIS
        reconfiguration only — the deadline scheduler uses it to downgrade
        a single event to stop-copy without flipping the whole controller.
        ``operating_point`` (reshard.autotune.OperatingPoint) likewise
        overrides ``stream_k``/``staging_bytes`` for this reconfiguration;
        None keeps the documented fallback constants.

        Consults the warm world pool first: a hit (or an in-flight
        speculative build for the same key, which the Prepare thread joins)
        skips lower+compile entirely and goes straight to transfer
        planning.
        """
        if overlap is not None:
            assert overlap in ("stop_copy", "stream"), overlap
            self._overlap_mode = overlap
        if operating_point is not None:
            self._operating_point = operating_point
        mode = self._overlap_mode
        gen = self.machine.begin_prepare(description=target.describe())

        src_parallel = self.world.parallel
        warm = None
        join = None
        if self.world_pool is not None:
            # take BEFORE any harvest: a harvest here could LRU-evict the
            # very entry the deadline estimator just priced as warm. A
            # ready-but-unharvested speculative builder is still caught by
            # the join path below (its result() returns immediately).
            warm = self.world_pool.take(self.pool_key(target))
            if warm is None:
                # a speculative build for this exact key is in flight:
                # join it instead of duplicating the compile
                join = self._spec_builders.pop(self.pool_key(target), None)

        def build():
            handle = None
            try:
                if warm is not None:
                    handle = self._refresh_pooled(warm, mode)
                elif join is not None:
                    handle = self._refresh_pooled(
                        join.result(), mode, source="speculative_join"
                    )
            except BaseException:
                # speculation must never fail the real resize: a broken
                # warm/joined world falls back to a fresh cold build (the
                # taken handle is released, not left pinned until GC)
                if warm is not None:
                    warm.release()
                handle = None
            if handle is None:
                handle = self._build_world(
                    target,
                    split_step=mode == "stream" or self.world_pool is not None,
                )
            # transfer planning is metadata-only — do it here, in the
            # Prepare thread, so the commit pause never pays it (paper:
            # planning runs during Prepare)
            try:
                t0 = time.perf_counter()
                specs, plan = plan_state_transfer(
                    self.cfg, src_parallel, target,
                    source_policy=self.source_policy,
                )
                handle.timings["plan_s"] = time.perf_counter() - t0
                handle.plan_bundle = (src_parallel, specs, plan)
            except BaseException:
                # the resize fails either way; re-pool (or release) the
                # completed world rather than leaking it to GC
                self._discard_world(handle)
                raise
            return handle

        self._builder = ShadowBuilder(
            build, gen.gen_id, on_discard=self._discard_world
        ).start()
        # knowable-now metadata for the stream-ahead prewarm (§15)
        self._inflight_target = target
        return gen.gen_id

    def cancel_resize(self, outcome: Optional[str] = None) -> None:
        """Target became stale before commit (paper §7): abandon shadow.

        ``outcome`` (``retargeted`` | ``aborted``) retires the pending
        reconfiguration with a ReconfigRecord so event-stream accounting
        (DESIGN.md §10) sees every disposition; None keeps the classic
        silent cancel."""
        if outcome is not None and self._builder is not None:
            rec = self._pending_rec or ReconfigRecord(
                gen_id=self._builder.gen_id,
                src=self.world.parallel.describe(),
                dst=self.machine.shadow.description if self.machine.shadow else "?",
                mode="live_overlap" if self._overlap_mode == "stream" else "live",
            )
            rec.outcome = outcome
            self.records.append(rec)
        if self._builder is not None:
            self._builder.abandon()
        self.machine.cancel()
        self._reset_reconfig_state()

    @property
    def reconfig_pending(self) -> bool:
        """A resize is in flight (Prepare/Ready/streaming, not committed)."""
        return self._builder is not None

    def wait_shadow_ready(self, timeout: Optional[float] = None) -> None:
        """Block until the in-flight shadow world finishes building.

        Deterministic-replay hook (parity tests, ``--check`` benchmark
        gates): removes XLA-compile wall-clock from the commit-step
        alignment. Never used on the autonomous path — there the training
        loop simply keeps stepping until ``_poll_boundary`` sees readiness.
        """
        if self._builder is not None:
            self._builder.result(timeout)

    def retarget_resize(
        self,
        target: ParallelConfig,
        overlap: Optional[str] = None,
        operating_point=None,
    ) -> int:
        """A newer elasticity event supersedes the in-flight reconfiguration
        (paper §7 'Concurrent reconfiguration events').

        The pending shadow is abandoned (its build thread cannot be killed,
        only orphaned) and a fresh Prepare starts for ``target``. Any state
        the superseded session already streamed is captured first — after a
        full drain, so no in-flight scatter writes into a re-homed carry —
        and the successor session adopts it (:meth:`OverlapSession.adopt`):
        the stream continues where it left off instead of restarting from
        scratch. The superseded event retires with a ``retargeted``
        ReconfigRecord carrying whatever pre-copy work it had done.
        """
        if self._builder is None:
            return self.request_resize(
                target, overlap=overlap, operating_point=operating_point
            )

        reuse = None
        rec = self._pending_rec
        if self._session is not None:
            # drain before capture: adopted carries must hold fully-landed
            # rows, and the old session's staging must not alias sources
            self._session.drain()
            reuse = (
                self._session_targets,
                dict(self._session.executor.dst),
                dict(self._session.streamed_at),
            )
            rep = self._session.report
            if rec is not None:
                rec.precopy_s = rep.precopy_seconds
                rec.precopy_bytes = rep.precopy_bytes
        if rec is None:
            dst = self.machine.shadow.description if self.machine.shadow else "?"
            rec = ReconfigRecord(
                gen_id=self._builder.gen_id,
                src=self.world.parallel.describe(),
                dst=dst,
                mode="live_overlap" if self._overlap_mode == "stream" else "live",
            )
        rec.outcome = "retargeted"
        self.records.append(rec)

        self._builder.abandon()
        # the grads-only executable targets the OLD world, which a retarget
        # does not change — keep the compile (or compiled fn) across resets
        grad_builder = self._grad_builder
        if self.machine.state in (GenState.PREPARE, GenState.READY):
            self.machine.cancel()
        self._reset_reconfig_state()
        self._grad_builder = grad_builder
        gen_id = self.request_resize(
            target, overlap=overlap, operating_point=operating_point
        )
        self._reuse = reuse
        return gen_id

    def escalate_commit(self) -> Optional[ReconfigRecord]:
        """Deadline pressure mid-stream: commit NOW via stop-copy.

        The scheduler calls this when the warning window no longer covers
        the remaining pre-copy rounds. If the shadow world is ready the
        whole (remaining) transfer executes inside one stop-copy pause —
        the middle rung of the fallback lattice. Returns the commit record,
        or None when nothing was ready to commit (caller falls through to
        the checkpoint rung)."""
        if self._builder is None or not self._builder.ready:
            return None
        if self.machine.state == GenState.PREPARE:
            self.machine.mark_ready(self._builder.gen_id, payload=self._builder.result())
        if self.machine.state != GenState.READY:
            return None
        rep = None
        reused = self._pending_rec.reused_layers if self._pending_rec else 0
        if self._session is not None:
            # retire the streaming session: its scatters must land before
            # its carries are dropped; the stop-copy below re-moves
            # everything from the current cut
            self._session.drain()
            rep = self._session.report
        self._commit_switch()
        rec = self.records[-1]
        rec.outcome = "fell_back"
        if rep is not None:
            # keep the abandoned rounds' accounting: the escalation's cost
            # IS the pre-copy work it wasted
            rec.precopy_s = rep.precopy_seconds
            rec.precopy_bytes = rep.precopy_bytes
            # max, not overwrite: the stop-copy commit already counted the
            # plan's resident layers; the session's figure additionally
            # includes layers adopted at retarget
            rec.reused_layers = max(rec.reused_layers, reused)
        return rec

    # ------------------------------------------------------------------
    # Training loop with boundary polling
    # ------------------------------------------------------------------
    def train_steps(self, n: int, collect: Optional[Callable] = None) -> list[float]:
        losses = []
        for _ in range(n):
            t0 = time.perf_counter()
            batch = self._batch()
            if self._commit_armed:
                # overlapped mode: this step runs split (grads on the old
                # world overlapped with the dirty re-sync; optimizer update
                # on the new world) and commits the switch at its end
                metrics = self._split_step_commit(batch)
            else:
                self.params, self.opt_state, metrics = self.world.step_fn(
                    self.params, self.opt_state, batch
                )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.iteration_times.append(dt)
            self.ledger.record(t0, t0 + dt, "train", self.world.parallel.world_size)
            losses.append(float(metrics["loss"]))
            self.step += 1
            if collect:
                collect(self.step, metrics)
            if self._ckpt and self.step % self.ckpt_interval == 0:
                self._ckpt.save(self.step, {"params": self.params, "opt": self.opt_state})
            if self.parity_every and self.step % self.parity_every == 0:
                self._refresh_parity()
            self._poll_boundary()
        return losses

    def _refresh_parity(self) -> None:
        """Idle-boundary XOR parity snapshot (spare-shard scheme, §15)."""
        from repro.core.resource_view import build_tensor_specs
        from repro.elastic.redundancy import ParityStore

        if self._parity is None or self._parity.cfg != self.world.parallel:
            specs = build_tensor_specs(
                self.cfg, include_optimizer=True, zero_sharding=False
            )
            self._parity = ParityStore(specs, self.world.parallel)
        named, _ = named_state_leaves(self.params, self.opt_state)
        self._parity.refresh(named, self.step)

    def _batch(self):
        tokens = jnp.asarray(self.data.global_batch_at(self.step))
        batch = {"tokens": tokens}
        if self.cfg.family == "encdec":
            # dtype must match the AOT lowering's abstract batch (see
            # shadow.abstract_batch) or the compiled step rejects the input
            batch["frames"] = jnp.zeros(
                (self.global_batch, self.seq_len, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        return batch

    def _poll_boundary(self) -> None:
        """Iteration boundary = the consistent cut (invariant I3)."""
        if self._spec_builders:
            self._harvest_spec_builders()
        if self._builder is None or not self._builder.ready:
            return
        if self.machine.state == GenState.PREPARE:
            handle = self._builder.result()
            self.machine.mark_ready(self._builder.gen_id, payload=handle)
        if self.machine.state != GenState.READY:
            return
        if self._overlap_mode == "stop_copy":
            self._commit_switch()
            return
        # overlapped streaming: pre-copy K layers per boundary while the
        # Active World keeps training; once the plan is fully streamed,
        # arm the split-step commit for the NEXT train step
        if self._session is None:
            self._start_overlap_session()
        t0 = time.perf_counter()
        named, _ = named_state_leaves(self.params, self.opt_state)
        self._session.stream_next(named, self.step)
        dt = time.perf_counter() - t0
        self.ledger.record(t0, t0 + dt, "reshard_overlap",
                           self.world.parallel.world_size)
        if not self._session.done_precopy:
            return
        ready = self._grad_fn_ready()
        if ready:
            self._commit_armed = True
        elif ready is None:
            # split-step executables unavailable (compile failed): the
            # reconfiguration still completes — degrade to stop-copy
            self._commit_switch()

    def _grad_fn_ready(self):
        """True = armed, False = still compiling, None = compile failed."""
        if self.world.grad_fn is not None:
            return True
        if self._grad_builder is None:
            return False
        th, holder = self._grad_builder
        if th.is_alive():
            return False
        self._grad_builder = None
        if "err" in holder:
            import warnings

            warnings.warn(
                "split-step grad compile failed; falling back to stop-copy "
                f"commit: {holder['err']!r}"
            )
            return None
        self.world.grad_fn = holder["fn"]
        return True

    # ------------------------------------------------------------------
    # Plan + target-sharding bookkeeping (computed once, at READY)
    # ------------------------------------------------------------------
    def _named_target_shardings(self, world: WorldHandle) -> dict:
        ps, os_, _ = world.shardings
        named = {}
        for p, sh in tree_paths(ps).items():
            named[f"params/{p}"] = sh
        for coll in ("mu", "nu"):
            for p, sh in tree_paths(os_[coll]).items():
                named[f"{coll}/{p}"] = sh
        return named

    def _extra_shardings(self, world: WorldHandle) -> dict:
        """Shardings for opt-state leaves outside the resource view
        (step count, error-feedback buffers, ...)."""
        _, os_, _ = world.shardings
        return {k: v for k, v in os_.items() if k not in ("mu", "nu")}

    def _ensure_plan(self, new_world: WorldHandle) -> None:
        """Intersection plan for this reconfiguration. Normally precomputed
        by the Prepare thread (request_resize); recomputed here — timed into
        the record — only if the source layout changed since the request."""
        if self._session_plan is not None:
            return
        bundle = new_world.plan_bundle
        if bundle is not None and bundle[0] == self.world.parallel:
            _, specs, plan = bundle
            self._plan_seconds = 0.0
        else:
            t0 = time.perf_counter()
            specs, plan = plan_state_transfer(
                self.cfg,
                self.world.parallel,
                new_world.parallel,
                source_policy=self.source_policy,
            )
            self._plan_seconds = time.perf_counter() - t0
        self._session_specs = specs
        self._session_plan = plan
        self._session_targets = self._named_target_shardings(new_world)

    def _op_params(self) -> tuple[int, int]:
        """(stream_k, staging_bytes) for the current reconfiguration: the
        tuned operating point when the scheduler installed one, else the
        documented fallback constants."""
        op = self._operating_point
        if op is None:
            return self.stream_k, self.staging_bytes
        return op.stream_k, op.staging_bytes

    def _start_overlap_session(self) -> None:
        new_world: WorldHandle = self.machine.shadow.payload
        self._ensure_plan(new_world)
        stream_k, staging_bytes = self._op_params()
        self._session = OverlapSession(
            self._session_specs,
            self._session_plan,
            {},  # sources provided per streaming round
            self._session_targets,
            staging_bytes,
            stream_k=stream_k,
            wire_policy=self.wire_policy,
            wire_bw_bytes_s=self.wire_bw_bytes_s,
        )
        self._pending_rec = ReconfigRecord(
            gen_id=self._builder.gen_id,
            src=self.world.parallel.describe(),
            dst=new_world.parallel.describe(),
            prepare_s=new_world.timings.get("prepare_total_s", 0.0),
            mode="live_overlap",
            plan_s=self._plan_seconds,
            warm_hit=bool(new_world.timings.get("warm_hit", False)),
            prepare_source=new_world.timings.get("prepare_source", "cold"),
        )
        if self._operating_point is not None:
            self._pending_rec.operating_point = self._operating_point.to_dict()
        # retarget reuse: continue from the superseded session's streamed
        # state instead of restarting the stream from scratch
        if self._reuse is not None:
            old_targets, old_carries, old_streamed_at = self._reuse
            self._reuse = None
            self._session.adopt(old_carries, old_targets, old_streamed_at)
        # the session's figure already counts the plan's resident layers
        # (never streamed) plus anything adopted above
        self._pending_rec.reused_layers = self._session.report.reused_layers
        self._pending_rec.resident_layers = self._session.report.resident_layers
        if self.sync_compile and self.world.grad_fn is None:
            self.world.grad_fn = self._compile_grad_fn(self.world)
        # grads-only executable for the OLD world: compiled in a background
        # thread so the training loop never stalls on XLA (the commit is
        # simply not armed until it lands)
        if self.world.grad_fn is None and self._grad_builder is None:
            import threading

            world = self.world
            holder: dict = {}

            def compile_grad():
                try:
                    holder["fn"] = self._compile_grad_fn(world)
                except BaseException as e:  # surfaced at arm time
                    holder["err"] = e

            # non-daemon for the same reason as the prewarm thread: a
            # daemon thread killed mid-XLA-compile at exit crashes
            th = threading.Thread(target=compile_grad, daemon=False)
            th.start()
            self._grad_builder = (th, holder)

    def _compile_grad_fn(self, world: WorldHandle):
        from repro.distribution.step import jit_grad_step
        from repro.models.model import abstract_params

        jitted, _ = jit_grad_step(
            self.cfg,
            world.mesh,
            self.global_batch,
            microbatches=self.microbatches,
            hint_version=self.hint_version,
            parallel=world.parallel,
        )
        aparams = abstract_params(self.cfg)
        abatch = abstract_batch(self.cfg, self.global_batch, self.seq_len)
        return jitted.lower(aparams, abatch).compile()

    # ------------------------------------------------------------------
    # Switch — stop-copy: the whole transfer inside the pause
    # ------------------------------------------------------------------
    def _commit_switch(self) -> None:
        gen_id = self._builder.gen_id
        new_world: WorldHandle = self.machine.shadow.payload
        self._ensure_plan(new_world)
        plan = self._session_plan
        rec = ReconfigRecord(
            gen_id=gen_id,
            src=self.world.parallel.describe(),
            dst=new_world.parallel.describe(),
            prepare_s=new_world.timings.get("prepare_total_s", 0.0),
            plan_network_bytes=plan.network_bytes,
            plan_local_bytes=plan.local_bytes,
            layers_total=len(plan.layers()),
            reused_layers=len(plan.resident_layers()),
            resident_layers=len(plan.resident_layers()),
            plan_s=self._plan_seconds,
            warm_hit=bool(new_world.timings.get("warm_hit", False)),
            prepare_source=new_world.timings.get("prepare_source", "cold"),
        )
        pause_start = time.perf_counter()
        self.machine.begin_switch(gen_id)

        # 1. drain: all in-flight device work completes (1F1B boundary)
        t0 = time.perf_counter()
        jax.block_until_ready((self.params, self.opt_state))
        rec.drain_s = time.perf_counter() - t0

        # 2. streaming transfer: the plan executed on live arrays through
        # the shared engine (same protocol code as the sim oracle)
        t0 = time.perf_counter()
        named, extras = named_state_leaves(self.params, self.opt_state)
        _, op_staging = self._op_params()
        moved, stats = live_reshard_planned(
            self._session_specs,
            plan,
            named,
            self._session_targets,
            staging_bytes=op_staging,
            wire_policy=self.wire_policy,
            wire_bw_bytes_s=self.wire_bw_bytes_s,
        )
        new_extras, rep_x = live_reshard(
            extras, self._extra_shardings(new_world),
            staging_bytes=op_staging,
        )
        self.params, self.opt_state = rebuild_state(
            moved, self.params, self.opt_state, new_extras
        )
        rec.transfer_s = time.perf_counter() - t0
        rec.moved_bytes = (
            stats.network_bytes + stats.local_bytes + rep_x.moved_bytes
        )
        rec.skipped_bytes = stats.resident_bytes
        rec.resident_cells = stats.resident_cells
        rec.wire_bytes = stats.wire_bytes
        rec.logical_bytes = stats.logical_bytes
        rec.executed_bytes = stats.executed_bytes + rep_x.moved_bytes
        rec.stream_dispatch_s = stats.dispatch_seconds
        rec.stream_drain_s = stats.drain_seconds
        rec.generic_cells = stats.generic_cells
        if self._operating_point is not None:
            rec.operating_point = self._operating_point.to_dict()

        # 3. atomic switch: pointer swap of world references
        t0 = time.perf_counter()
        old = self.machine.commit_switch(gen_id)
        rec.switch_s = time.perf_counter() - t0

        rec.total_pause_s = time.perf_counter() - pause_start
        self.ledger.record(
            pause_start,
            pause_start + rec.total_pause_s,
            "pause",
            max(self.world.parallel.world_size, new_world.parallel.world_size),
        )
        self.records.append(rec)
        self._reset_reconfig_state()

        # 4. cleanup (old world retires into the warm pool when one exists,
        # else its resources release; source arrays freed as the last
        # references drop with the old generation)
        self._retire_world(old)
        self.machine.finish_cleanup()

    # ------------------------------------------------------------------
    # Switch — overlapped: grads on the old world hide the dirty re-sync;
    # the optimizer update lands directly on the new world
    # ------------------------------------------------------------------
    def _split_step_commit(self, batch) -> dict:
        gen_id = self._builder.gen_id
        new_world: WorldHandle = self.machine.shadow.payload
        session = self._session
        rec = self._pending_rec
        plan = self._session_plan

        # dispatch the final gradient computation on the OLD world (params
        # are not donated: they are simultaneously the re-sync source)
        t0 = time.perf_counter()
        loss, grads = self.world.grad_fn(self.params, batch)

        # overlapped with it: re-sync every dirty layer from this
        # boundary's consistent cut, plus the non-resource-view leftovers.
        # drain=False: only the dispatch (and the staging sync) happens
        # here — the scatters keep landing underneath the grad computation,
        # and the single blocking drain moves inside the pause where it is
        # a residual tail rather than a full re-stream wait
        named, extras = named_state_leaves(self.params, self.opt_state)
        session.resync(named, self.step, drain=False)
        _, op_staging = self._op_params()
        new_extras, _ = live_reshard(
            extras, self._extra_shardings(new_world),
            staging_bytes=op_staging,
        )
        t1 = time.perf_counter()
        jax.block_until_ready((loss, grads))
        grad_tail_s = time.perf_counter() - t1  # residual wait past overlap

        # ---- the commit pause: re-sync tail + grad reshard + update +
        # pointer swap. session.drain() is the ONLY blocking wait on the
        # streamed state (per-round barriers were retired with the async
        # data plane); it must land before update_fn may donate the
        # destination carries ----
        pause_start = time.perf_counter()
        self.machine.begin_switch(gen_id)
        commit_drain_s = session.drain()
        t0 = time.perf_counter()
        p_specs = [s for s in self._session_specs if s.collection == "params"]
        from repro.core.intersection import TransferPlan

        p_plan = TransferPlan(
            tasks=[t for t in plan.tasks if t.collection == "params"],
            cfg_src=plan.cfg_src,
            cfg_dst=plan.cfg_dst,
        )
        g_named = {
            f"params/{p}": leaf for p, leaf in tree_paths(grads).items()
        }
        g_targets = {
            k: v for k, v in self._session_targets.items()
            if k.startswith("params/")
        }
        g_moved, g_stats = live_reshard_planned(
            p_specs, p_plan, g_named, g_targets,
            staging_bytes=op_staging,
            wire_policy=self.wire_policy,
            wire_bw_bytes_s=self.wire_bw_bytes_s,
        )
        from repro.utils.pytree import tree_from_paths

        grads_new = tree_from_paths(
            {p: g_moved[f"params/{p}"] for p in tree_paths(grads)}, grads
        )
        rec.transfer_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        params_new, opt_new = rebuild_state(
            session.results(), self.params, self.opt_state, new_extras
        )
        self.params, self.opt_state, om = new_world.update_fn(
            grads_new, opt_new, params_new
        )
        jax.block_until_ready(self.params)
        rec.update_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        old = self.machine.commit_switch(gen_id)
        rec.switch_s = time.perf_counter() - t0
        rec.total_pause_s = time.perf_counter() - pause_start

        rep = session.report
        # drain_s = residual waits: grad tail outside the pause + re-sync
        # tail inside it (commit_drain_s appears here and on the drain-side
        # axis below, nowhere else — the phase columns stay additive)
        rec.drain_s = grad_tail_s + commit_drain_s
        rec.precopy_s = rep.precopy_seconds
        rec.precopy_bytes = rep.precopy_bytes
        rec.resync_s = rep.resync_seconds
        rec.resync_bytes = rep.resync_bytes
        rec.stream_dispatch_s = rep.dispatch_seconds + g_stats.dispatch_seconds
        rec.stream_drain_s = (
            rep.drain_seconds + commit_drain_s + g_stats.drain_seconds
        )
        rec.generic_cells = session.stats.generic_cells + g_stats.generic_cells
        rec.dirty_layers = rep.resync_layers
        rec.layers_total = len(plan.layers())
        rec.reused_layers = rep.reused_layers
        rec.resident_layers = rep.resident_layers
        rec.skipped_bytes = rep.skipped_bytes + g_stats.resident_bytes
        rec.resident_cells = rep.resident_cells + g_stats.resident_cells
        rec.wire_bytes = rep.wire_bytes + g_stats.wire_bytes
        rec.logical_bytes = rep.logical_bytes + g_stats.logical_bytes
        rec.plan_network_bytes = plan.network_bytes
        rec.plan_local_bytes = plan.local_bytes
        rec.moved_bytes = rep.total_bytes + g_stats.network_bytes + g_stats.local_bytes
        rec.executed_bytes = session.stats.executed_bytes + g_stats.executed_bytes
        self.ledger.record(
            pause_start, pause_start + rec.total_pause_s, "pause",
            max(self.world.parallel.world_size, new_world.parallel.world_size),
        )
        self.records.append(rec)
        self._reset_reconfig_state()

        self._retire_world(old)
        self.machine.finish_cleanup()
        return {"loss": loss, **om}

    def _reset_reconfig_state(self) -> None:
        self._builder = None
        self._inflight_target = None
        self._session = None
        self._session_specs = None
        self._session_plan = None
        self._session_targets = None
        self._pending_rec = None
        self._commit_armed = False
        self._grad_builder = None
        self._plan_seconds = 0.0
        self._reuse = None
        self._overlap_mode = self.overlap
        self._operating_point = None

    # ------------------------------------------------------------------
    # Fail-stop fallback (invariant I4) and restart baselines
    # ------------------------------------------------------------------
    def checkpoint_now(self) -> None:
        """Durable snapshot of the current step, synchronously.

        The scheduler's checkpoint rung: a warned event whose window cannot
        fit any live path saves NOW (inside the window) so the follow-up
        restore loses no progress."""
        if self._ckpt is not None:
            self._ckpt.save(
                self.step, {"params": self.params, "opt": self.opt_state}
            )
            self._ckpt.wait()

    def peer_coverage(
        self,
        target: ParallelConfig,
        lost_ranks: tuple = (),
        devices_failed: bool = True,
    ):
        """(survivor-constrained plan covers the state?, donor wire bytes).

        Metadata-only (one intersection plan), used by the deadline
        estimator to price the ``peer_recover`` rung. Counts state the
        fresh parity word could repair as covered."""
        from repro.elastic.redundancy import survivors_for

        src = self.world.parallel
        survivors = survivors_for(
            src, lost_ranks, target=target, devices_failed=devices_failed
        )
        _, plan = plan_state_transfer(
            self.cfg, src, target,
            source_policy=self.source_policy, allowed_src=survivors,
        )
        lost_bytes = plan.lost_bytes
        parity_ok = self._parity is not None and self._parity.covers(self.step)
        covered = lost_bytes == 0 or parity_ok
        return covered, plan.network_bytes + (lost_bytes if parity_ok else 0)

    def fail_stop_recover(
        self,
        target: ParallelConfig,
        devices_failed: bool = True,
        lost_ranks: tuple = (),
    ) -> ReconfigRecord:
        """Recover a fail-stop from surviving peers, in memory (§15).

        The recovery rungs, in order:

        1. **peer_recover** — plan the state transfer with sources
           restricted to the survivor set; DP/EP replicas donate the cells
           the dead ranks held (donor-balanced), cells whose whole replica
           group died are reconstructed from the XOR parity word when it
           is fresh. The survivor world comes warm-pool-first, then the
           stream runs over the same engine as a live resize — losslessly:
           recovery is correctness-critical, so the compressed wire format
           never applies. No step rollback: the survivors' state IS the
           current step.
        2. **checkpoint** (demoted, last resort) — only when survivors +
           parity cannot cover the state and a ckpt_dir exists.
        3. Neither → typed :class:`RecoveryError` (never a bare assert).

        ``devices_failed`` distinguishes an unannounced failure (devices
        in the old world are suspect: the outgoing world is NOT pooled and
        pooled worlds overlapping the lost device prefix are invalidated)
        from the scheduler's past-deadline rung for a *warned* event
        (devices are fine, only the window was too short — everyone
        survives and warm worlds stay valid). ``lost_ranks`` names the
        dead ranks explicitly; empty means the prefix-allocation default
        (the ranks beyond ``target``'s world died)."""
        from repro.elastic.redundancy import (
            balance_donors,
            heal_plan,
            survivors_for,
        )

        src_parallel = self.world.parallel
        survivors = survivors_for(
            src_parallel, lost_ranks, target=target,
            devices_failed=devices_failed,
        )
        lost = frozenset(range(src_parallel.world_size)) - survivors
        if devices_failed and self.world_pool is not None and lost:
            # under prefix allocation a pooled world of size W runs on
            # devices[:W] — it overlaps the dead set iff W exceeds the
            # lowest lost device id
            min_lost = min(lost)
            self.world_pool.invalidate(
                lambda key, h: h.parallel.world_size > min_lost
            )

        rec = ReconfigRecord(
            gen_id=-1, src=src_parallel.describe(), dst=target.describe(),
            mode="peer_recover", outcome="committed",
        )
        rec.lost_devices = len(lost)
        pause_start = time.perf_counter()

        # residual shadow work (paper §4.1 graceful degradation): a ready
        # shadow for the same target skips re-initialization — even one
        # caught mid-stream or mid-commit; its partially streamed state is
        # dropped (it may predate this boundary's cut) and re-streamed
        residual = None
        if (
            self._builder is not None
            and self._builder.ready
            and self.machine.shadow is not None
        ):
            cand: WorldHandle = self._builder.result()
            if cand.parallel == target:
                residual = cand
        if self._builder is not None and residual is None:
            self._builder.abandon()
        if self.machine.state in (GenState.PREPARE, GenState.READY):
            self.machine.cancel()
        self._reset_reconfig_state()

        # if a prewarm for exactly this pair is mid-compile, wait for it:
        # its cache insert is strictly cheaper than compiling the same
        # programs a second time in parallel with it
        if (
            self._prewarm_thread is not None
            and self._prewarm_thread.is_alive()
            and self._prewarm_pair == (src_parallel, target)
        ):
            self._prewarm_thread.join(timeout=60.0)
        self._speculation_trace(
            f"recover {src_parallel.describe()}->{target.describe()} "
            f"prewarmed={(src_parallel, target) in self._prewarmed_pairs}"
        )

        # survivor-constrained plan (metadata only)
        t0 = time.perf_counter()
        specs, plan = plan_state_transfer(
            self.cfg, src_parallel, target,
            source_policy=self.source_policy, allowed_src=survivors,
        )
        rec.plan_s = time.perf_counter() - t0

        lost_tasks = plan.lost_tasks()
        parity_fresh = (
            self._parity is not None
            and self._parity.cfg == src_parallel
            and self._parity.covers(self.step)
        )
        if lost_tasks and not parity_fresh:
            # peers cannot cover the state: demote to the checkpoint rung
            return self._checkpoint_restore(
                target, devices_failed, pause_start, rec.lost_devices,
                reason=f"{plan.lost_bytes} bytes have no surviving replica "
                "and no fresh parity",
            )

        # consistent cut: all in-flight device work lands before we read
        # survivor bytes (and before parity mixes them into a repair)
        t0 = time.perf_counter()
        jax.block_until_ready((self.params, self.opt_state))
        rec.drain_s = time.perf_counter() - t0

        named, extras = named_state_leaves(self.params, self.opt_state)
        if lost_tasks:
            named, rec.parity_bytes = self._parity.repair(
                named, lost, self.step
            )
            plan, _ = heal_plan(plan, specs)
        plan = balance_donors(plan, specs, survivors)
        rec.plan_network_bytes = plan.network_bytes
        rec.plan_local_bytes = plan.local_bytes
        rec.donors = len(
            {t.src_rank for t in plan.tasks if t.kind == "remote"}
        )

        # survivor world: residual shadow, warm pool, an in-flight
        # speculative build (joined), then cold
        t0 = time.perf_counter()
        world = residual
        rec.prepare_source = "residual" if residual is not None else "cold"
        if world is None and self.world_pool is not None:
            world = self.world_pool.take(self.pool_key(target))
            if world is not None:
                rec.prepare_source = "pool"
            else:
                join = self._spec_builders.pop(self.pool_key(target), None)
                if join is not None:
                    try:
                        world = self._refresh_pooled(
                            join.result(), self._overlap_mode,
                            source="speculative_join",
                        )
                        rec.prepare_source = "speculative_join"
                    except BaseException:
                        world = None
        rec.warm_hit = world is not None and rec.prepare_source == "pool"
        if world is None:
            world = self._build_world(
                target, split_step=self.world_pool is not None
            )
        rec.prepare_s = time.perf_counter() - t0

        # donor stream over the shared engine — always lossless: a lossy
        # wire would make the recovered state diverge from the survivors'
        t0 = time.perf_counter()
        targets = self._named_target_shardings(world)
        moved, stats = live_reshard_planned(
            specs, plan, named, targets,
            staging_bytes=self.staging_bytes,
            wire_policy=None,
            wire_bw_bytes_s=self.wire_bw_bytes_s,
        )
        new_extras, rep_x = live_reshard(
            extras, self._extra_shardings(world),
            staging_bytes=self.staging_bytes,
        )
        self.params, self.opt_state = rebuild_state(
            moved, self.params, self.opt_state, new_extras
        )
        rec.transfer_s = time.perf_counter() - t0
        rec.moved_bytes = (
            stats.network_bytes + stats.local_bytes + rep_x.moved_bytes
        )
        rec.skipped_bytes = stats.resident_bytes
        rec.resident_cells = stats.resident_cells
        rec.wire_bytes = stats.wire_bytes
        rec.logical_bytes = stats.logical_bytes
        rec.executed_bytes = stats.executed_bytes + rep_x.moved_bytes
        # NO step rollback: survivors carry the current step's state

        t0 = time.perf_counter()
        gen = self.machine.begin_prepare("failstop-" + target.describe())
        self.machine.mark_ready(gen.gen_id, payload=world)
        self.machine.begin_switch(gen.gen_id)
        old = self.machine.commit_switch(gen.gen_id)
        rec.switch_s = time.perf_counter() - t0
        if devices_failed:
            # the outgoing world ran on the (partially) failed device set:
            # never pool it — a later walk-up would compute the same
            # fingerprint from the static device list and serve executables
            # loaded onto a dead device
            old.payload = None
        else:
            self._retire_world(old)
        self.machine.finish_cleanup()
        # the parity word XORs per-rank images of the OLD layout
        self._parity = None

        rec.total_pause_s = time.perf_counter() - pause_start
        self.ledger.record(
            pause_start, pause_start + rec.total_pause_s, "pause",
            target.world_size,
        )
        self.records.append(rec)
        return rec

    def _checkpoint_restore(
        self,
        target: ParallelConfig,
        devices_failed: bool,
        pause_start: float,
        lost_devices: int,
        reason: str = "",
    ) -> ReconfigRecord:
        """The demoted last-resort rung: rebuild from the latest durable
        checkpoint (rolls the step back to it). Only reached when the
        survivor set plus parity cannot cover the state."""
        from repro.core.errors import RecoveryError

        if not self.ckpt_dir:
            raise RecoveryError(
                f"fail-stop to {target.describe()} is unrecoverable: "
                f"{reason or 'peers cannot cover the state'}, and no "
                "checkpoint directory is configured"
            )
        if self._ckpt:
            try:
                self._ckpt.wait()
            except Exception:
                # a failed background write surfaces here (satellite:
                # AsyncCheckpointer error propagation); an older durable
                # step may still exist — let load_checkpoint decide
                pass
        rec = ReconfigRecord(
            gen_id=-1, src=self.world.parallel.describe(),
            dst=target.describe(), mode="fallback", outcome="fell_back",
        )
        rec.lost_devices = lost_devices
        # residual shadow work (paper §4.1 graceful degradation): a ready
        # shadow for the same target skips re-initialization
        residual = None
        if (
            self._builder is not None
            and self._builder.ready
            and self.machine.shadow is not None
        ):
            cand: WorldHandle = self._builder.result()
            if cand.parallel == target:
                residual = cand
        if self._builder is not None and residual is None:
            self._builder.abandon()
        if self.machine.state in (GenState.PREPARE, GenState.READY):
            self.machine.cancel()
        self._reset_reconfig_state()

        t0 = time.perf_counter()
        world = residual
        rec.prepare_source = "residual" if residual is not None else "cold"
        if world is None and self.world_pool is not None:
            # warm pool: same graceful degradation as residual shadow work
            world = self.world_pool.take(self.pool_key(target))
            if world is not None:
                rec.prepare_source = "pool"
        rec.warm_hit = world is not None
        if world is None:
            world = self._build_world(
                target, split_step=self.world_pool is not None
            )
        init_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        ps, os_, _ = world.shardings
        try:
            state, step, load_s = load_checkpoint(
                self.ckpt_dir,
                like={"params": self.params, "opt": self.opt_state},
                target_shardings={"params": ps, "opt": os_},
            )
        except Exception as e:
            raise RecoveryError(
                f"fail-stop to {target.describe()} is unrecoverable: "
                f"{reason or 'peers cannot cover the state'}, and no "
                f"durable checkpoint could be loaded from {self.ckpt_dir}"
            ) from e
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = step

        gen = self.machine.begin_prepare("failstop-" + target.describe())
        self.machine.mark_ready(gen.gen_id, payload=world)
        self.machine.begin_switch(gen.gen_id)
        old = self.machine.commit_switch(gen.gen_id)
        if devices_failed:
            old.payload = None
        else:
            self._retire_world(old)
        self.machine.finish_cleanup()
        self._parity = None

        rec.transfer_s = load_s
        rec.prepare_s = init_s
        rec.total_pause_s = time.perf_counter() - pause_start
        self.ledger.record(
            pause_start, pause_start + rec.total_pause_s, "pause",
            target.world_size,
        )
        self.records.append(rec)
        return rec

    def gathered_params(self) -> Any:
        """Fully-replicated host copy (verification only — never on the
        live path)."""
        return jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), self.params
        )
