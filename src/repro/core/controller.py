"""LiveR controller (paper §4.3 end-to-end workflow, §4.7 switch).

Orchestrates the full reconfiguration lifecycle on live JAX state:

  trigger → Prepare (shadow thread: mesh + AOT compile)  [overlapped, I1]
          → Ready   (await iteration boundary)           [deterministic, I3]
          → Switch  (drain → live reshard → pointer swap) [the only pause]
          → Cleanup (free old world asynchronously)
          → Stable

plus the fail-stop fallback to durable checkpoints (invariant I4) and the
stop-and-restart / checkpoint-reshape (UCP) baselines used by the
benchmarks.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.downtime import GoodputLedger
from repro.core.generations import GenerationMachine, GenState
from repro.core.reshard import DEFAULT_STAGING_BYTES, live_reshard
from repro.core.shadow import ShadowBuilder, WorldHandle, build_train_world
from repro.data import SyntheticLM
from repro.optim import AdamWConfig


@dataclass
class ReconfigRecord:
    gen_id: int
    src: str
    dst: str
    prepare_s: float = 0.0
    drain_s: float = 0.0
    transfer_s: float = 0.0
    switch_s: float = 0.0
    total_pause_s: float = 0.0
    moved_bytes: int = 0
    mode: str = "live"  # live | restart | ucp_restart | fallback


class LiveRController:
    def __init__(
        self,
        cfg: ModelConfig,
        parallel: ParallelConfig,
        opt_cfg: AdamWConfig,
        seq_len: int,
        global_batch: int,
        data: Optional[SyntheticLM] = None,
        ckpt_dir: Optional[str] = None,
        ckpt_interval: int = 50,
        staging_bytes: int = DEFAULT_STAGING_BYTES,
        devices=None,
        microbatches: int = 1,
        compression: str = "none",
        hint_version: str | None = None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.staging_bytes = staging_bytes
        self.devices = devices if devices is not None else jax.devices()
        self.microbatches = microbatches
        self.compression = compression
        self.hint_version = hint_version
        self.machine = GenerationMachine()
        self.ledger = GoodputLedger()
        self.records: list[ReconfigRecord] = []
        self.iteration_times: list[float] = []
        self.step = 0
        self.data = data or SyntheticLM(cfg.vocab_size, seq_len, global_batch, seed)
        self.ckpt_dir = ckpt_dir
        self.ckpt_interval = ckpt_interval
        self._ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self._builder: Optional[ShadowBuilder] = None

        # Active World (generation 0)
        world = build_train_world(
            cfg, parallel, opt_cfg, global_batch, seq_len,
            microbatches=microbatches, devices=self._device_subset(parallel),
            compression=compression, hint_version=hint_version,
        )
        world.gen_id = 0
        self.machine.active.payload = world
        from repro.distribution.step import init_train_state

        self.params, self.opt_state = init_train_state(
            cfg, world.mesh, seed=seed, compression=compression
        )

    # ------------------------------------------------------------------
    @property
    def world(self) -> WorldHandle:
        return self.machine.active.payload

    def _device_subset(self, parallel: ParallelConfig):
        return self.devices[: parallel.world_size]

    # ------------------------------------------------------------------
    # Prepare (background)
    # ------------------------------------------------------------------
    def request_resize(self, target: ParallelConfig) -> int:
        """Trigger: spawn Shadow World preparation. Non-blocking."""
        gen = self.machine.begin_prepare(description=target.describe())

        def build():
            return build_train_world(
                self.cfg,
                target,
                self.opt_cfg,
                self.global_batch,
                self.seq_len,
                microbatches=self.microbatches,
                devices=self._device_subset(target),
                compression=self.compression,
                hint_version=self.hint_version,
            )

        self._builder = ShadowBuilder(build, gen.gen_id).start()
        return gen.gen_id

    def cancel_resize(self) -> None:
        """Target became stale before commit (paper §7): abandon shadow."""
        self.machine.cancel()
        self._builder = None

    # ------------------------------------------------------------------
    # Training loop with boundary polling
    # ------------------------------------------------------------------
    def train_steps(self, n: int, collect: Optional[Callable] = None) -> list[float]:
        losses = []
        for _ in range(n):
            t0 = time.perf_counter()
            batch = self._batch()
            self.params, self.opt_state, metrics = self.world.step_fn(
                self.params, self.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.iteration_times.append(dt)
            self.ledger.record(t0, t0 + dt, "train", self.world.parallel.world_size)
            losses.append(float(metrics["loss"]))
            self.step += 1
            if collect:
                collect(self.step, metrics)
            if self._ckpt and self.step % self.ckpt_interval == 0:
                self._ckpt.save(self.step, {"params": self.params, "opt": self.opt_state})
            self._poll_boundary()
        return losses

    def _batch(self):
        tokens = jnp.asarray(self.data.global_batch_at(self.step))
        batch = {"tokens": tokens}
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (self.global_batch, self.seq_len, self.cfg.d_model), jnp.float32
            )
        return batch

    def _poll_boundary(self) -> None:
        """Iteration boundary = the consistent cut (invariant I3)."""
        if self._builder is not None and self._builder.ready:
            if self.machine.state == GenState.PREPARE:
                handle = self._builder.result()
                self.machine.mark_ready(self._builder.gen_id, payload=handle)
            if self.machine.state == GenState.READY:
                self._commit_switch()

    # ------------------------------------------------------------------
    # Switch (the only pause on the live path)
    # ------------------------------------------------------------------
    def _commit_switch(self) -> None:
        gen_id = self._builder.gen_id
        new_world: WorldHandle = self.machine.shadow.payload
        rec = ReconfigRecord(
            gen_id=gen_id,
            src=self.world.parallel.describe(),
            dst=new_world.parallel.describe(),
            prepare_s=new_world.timings.get("prepare_total_s", 0.0),
        )
        pause_start = time.perf_counter()
        self.machine.begin_switch(gen_id)

        # 1. drain: all in-flight device work completes (1F1B boundary)
        t0 = time.perf_counter()
        jax.block_until_ready((self.params, self.opt_state))
        rec.drain_s = time.perf_counter() - t0

        # 2. streaming transfer: live reshard onto the new world
        t0 = time.perf_counter()
        ps, os_, _ = new_world.shardings
        self.params, rep_p = live_reshard(
            self.params, ps, staging_bytes=self.staging_bytes
        )
        self.opt_state, rep_o = live_reshard(
            self.opt_state, os_, staging_bytes=self.staging_bytes
        )
        rec.transfer_s = time.perf_counter() - t0
        rec.moved_bytes = rep_p.moved_bytes + rep_o.moved_bytes

        # 3. atomic switch: pointer swap of world references
        t0 = time.perf_counter()
        old = self.machine.commit_switch(gen_id)
        rec.switch_s = time.perf_counter() - t0

        rec.total_pause_s = time.perf_counter() - pause_start
        self.ledger.record(
            pause_start,
            pause_start + rec.total_pause_s,
            "pause",
            max(self.world.parallel.world_size, new_world.parallel.world_size),
        )
        self.records.append(rec)
        self._builder = None

        # 4. cleanup (old world resources released; mesh handles are cheap
        # in JAX — state arrays were donated during reshard)
        old.payload = None
        self.machine.finish_cleanup()

    # ------------------------------------------------------------------
    # Fail-stop fallback (invariant I4) and restart baselines
    # ------------------------------------------------------------------
    def fail_stop_recover(self, target: ParallelConfig) -> ReconfigRecord:
        """Unannounced failure: rebuild from the latest durable checkpoint."""
        assert self.ckpt_dir, "fallback requires a checkpoint directory"
        if self._ckpt:
            self._ckpt.wait()
        rec = ReconfigRecord(
            gen_id=-1, src=self.world.parallel.describe(),
            dst=target.describe(), mode="fallback",
        )
        pause_start = time.perf_counter()
        # residual shadow work (paper §4.1 graceful degradation): a ready
        # shadow for the same target skips re-initialization
        residual = None
        if (
            self._builder is not None
            and self._builder.ready
            and self.machine.shadow is not None
        ):
            cand: WorldHandle = self._builder.result()
            if cand.parallel == target:
                residual = cand
        if self.machine.state in (GenState.PREPARE, GenState.READY):
            self.machine.cancel()
        self._builder = None

        t0 = time.perf_counter()
        world = residual or build_train_world(
            self.cfg, target, self.opt_cfg, self.global_batch, self.seq_len,
            microbatches=self.microbatches, devices=self._device_subset(target),
            compression=self.compression, hint_version=self.hint_version,
        )
        init_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        ps, os_, _ = world.shardings
        like = {
            "params": jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                jax.eval_shape(lambda: self.params),
            ),
        }
        state, step, load_s = load_checkpoint(
            self.ckpt_dir,
            like={"params": self.params, "opt": self.opt_state},
            target_shardings={"params": ps, "opt": os_},
        )
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = step

        gen = self.machine.begin_prepare("failstop-" + target.describe())
        self.machine.mark_ready(gen.gen_id, payload=world)
        self.machine.begin_switch(gen.gen_id)
        old = self.machine.commit_switch(gen.gen_id)
        old.payload = None
        self.machine.finish_cleanup()

        rec.transfer_s = load_s
        rec.prepare_s = init_s
        rec.total_pause_s = time.perf_counter() - pause_start
        self.ledger.record(
            pause_start, pause_start + rec.total_pause_s, "pause",
            target.world_size,
        )
        self.records.append(rec)
        return rec

    def gathered_params(self) -> Any:
        """Fully-replicated host copy (verification only — never on the
        live path)."""
        return jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), self.params
        )
