"""Elasticity events (paper §4.1 'Elasticity event spectrum').

Planned resizes and preemption warnings carry a warning window; fail-stop
events do not (invariant I4 routes them to checkpoint recovery).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ParallelConfig


@dataclass(frozen=True)
class ResizeEvent:
    """Warning-based or planned elasticity event."""

    time_s: float  # when the event fires (trace time)
    target: ParallelConfig  # topology chosen by the (external) search system
    warning_s: float = 120.0  # e.g. AWS Spot's 2-minute notice
    kind: str = "resize"  # resize | scale_out | scale_in | preempt

    @property
    def deadline_s(self) -> float:
        return self.time_s + self.warning_s


@dataclass(frozen=True)
class FailStopEvent:
    """Unannounced failure: zero warning window. The scheduler routes these
    to the durable-checkpoint fallback (controller ``fail_stop_recover``);
    ``target`` is the post-failure topology when the (external) search
    system has already chosen one, else the scheduler picks via
    :func:`repro.core.topology_search.best_target` over the surviving
    devices."""

    time_s: float
    lost_ranks: tuple[int, ...] = ()
    kind: str = "fail_stop"
    target: Optional[ParallelConfig] = None


ElasticityEvent = ResizeEvent | FailStopEvent


def sort_trace(events: list) -> list:
    """Events in firing order (stable for simultaneous arrivals, which the
    scheduler then coalesces)."""
    return sorted(events, key=lambda e: e.time_s)
