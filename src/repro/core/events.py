"""Elasticity events (paper §4.1 'Elasticity event spectrum').

Planned resizes and preemption warnings carry a warning window — use
``warning_s=float("inf")`` for a planned resize with no deadline at all
(the arithmetic is inf-safe end to end; serialized payloads render it as
the string ``"inf"``). Fail-stop events have no window; the scheduler
recovers them from peer replicas when the survivors cover the state,
falling back to the durable checkpoint (DESIGN.md §15).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ParallelConfig


@dataclass(frozen=True)
class ResizeEvent:
    """Warning-based or planned elasticity event."""

    time_s: float  # when the event fires (trace time)
    target: ParallelConfig  # topology chosen by the (external) search system
    warning_s: float = 120.0  # e.g. AWS Spot's 2-minute notice
    kind: str = "resize"  # resize | scale_out | scale_in | preempt

    @property
    def deadline_s(self) -> float:
        return self.time_s + self.warning_s


@dataclass(frozen=True)
class FailStopEvent:
    """Unannounced failure: zero warning window. The scheduler routes these
    to peer-replica recovery (controller ``fail_stop_recover``), which
    demotes to the durable checkpoint only when survivors + parity cannot
    cover the state; ``target`` is the post-failure topology when the
    (external) search
    system has already chosen one, else the scheduler picks via
    :func:`repro.core.topology_search.best_target` over the surviving
    devices."""

    time_s: float
    lost_ranks: tuple[int, ...] = ()
    kind: str = "fail_stop"
    target: Optional[ParallelConfig] = None


ElasticityEvent = ResizeEvent | FailStopEvent


def sort_trace(events: list) -> list:
    """Events in firing order (stable for simultaneous arrivals, which the
    scheduler then coalesces)."""
    return sorted(events, key=lambda e: e.time_s)
