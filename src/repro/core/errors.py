"""Typed recovery errors (DESIGN.md §15).

``RecoveryError`` replaces the bare ``assert self.ckpt_dir`` that used to
guard ``fail_stop_recover``: asserts vanish under ``python -O``, and the
scheduler needs a typed signal it can catch to retire the event as
``aborted`` instead of crashing the replay loop. Raised when a fail-stop
cannot be recovered by *any* rung — the survivor set (plus parity) cannot
cover the state and no checkpoint directory is configured.

Lives in ``core`` (not ``elastic.redundancy``) so the reshard engine can
refuse to execute ``kind == "lost"`` tasks without importing the elastic
package (which imports reshard back — a cycle).
"""

from __future__ import annotations


class RecoveryError(RuntimeError):
    """No recovery rung can restore the state; fail loudly with context."""
