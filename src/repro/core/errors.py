"""Typed control-plane errors (DESIGN.md §15, §17).

``RecoveryError`` replaces the bare ``assert self.ckpt_dir`` that used to
guard ``fail_stop_recover``: asserts vanish under ``python -O``, and the
scheduler needs a typed signal it can catch to retire the event as
``aborted`` instead of crashing the replay loop. Raised when a fail-stop
cannot be recovered by *any* rung — the survivor set (plus parity) cannot
cover the state and no checkpoint directory is configured.

Lives in ``core`` (not ``elastic.redundancy``) so the reshard engine can
refuse to execute ``kind == "lost"`` tasks without importing the elastic
package (which imports reshard back — a cycle).
"""

from __future__ import annotations


class RecoveryError(RuntimeError):
    """No recovery rung can restore the state; fail loudly with context."""


class ProtocolError(RuntimeError):
    """Malformed or unsupported control-plane message (DESIGN.md §17):
    unknown type tag, missing required field, or a schema version newer
    than this decoder. Also raised driver-side when an endpoint answers a
    command with an unexpected ``ErrorResponse``."""


class TraceError(ValueError):
    """Malformed volatility-trace row (``elastic/trace.py``): unknown
    event kind, non-positive device count, negative/NaN warning window or
    timestamp, or invalid lost-rank list. Raised at trace-load time so a
    bad row fails the replay up front instead of mid-run with an opaque
    topology-search error."""
