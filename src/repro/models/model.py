"""Public model API: init / forward / loss / prefill / decode / input specs.

All ten assigned architectures flow through these entry points; the
distribution layer wraps them into pjit'd train/prefill/decode steps and the
dry-run lowers them against the production mesh.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import kvcache
from repro.models import layers as L
from repro.models import shard_hints
from repro.models import transformer as T


def _dtype(name: str):
    # jnp.dtype resolves any registered dtype name; a literal two-entry map
    # here raised KeyError for e.g. float16 (see shadow.abstract_batch)
    return jnp.dtype(name)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_with_axes(cfg: ModelConfig, rng) -> tuple[dict, dict]:
    pdt = _dtype(cfg.param_dtype)
    keys = jax.random.split(rng, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["embed"], axes["embed"] = L.embed_init(keys[0], cfg, pdt)
    if cfg.family == "encdec":
        enc_cfg = _encoder_cfg(cfg)
        params["encoder"], axes["encoder"] = {}, {}
        params["encoder"]["blocks"], axes["encoder"]["blocks"] = T.stack_init(
            keys[3], enc_cfg, pdt
        )
        params["encoder"]["final_norm"], axes["encoder"]["final_norm"] = L.rmsnorm_init(
            cfg.d_model, pdt
        )
        params["blocks"], axes["blocks"] = T.stack_init(keys[1], cfg, pdt, cross=True)
    else:
        params["blocks"], axes["blocks"] = T.stack_init(keys[1], cfg, pdt)
    params["final_norm"], axes["final_norm"] = L.rmsnorm_init(cfg.d_model, pdt)
    if not cfg.tie_embeddings:
        params["lm_head"], axes["lm_head"] = L.lm_head_init(keys[2], cfg, pdt)
    return params, axes


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        cfg,
        family="dense",
        num_layers=cfg.encoder_layers,
        num_experts=0,
        attn_period=0,
        frontend=None,
    )


def init_params(cfg: ModelConfig, rng) -> dict:
    return _init_with_axes(cfg, rng)[0]


@functools.lru_cache(maxsize=64)
def _abstract_cached(cfg: ModelConfig):
    return jax.eval_shape(lambda: _init_with_axes(cfg, jax.random.key(0))[0])


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree — no allocation (dry-run / planning)."""
    return _abstract_cached(cfg)


def param_logical_axes(cfg: ModelConfig) -> dict:
    """Logical-axis tuples mirroring the param tree (tuples are leaves)."""
    return _init_with_axes_axes(cfg)


@functools.lru_cache(maxsize=64)
def _init_with_axes_axes(cfg: ModelConfig) -> dict:
    # axes tree contains python tuples only; compute it via eval_shape to
    # avoid touching devices, then discard the abstract params.
    out = {}

    def capture():
        p, a = _init_with_axes(cfg, jax.random.key(0))
        out["axes"] = a
        return p

    jax.eval_shape(capture)
    return out["axes"]


def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    from repro.utils.pytree import axes_paths, tree_paths

    params = abstract_params(cfg)
    axes = param_logical_axes(cfg)
    pflat = tree_paths(params)
    aflat = axes_paths(axes)
    total = 0
    for path, leaf in pflat.items():
        n = int(np.prod(leaf.shape))
        if active_only and cfg.num_experts > 0:
            ax = aflat.get(path, ())
            if "expert" in ax:
                n = n * cfg.experts_per_token // cfg.num_experts
        total += n
    return total


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _embed_or_frames(cfg: ModelConfig, params, batch, dtype):
    if cfg.family == "encdec":
        return batch["frames"].astype(dtype)
    return L.embed_apply(params["embed"], batch["tokens"], dtype)


def encode(cfg: ModelConfig, params, frames: jax.Array, remat: str = "full"):
    """Encoder forward (enc-dec archs). frames: (b, s_enc, d_model)."""
    enc_cfg = _encoder_cfg(cfg)
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x, _ = T.stack_forward(
        params["encoder"]["blocks"],
        enc_cfg,
        frames.astype(_dtype(cfg.dtype)),
        positions,
        causal=False,
        remat=remat,
    )
    return L.rmsnorm_apply(params["encoder"]["final_norm"], x)


def forward(
    cfg: ModelConfig, params: dict, batch: dict, remat: str = "full"
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits, moe_aux_loss).

    batch: {"tokens": (b,s) int32} for decoder-only;
           {"frames": (b,s_enc,d), "tokens": (b,s) int32} for enc-dec.
    """
    adt = _dtype(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, batch["frames"], remat=remat)
    x = shard_hints.constrain(L.embed_apply(params["embed"], tokens, adt), "activation")
    x, aux = T.stack_forward(
        params["blocks"], cfg, x, positions, causal=True, enc_out=enc_out, remat=remat
    )
    x = L.rmsnorm_apply(params["final_norm"], x)
    logits = L.lm_head_apply(params.get("lm_head"), params["embed"], x)
    logits = shard_hints.constrain(logits, "logits")
    return logits, aux


def loss_fn(
    cfg: ModelConfig, params: dict, batch: dict, remat: str = "full",
    aux_weight: float = 0.01,
) -> tuple[jax.Array, dict]:
    logits, aux = forward(cfg, params, batch, remat=remat)
    tokens = batch["tokens"]
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - tgt_logit).mean()
    loss = nll + aux_weight * aux
    return loss, {"nll": nll, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return kvcache.init_cache(cfg, batch, max_seq, dtype)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: kvcache.init_cache(cfg, batch, max_seq, dtype))


def prefill(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    cache_dtype=jnp.bfloat16,
    max_seq: int = 0,
):
    """Process the prompt; returns (last_logits, cache, cross_kv).

    ``max_seq``: total decode horizon — the cache is sized for it.
    """
    adt = _dtype(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_out = None
    cross_kv = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, params, batch["frames"])
        cross_kv = _build_cross_kv(cfg, params, enc_out)
    x = shard_hints.constrain(L.embed_apply(params["embed"], tokens, adt), "activation")
    x, collected = T.stack_prefill(params["blocks"], cfg, x, positions, enc_out=enc_out)
    x = L.rmsnorm_apply(params["final_norm"], x[:, -1:])
    logits = L.lm_head_apply(params.get("lm_head"), params["embed"], x)
    cache = kvcache.cache_from_prefill(cfg, collected, cache_dtype, max_seq=max_seq)
    return logits, cache, cross_kv


def prefill_chunked(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    chunk_len: int,
    max_seq: int = 0,
    cache_dtype=jnp.float32,
):
    """Chunked prefill (beyond-paper serving feature, EXPERIMENTS §Perf
    cell C): process the prompt ``chunk_len`` tokens at a time against the
    growing KV/SSD cache, bounding activation memory to O(chunk·context)
    instead of the O(s²) scores of whole-prompt prefill. Decoder-only archs.

    Returns (last_logits, cache) — same contract as :func:`prefill`.
    """
    assert cfg.family != "encdec", "chunked prefill is decoder-only"
    adt = _dtype(cfg.dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    assert s % chunk_len == 0, (s, chunk_len)
    horizon = max(max_seq, s)
    cache = kvcache.init_cache(cfg, b, horizon, cache_dtype)
    x_last = None
    for i in range(s // chunk_len):
        pos0 = i * chunk_len
        chunk = jax.lax.dynamic_slice_in_dim(tokens, pos0, chunk_len, axis=1)
        x = L.embed_apply(params["embed"], chunk, adt)
        x, cache = T.stack_chunk(params["blocks"], cfg, x, cache, pos0)
        x_last = x
    h = L.rmsnorm_apply(params["final_norm"], x_last[:, -1:])
    logits = L.lm_head_apply(params.get("lm_head"), params["embed"], h)
    return logits, cache


def _build_cross_kv(cfg: ModelConfig, params, enc_out):
    from repro.models import attention as attn_mod

    prog = T.block_program(cfg)
    out = {}
    for j in range(len(prog)):
        bp = params["blocks"][f"pos{j}"]

        def per_layer(cross_params):
            k, v = attn_mod.cross_attn_kv(cross_params, cfg, enc_out)
            return {"k": k, "v": v}

        out[f"pos{j}"] = jax.vmap(per_layer)(bp["cross"])
    return out


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,  # (b, 1) int32
    pos: jax.Array,  # scalar int32 — absolute position of the new token
    cross_kv: dict | None = None,
):
    """One serving step: returns (logits (b,1,V), new_cache)."""
    adt = _dtype(cfg.dtype)
    x = L.embed_apply(params["embed"], tokens, adt)
    x, new_cache = T.stack_decode(params["blocks"], cfg, x, cache, pos, cross_kv)
    x = L.rmsnorm_apply(params["final_norm"], x)
    logits = L.lm_head_apply(params.get("lm_head"), params["embed"], x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Input specs (dry-run stand-ins; ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: token batch (+ frames for the audio-frontend stub).
    decode: single-token batch + abstract KV/state cache at seq_len capacity.
    """
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": sds((b, s), jnp.int32)}
        if cfg.family == "encdec":
            specs["frames"] = sds((b, s, cfg.d_model), _dtype(cfg.dtype))
        return specs
    # decode: the cache is an input too
    specs = {
        "tokens": sds((b, 1), jnp.int32),
        "pos": sds((), jnp.int32),
        "cache": abstract_cache(cfg, b, s),
    }
    if cfg.family == "encdec":
        enc_len = min(s, 4096)
        specs["cross_kv"] = jax.eval_shape(
            lambda: kvcache.init_cross_kv(cfg, b, enc_len)
        )
    return specs
