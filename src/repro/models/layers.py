"""Primitive layers: norms, RoPE, dense MLPs, embeddings.

Parameters are plain dicts; every constructor returns ``(params, axes)``
where ``axes`` mirrors the param tree with tuples of *logical axis names*
(consumed by distribution.sharding and by the Abstract Resource View).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(rng, shape, dtype, in_axis=0):
    fan_in = shape[in_axis]
    scale = 1.0 / np.sqrt(fan_in)
    return jax.random.normal(rng, shape, dtype) * jnp.asarray(scale, dtype)


def _embed_init(rng, shape, dtype):
    return jax.random.normal(rng, shape, dtype) * jnp.asarray(0.02, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> tuple[dict, dict]:
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm_apply(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def head_rmsnorm_apply(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """qk-norm: normalize over the trailing head_dim."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    k1, k2, k3 = jax.random.split(rng, 3)
    d, f = cfg.d_model, cfg.d_ff
    params = {
        "wi_gate": _dense_init(k1, (d, f), dtype),
        "wi_up": _dense_init(k2, (d, f), dtype),
        "wo": _dense_init(k3, (f, d), dtype, in_axis=0),
    }
    axes = {
        "wi_gate": ("embed", "ffn"),
        "wi_up": ("embed", "ffn"),
        "wo": ("ffn", "embed"),
    }
    return params, axes


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def mlp_apply(params: dict, x: jax.Array, act: str) -> jax.Array:
    gate = _act(act, x @ params["wi_gate"].astype(x.dtype))
    up = x @ params["wi_up"].astype(x.dtype)
    return (gate * up) @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init(rng, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    params = {"tok": _embed_init(rng, (cfg.vocab_size, cfg.d_model), dtype)}
    axes = {"tok": ("vocab", "embed")}
    return params, axes


def embed_apply(params: dict, tokens: jax.Array, dtype) -> jax.Array:
    return params["tok"].astype(dtype)[tokens]


def lm_head_init(rng, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    params = {"w": _dense_init(rng, (cfg.d_model, cfg.vocab_size), dtype)}
    axes = {"w": ("embed", "vocab")}
    return params, axes


def lm_head_apply(params: dict | None, embed_params: dict, x: jax.Array) -> jax.Array:
    if params is None:  # tied embeddings
        return x @ embed_params["tok"].astype(x.dtype).T
    return x @ params["w"].astype(x.dtype)
