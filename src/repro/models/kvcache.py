"""Decode-time caches: KV ring buffers (full / sliding-window attention),
SSD recurrent states (Mamba-2), and cross-attention KV for enc-dec.

Cache capacity: full attention => ``max_seq``; sliding window => ``min(max_seq,
window)`` (ring buffer, see attention.attn_decode). Cache leaves are stacked
over ``n_periods`` (leading axis) so the decode scan threads them as xs/ys.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.transformer import block_program, n_periods


def cache_capacity(cfg: ModelConfig, max_seq: int) -> int:
    if cfg.sliding_window > 0:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Zero cache pytree (real or under jax.eval_shape for abstract)."""
    prog = block_program(cfg)
    np_ = n_periods(cfg)
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    T = cache_capacity(cfg, max_seq)
    cache = {}
    for j, (mixer, _) in enumerate(prog):
        if mixer == "attn":
            cache[f"pos{j}"] = {
                "k": jnp.zeros((np_, batch, T, kh, hd), dtype),
                "v": jnp.zeros((np_, batch, T, kh, hd), dtype),
            }
        else:
            i, h, n, conv_ch = ssm_mod.ssm_dims(cfg)
            cache[f"pos{j}"] = {
                "ssd": jnp.zeros((np_, batch, h, ssm_mod.SSM_HEAD_DIM, n), jnp.float32),
                "conv": jnp.zeros((np_, batch, ssm_mod.CONV_WIDTH - 1, conv_ch), jnp.float32),
            }
    return cache


def init_cross_kv(cfg: ModelConfig, batch: int, enc_len: int, dtype=jnp.bfloat16):
    if cfg.family != "encdec":
        return None
    np_ = n_periods(cfg)
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        f"pos{j}": {
            "k": jnp.zeros((np_, batch, enc_len, kh, hd), dtype),
            "v": jnp.zeros((np_, batch, enc_len, kh, hd), dtype),
        }
        for j in range(len(block_program(cfg)))
    }


def cache_from_prefill(
    cfg: ModelConfig, collected: dict, cache_dtype=jnp.bfloat16, max_seq: int = 0
):
    """Convert stack_prefill's collected KV/states into decode-cache layout.

    Collected attention KV has shape (np_, b, s, kh, hd); for sliding-window
    models only the trailing ``window`` positions are retained (ring-aligned:
    slot = pos % window, exact when s % window == 0). When ``max_seq`` (the
    decode horizon) exceeds the prompt length the cache is padded to
    ``cache_capacity(cfg, max_seq)`` so subsequent decode steps have slots.
    """
    prog = block_program(cfg)
    out = {}
    for j, (mixer, _) in enumerate(prog):
        c = collected[f"pos{j}"]
        if mixer == "attn":
            k, v = c["k"], c["v"]
            if cfg.sliding_window > 0 and k.shape[2] > cfg.sliding_window:
                w = cfg.sliding_window
                assert k.shape[2] % w == 0, "prefill len must be multiple of window"
                k, v = k[:, :, -w:], v[:, :, -w:]
            cap = cache_capacity(cfg, max(max_seq, k.shape[2]))
            if cap > k.shape[2]:
                pad = ((0, 0), (0, 0), (0, cap - k.shape[2]), (0, 0), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            out[f"pos{j}"] = {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)}
        else:
            out[f"pos{j}"] = c
    return out
