"""Trace-time activation-sharding hints (beyond-paper, DESIGN.md §8).

GSPMD propagates shardings from weights alone, which leaves several
pathologies in the baseline HLO (full logits all-gathers, replicated MoE
dispatch compute, FSDP param gathers on the decode path). A step builder
wraps its body in ``active({...})`` with NamedShardings; the model code
calls ``constrain(x, "logits")`` etc. at the annotated points. The
contextvar is thread-local, so concurrent shadow-world traces are safe;
with no active hints every call is a no-op (the paper-faithful baseline).
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional

import jax

_HINTS: contextvars.ContextVar[dict] = contextvars.ContextVar(
    "shard_hints", default={}
)

# annotated points (documented for the perf log):
#   activation      (b, s, d)       embedding output / residual stream
#   logits          (b, s, vocab)   pre-CE logits
#   attn_qkv        (b, s, h, hd)   q/k/v after head reshape
#   moe_expert_in   (e, b, c, d)    dispatched expert inputs
#   moe_expert_mid  (e, b, c, f)    expert hidden activations
#   moe_dispatch    (b, s, e, c)    dispatch/combine one-hots


@contextlib.contextmanager
def active(hints: Optional[dict]):
    tok = _HINTS.set(hints or {})
    try:
        yield
    finally:
        _HINTS.reset(tok)


def constrain(x: Any, name: str):
    h = _HINTS.get().get(name)
    if h is None:
        return x
    return jax.lax.with_sharding_constraint(x, h)


def make_train_hints(mesh, version: str) -> dict:
    """Pre-baked hint sets used by the §Perf iterations."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = batch_axes if batch_axes else None
    ns = lambda *spec: NamedSharding(mesh, P(*spec))
    if version == "v1":  # vocab-sharded logits + batch-sharded activations
        return {
            "activation": ns(bspec, None, None),
            "logits": ns(bspec, None, "model"),
        }
    if version == "v2":  # v1 + TP attention activations
        return {
            **make_train_hints(mesh, "v1"),
            "attn_qkv": ns(bspec, None, "model", None),
        }
    if version == "v3":  # v2 + expert-parallel MoE dispatch
        return {
            **make_train_hints(mesh, "v2"),
            "moe_expert_in": ns("model", bspec, None, None),
            "moe_expert_mid": ns("model", bspec, None, None),
            "moe_dispatch": ns(bspec, None, "model", None),
        }
    if version == "v4":  # v2 + sequence-parallel residual stream
        return {
            **make_train_hints(mesh, "v2"),
            "activation": ns(bspec, "model", None),
        }
    if version == "moe_only":
        return {
            "moe_expert_in": ns("model", bspec, None, None),
            "moe_expert_mid": ns("model", bspec, None, None),
            "moe_dispatch": ns(bspec, None, "model", None),
        }
    raise KeyError(version)
