"""Transformer assembly: block program, scan-over-layers, decode scan.

Layers are grouped into *periods* (the LCM of the architecture's interleave
periods) so that heterogeneous stacks — Jamba's 1-attention-per-8 hybrid with
MoE every 2nd layer, Mixtral's uniform MoE, Mamba-2's MLP-free blocks — all
scan over ``n_periods`` with per-position stacked parameters. This keeps the
lowered HLO size independent of depth (critical for dry-run compile times)
and gives pipeline parallelism a natural stage boundary.
"""

from __future__ import annotations

import math
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp_apply, mlp_init, rmsnorm_apply, rmsnorm_init


# ---------------------------------------------------------------------------
# Block program
# ---------------------------------------------------------------------------


def _unroll() -> bool:
    """Dry-run cost probes set REPRO_SCAN_UNROLL=1 so XLA's cost analysis
    (which counts a while body exactly once) sees true trip counts."""
    return os.environ.get("REPRO_SCAN_UNROLL") == "1"


def block_program(cfg: ModelConfig) -> list[tuple[str, str]]:
    """[(mixer, mlp)] per position within one period.

    mixer in {"attn", "ssm"}; mlp in {"dense", "moe", "none"}.
    """
    period = 1
    if cfg.family == "hybrid" and cfg.attn_period > 0:
        period = math.lcm(cfg.attn_period, cfg.moe_period if cfg.num_experts else 1)
    elif cfg.num_experts > 0 and cfg.moe_period > 1:
        period = cfg.moe_period
    assert cfg.num_layers % period == 0, (cfg.name, cfg.num_layers, period)
    prog = []
    for j in range(period):
        mixer = cfg.layer_kind(j)
        if cfg.d_ff == 0:
            mlp = "none"
        elif cfg.is_moe_layer(j):
            mlp = "moe"
        else:
            mlp = "dense"
        prog.append((mixer, mlp))
    return prog


def n_periods(cfg: ModelConfig) -> int:
    return cfg.num_layers // len(block_program(cfg))


# ---------------------------------------------------------------------------
# Single block init/apply
# ---------------------------------------------------------------------------


def _block_init(rng, cfg: ModelConfig, mixer: str, mlp: str, dtype, cross: bool):
    keys = jax.random.split(rng, 4)
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["ln1"], axes["ln1"] = rmsnorm_init(cfg.d_model, dtype)
    if mixer == "attn":
        params["mixer"], axes["mixer"] = attn.attn_init(keys[0], cfg, dtype)
    else:
        params["mixer"], axes["mixer"] = ssm_mod.ssm_init(keys[0], cfg, dtype)
    if cross:
        params["ln_x"], axes["ln_x"] = rmsnorm_init(cfg.d_model, dtype)
        params["cross"], axes["cross"] = attn.attn_init(keys[2], cfg, dtype, cross=True)
    if mlp != "none":
        params["ln2"], axes["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        if mlp == "moe":
            params["mlp"], axes["mlp"] = moe_mod.moe_init(keys[1], cfg, dtype)
        else:
            params["mlp"], axes["mlp"] = mlp_init(keys[1], cfg, dtype)
    return params, axes


def _block_apply_full(
    bp: dict,
    cfg: ModelConfig,
    mixer: str,
    mlp: str,
    x: jax.Array,
    positions: jax.Array,
    causal: bool,
    enc_out: jax.Array | None = None,
    collect_kv: bool = False,
):
    """Returns (x, aux_loss, kv) — kv is (k, v) when collect_kv and attn."""
    aux = jnp.zeros((), jnp.float32)
    kv = None
    h = rmsnorm_apply(bp["ln1"], x)
    if mixer == "attn":
        if collect_kv:
            out, k, v = attn.attn_forward(
                bp["mixer"], cfg, h, positions, causal=causal, return_kv=True
            )
            kv = (k, v)
        else:
            out = attn.attn_forward(bp["mixer"], cfg, h, positions, causal=causal)
        h = out
    else:
        if collect_kv:
            h, state = ssm_mod.ssm_forward(bp["mixer"], cfg, h, return_state=True)
            kv = state
        else:
            h = ssm_mod.ssm_forward(bp["mixer"], cfg, h)
    x = x + h
    if enc_out is not None and "cross" in bp:
        h = rmsnorm_apply(bp["ln_x"], x)
        x = x + attn.cross_attn_forward(bp["cross"], cfg, h, enc_out)
    if mlp != "none":
        h = rmsnorm_apply(bp["ln2"], x)
        if mlp == "moe":
            aux = aux + moe_mod.moe_aux_loss(bp["mlp"], cfg, h)
            h = moe_mod.moe_apply(bp["mlp"], cfg, h)
        else:
            h = mlp_apply(bp["mlp"], h, cfg.act)
        x = x + h
    # re-anchor the residual stream's sharding each block: without this
    # GSPMD resolves the FSDP-sharded contraction dims by ALL-REDUCING
    # activation-sized partial sums (see EXPERIMENTS.md §Perf)
    from repro.models import shard_hints

    x = shard_hints.constrain(x, "activation")
    return x, aux, kv


# ---------------------------------------------------------------------------
# Stack init (stacked over n_periods) and forward scan
# ---------------------------------------------------------------------------


def stack_init(rng, cfg: ModelConfig, dtype, cross: bool = False):
    prog = block_program(cfg)
    np_ = n_periods(cfg)
    params, axes = {}, {}
    rngs = jax.random.split(rng, len(prog))
    for j, (mixer, mlp) in enumerate(prog):
        keys = jax.random.split(rngs[j], np_)
        stacked = jax.vmap(
            lambda k: _block_init(k, cfg, mixer, mlp, dtype, cross)[0]
        )(keys)
        _, ax = _block_init(rngs[j], cfg, mixer, mlp, dtype, cross)
        params[f"pos{j}"] = stacked
        axes[f"pos{j}"] = jax.tree_util.tree_map(
            lambda a: ("layers",) + a, ax, is_leaf=lambda a: isinstance(a, tuple)
        )
    return params, axes


def stack_forward(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    enc_out: jax.Array | None = None,
    remat: str = "full",
) -> tuple[jax.Array, jax.Array]:
    """Returns (x, total_moe_aux_loss)."""
    prog = block_program(cfg)

    def body(carry, period_params):
        h, aux = carry
        for j, (mixer, mlp) in enumerate(prog):
            h, a, _ = _block_apply_full(
                period_params[f"pos{j}"], cfg, mixer, mlp, h, positions, causal, enc_out
            )
            aux = aux + a
        return (h, aux), None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params, unroll=_unroll()
    )
    return x, aux


def stack_prefill(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    enc_out: jax.Array | None = None,
):
    """Forward pass that also materializes the decode cache.

    Returns (x, cache) where cache mirrors the per-position structure of
    :func:`repro.models.kvcache.init_cache` (stacked over n_periods).
    For sliding-window attention the collected KV is cropped to the ring
    window by the caller (kvcache.cache_from_prefill).
    """
    prog = block_program(cfg)

    def body(carry, period_params):
        h = carry
        ys = {}
        for j, (mixer, mlp) in enumerate(prog):
            h, _, kv = _block_apply_full(
                period_params[f"pos{j}"],
                cfg,
                mixer,
                mlp,
                h,
                positions,
                True,
                enc_out,
                collect_kv=True,
            )
            if mixer == "attn":
                ys[f"pos{j}"] = {"k": kv[0], "v": kv[1]}
            else:
                ys[f"pos{j}"] = kv  # ssm state dict
        return h, ys

    x, cache = jax.lax.scan(body, x, params, unroll=_unroll())
    return x, cache


def stack_chunk(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (b, C, d) — prompt chunk
    cache: dict,
    pos0: int,  # static
):
    """Chunked-prefill step: like stack_decode but for C tokens at once —
    bounds prefill activation memory to O(C·context) instead of O(s²)
    (decoder-only archs; see model.prefill_chunked)."""
    prog = block_program(cfg)

    def body(carry, xs):
        h = carry
        period_params, period_cache = xs
        new_cache = {}
        for j, (mixer, mlp) in enumerate(prog):
            bp = period_params[f"pos{j}"]
            c = period_cache[f"pos{j}"]
            hin = rmsnorm_apply(bp["ln1"], h)
            if mixer == "attn":
                out, ck, cv = attn.attn_chunk(
                    bp["mixer"], cfg, hin, c["k"], c["v"], pos0
                )
                new_cache[f"pos{j}"] = {"k": ck, "v": cv}
            else:
                out, st = ssm_mod.ssm_forward(
                    bp["mixer"], cfg, hin, return_state=True, init_state=c
                )
                new_cache[f"pos{j}"] = {
                    "ssd": st["ssd"].astype(c["ssd"].dtype),
                    "conv": st["conv"].astype(c["conv"].dtype),
                }
            h = h + out
            if mlp != "none":
                h2 = rmsnorm_apply(bp["ln2"], h)
                if mlp == "moe":
                    h2 = moe_mod.moe_apply(bp["mlp"], cfg, h2)
                else:
                    h2 = mlp_apply(bp["mlp"], h2, cfg.act)
                h = h + h2
        return h, new_cache

    x, new_cache = jax.lax.scan(body, x, (params, cache), unroll=_unroll())
    return x, new_cache


# ---------------------------------------------------------------------------
# Decode scan (cache threaded as scan xs/ys)
# ---------------------------------------------------------------------------


def stack_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (b, 1, d)
    cache: dict,  # per-position stacked caches
    pos: jax.Array,  # scalar int32
    cross_kv: dict | None = None,  # per-position stacked (k, v) for enc-dec
):
    prog = block_program(cfg)

    def body(carry, xs):
        h = carry
        period_params, period_cache, period_cross = xs
        new_cache = {}
        for j, (mixer, mlp) in enumerate(prog):
            bp = period_params[f"pos{j}"]
            c = period_cache[f"pos{j}"]
            hin = rmsnorm_apply(bp["ln1"], h)
            if mixer == "attn":
                out, ck, cv = attn.attn_decode(bp["mixer"], cfg, hin, c["k"], c["v"], pos)
                new_cache[f"pos{j}"] = {"k": ck, "v": cv}
            else:
                out, st = ssm_mod.ssm_decode(bp["mixer"], cfg, hin, c)
                new_cache[f"pos{j}"] = st
            h = h + out
            if period_cross is not None and "cross" in bp:
                hx = rmsnorm_apply(bp["ln_x"], h)
                kv = (period_cross[f"pos{j}"]["k"], period_cross[f"pos{j}"]["v"])
                h = h + attn.cross_attn_forward(bp["cross"], cfg, hx, kv)
            if mlp != "none":
                h2 = rmsnorm_apply(bp["ln2"], h)
                if mlp == "moe":
                    h2 = moe_mod.moe_apply(bp["mlp"], cfg, h2)
                else:
                    h2 = mlp_apply(bp["mlp"], h2, cfg.act)
                h = h + h2
        return h, new_cache

    xs = (params, cache, cross_kv)
    x, new_cache = jax.lax.scan(body, x, xs, unroll=_unroll())
    return x, new_cache
