from repro.models.model import (
    init_params,
    abstract_params,
    param_logical_axes,
    forward,
    loss_fn,
    init_cache,
    abstract_cache,
    decode_step,
    input_specs,
    analytic_param_count,
)

__all__ = [
    "init_params",
    "abstract_params",
    "param_logical_axes",
    "forward",
    "loss_fn",
    "init_cache",
    "abstract_cache",
    "decode_step",
    "input_specs",
    "analytic_param_count",
]
