"""Mamba-2 SSD (state-space duality) mixer.

Used by ``mamba2-2.7b`` (every layer) and ``jamba-v0.1-52b`` (7 of 8 layers).
Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk state recurrence); decode uses the O(1) recurrent update.
The intra-chunk matmuls route through the Pallas ``ssd_scan`` kernel on TPU
(pure-jnp reference elsewhere) via ``kernels.ops``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init

SSM_HEAD_DIM = 64
CONV_WIDTH = 4


def ssm_dims(cfg: ModelConfig) -> tuple[int, int, int, int]:
    """(d_inner, nheads, d_state, conv_channels)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // SSM_HEAD_DIM
    d_state = cfg.ssm_state
    conv_ch = d_inner + 2 * d_state
    return d_inner, nheads, d_state, conv_ch


def ssm_init(rng, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    d = cfg.d_model
    i, h, n, conv_ch = ssm_dims(cfg)
    keys = jax.random.split(rng, 8)
    params = {
        "wz": _dense_init(keys[0], (d, i), dtype),
        "wx": _dense_init(keys[1], (d, i), dtype),
        "wB": _dense_init(keys[2], (d, n), dtype),
        "wC": _dense_init(keys[3], (d, n), dtype),
        "wdt": _dense_init(keys[4], (d, h), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((h,), 0.01, jnp.float32))).astype(dtype),
        "A_log": jnp.log(
            jax.random.uniform(keys[5], (h,), jnp.float32, 1.0, 16.0)
        ).astype(dtype),
        "D": jnp.ones((h,), dtype),
        "conv_w": _dense_init(keys[6], (CONV_WIDTH, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm_scale": jnp.ones((i,), dtype),
        "wo": _dense_init(keys[7], (i, d), dtype),
    }
    axes = {
        "wz": ("embed", "inner"),
        "wx": ("embed", "inner"),
        "wB": ("embed", "state"),
        "wC": ("embed", "state"),
        "wdt": ("embed", "ssm_heads"),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "conv_w": ("conv_k", "inner"),
        "conv_b": ("inner",),
        "norm_scale": ("inner",),
        "wo": ("inner", "embed"),
    }
    return params, axes


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, xbc: (b, s, c), w: (K, c)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for t in range(K):
        out = out + pad[:, t : t + xbc.shape[1], :].astype(jnp.float32) * w[t].astype(
            jnp.float32
        )
    return (out + b.astype(jnp.float32)).astype(xbc.dtype)


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array, eps=1e-6) -> jax.Array:
    yf = (y * jax.nn.silu(z.astype(jnp.float32))).astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _pre_ssd(params, cfg, x):
    """Shared projections+conv for forward/decode. x: (b,s,d)."""
    z = x @ params["wz"].astype(x.dtype)
    xi = x @ params["wx"].astype(x.dtype)
    Bssm = x @ params["wB"].astype(x.dtype)
    Cssm = x @ params["wC"].astype(x.dtype)
    dt_raw = x @ params["wdt"].astype(x.dtype)
    xbc = jnp.concatenate([xi, Bssm, Cssm], axis=-1)
    return z, xbc, dt_raw


def _post_conv_split(cfg, xbc):
    i, h, n, _ = ssm_dims(cfg)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(xbc.dtype)
    xi, Bssm, Cssm = jnp.split(xbc, [i, i + n], axis=-1)
    return xi, Bssm, Cssm


def ssm_forward(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    return_state: bool = False,
    init_state: dict | None = None,
):
    """Full-sequence SSD forward. x: (b, s, d) -> (b, s, d).

    ``init_state`` ({"ssd", "conv"}) continues from a previous chunk
    (chunked prefill): the conv uses the cached raw history instead of zero
    padding and the SSD recurrence starts from the carried state.
    """
    from repro.kernels import ops

    b, s, d = x.shape
    i, h, n, _ = ssm_dims(cfg)
    p = SSM_HEAD_DIM
    z, xbc_raw, dt_raw = _pre_ssd(params, cfg, x)
    if init_state is not None:
        hist = init_state["conv"].astype(xbc_raw.dtype)
        full = jnp.concatenate([hist, xbc_raw], axis=1)
        xbc = _causal_conv(full, params["conv_w"], params["conv_b"])[
            :, CONV_WIDTH - 1 :
        ]
        xbc_hist_src = full
    else:
        xbc = _causal_conv(xbc_raw, params["conv_w"], params["conv_b"])
        xbc_hist_src = xbc_raw
    xi, Bssm, Cssm = _post_conv_split(cfg, xbc)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (b,s,h)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (h,)
    xh = xi.reshape(b, s, h, p)
    y, final_state = ops.ssd_scan(
        xh,
        dt,
        A,
        Bssm.astype(jnp.float32),
        Cssm.astype(jnp.float32),
        cfg.ssm_chunk,
        init_state=init_state["ssd"].astype(jnp.float32) if init_state else None,
    )
    y = y + xh.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, i).astype(x.dtype)
    y = _gated_norm(y, z, params["norm_scale"])
    out = y @ params["wo"].astype(x.dtype)
    if return_state:
        state = {
            "ssd": final_state,
            "conv": xbc_hist_src[:, -(CONV_WIDTH - 1) :, :].astype(jnp.float32),
        }
        return out, state
    return out


# ---------------------------------------------------------------------------
# Decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------


def ssm_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    i, h, n, conv_ch = ssm_dims(cfg)
    return {
        "ssd": jnp.zeros((batch, h, SSM_HEAD_DIM, n), dtype),
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, conv_ch), dtype),
    }


def ssm_decode(params: dict, cfg: ModelConfig, x: jax.Array, state: dict):
    """x: (b, 1, d). Returns (y, new_state)."""
    b = x.shape[0]
    i, h, n, conv_ch = ssm_dims(cfg)
    p = SSM_HEAD_DIM
    z, xbc, dt_raw = _pre_ssd(params, cfg, x)  # (b,1,*)
    # conv with cached history
    hist = jnp.concatenate([state["conv"], xbc.astype(state["conv"].dtype)], axis=1)
    w = params["conv_w"].astype(jnp.float32)
    conv_out = (hist.astype(jnp.float32) * w[None]).sum(axis=1, keepdims=True) + params[
        "conv_b"
    ].astype(jnp.float32)
    xi, Bssm, Cssm = _post_conv_split(cfg, conv_out.astype(x.dtype))
    new_conv = hist[:, 1:, :]

    dt = jax.nn.softplus(
        dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (b,h)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A[None, :])  # (b,h)
    xh = xi[:, 0].reshape(b, h, p).astype(jnp.float32)
    Bv = Bssm[:, 0].astype(jnp.float32)  # (b,n)
    Cv = Cssm[:, 0].astype(jnp.float32)
    ssd = state["ssd"].astype(jnp.float32)
    ssd = decay[:, :, None, None] * ssd + (dt[:, :, None, None] * xh[..., None]) * Bv[
        :, None, None, :
    ]
    y = jnp.einsum("bhpn,bn->bhp", ssd, Cv) + xh * params["D"].astype(jnp.float32)[
        None, :, None
    ]
    y = y.reshape(b, 1, i).astype(x.dtype)
    y = _gated_norm(y, z, params["norm_scale"])
    out = y @ params["wo"].astype(x.dtype)
    return out, {"ssd": ssd.astype(state["ssd"].dtype), "conv": new_conv}
