"""Mixture-of-Experts MLP with capacity-based GShard-style dispatch.

Expert weights are stacked on a leading ``expert`` logical axis so they are
(a) shardable over a mesh axis (EP) and (b) first-class tensors in the
Abstract Resource View — EP reshaping (App. A.2.3 of the paper) migrates
slices of these tensors like any other.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import shard_hints
from repro.models.layers import _dense_init, _act, mlp_init, mlp_apply


def moe_init(rng, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    keys = jax.random.split(rng, 5)
    params = {
        "router": _dense_init(keys[0], (d, e), dtype),
        "wi_gate": _dense_init(keys[1], (e, d, f), dtype, in_axis=1),
        "wi_up": _dense_init(keys[2], (e, d, f), dtype, in_axis=1),
        "wo": _dense_init(keys[3], (e, f, d), dtype, in_axis=1),
    }
    axes = {
        "router": ("embed", "expert_in"),
        "wi_gate": ("expert", "embed", "ffn"),
        "wi_up": ("expert", "embed", "ffn"),
        "wo": ("expert", "ffn", "embed"),
    }
    if cfg.moe_shared_expert:
        sp, sa = mlp_init(keys[4], cfg, dtype)
        params["shared"] = sp
        axes["shared"] = sa
    return params, axes


def moe_apply(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (b, s, d)
) -> jax.Array:
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    capacity = max(1, int(cfg.moe_capacity_factor * k * s / e))

    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)  # (b,s,e)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (b,s,k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)  # (b,s,k,e)
    flat = onehot.reshape(b, s * k, e)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, k, e)
    pos = jnp.einsum("bske,bske->bsk", pos_in_expert, onehot)  # (b,s,k)
    keep = pos < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch/combine tensors (b, s, e, c)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), capacity, dtype=jnp.float32)
    dispatch = jnp.einsum("bske,bskc->bsec", onehot, pos_oh * keep[..., None])
    combine = jnp.einsum("bsk,bske,bskc->bsec", gate_vals, onehot, pos_oh)

    dispatch = shard_hints.constrain(dispatch, "moe_dispatch")
    combine = shard_hints.constrain(combine, "moe_dispatch")
    xin = jnp.einsum("bsec,bsd->ebcd", dispatch.astype(x.dtype), x)  # (e,b,c,d)
    xin = shard_hints.constrain(xin, "moe_expert_in")
    gate = _act(cfg.act, jnp.einsum("ebcd,edf->ebcf", xin, params["wi_gate"].astype(x.dtype)))
    up = jnp.einsum("ebcd,edf->ebcf", xin, params["wi_up"].astype(x.dtype))
    gate = shard_hints.constrain(gate, "moe_expert_mid")
    yout = jnp.einsum("ebcf,efd->ebcd", gate * up, params["wo"].astype(x.dtype))
    yout = shard_hints.constrain(yout, "moe_expert_in")
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), yout)

    if cfg.moe_shared_expert:
        y = y + mlp_apply(params["shared"], x, cfg.act)
    return y


def moe_aux_loss(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (b,s,e)
    e = cfg.num_experts
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return e * jnp.sum(frac_tokens * frac_probs)
