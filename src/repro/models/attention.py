"""GQA/MQA/MHA attention with RoPE, qk-norm, QKV bias and sliding windows.

Weights are stored 2-D flattened ``(d_model, heads*head_dim)`` so tensor-
parallel sharding over the fused head dimension is always divisible on the
production mesh (see DESIGN.md §4). The forward path optionally routes the
core attention product through the Pallas flash-attention kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import shard_hints
from repro.models.layers import _dense_init, apply_rope, head_rmsnorm_apply


def attn_init(rng, cfg: ModelConfig, dtype, cross: bool = False) -> tuple[dict, dict]:
    d = cfg.d_model
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    keys = jax.random.split(rng, 4)
    params = {
        "wq": _dense_init(keys[0], (d, h * hd), dtype),
        "wk": _dense_init(keys[1], (d, k * hd), dtype),
        "wv": _dense_init(keys[2], (d, k * hd), dtype),
        "wo": _dense_init(keys[3], (h * hd, d), dtype),
    }
    axes = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias and not cross:
        params.update(
            bq=jnp.zeros((h * hd,), dtype),
            bk=jnp.zeros((k * hd,), dtype),
            bv=jnp.zeros((k * hd,), dtype),
        )
        axes.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    if cfg.qk_norm and not cross:
        params.update(q_norm=jnp.ones((hd,), dtype), k_norm=jnp.ones((hd,), dtype))
        axes.update(q_norm=("head_dim",), k_norm=("head_dim",))
    return params, axes


def _project_qkv(params, cfg: ModelConfig, x, positions, rope: bool = True):
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ params["wq"].astype(x.dtype)
    kk = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias and "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        kk = kk + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, hd)
    kk = kk.reshape(b, s, k, hd)
    v = v.reshape(b, s, k, hd)
    if cfg.qk_norm and "q_norm" in params:
        q = head_rmsnorm_apply(params["q_norm"], q)
        kk = head_rmsnorm_apply(params["k_norm"], kk)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kk = apply_rope(kk, positions, cfg.rope_theta)
    q = shard_hints.constrain(q, "attn_qkv")
    return q, kk, v


def _sdpa(q, k, v, mask, scale):
    """Reference scaled-dot-product attention; q:(b,s,h,d) k/v:(b,t,kh,d)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    rep = h // kh
    qf = q.astype(jnp.float32) * scale
    scores = jnp.einsum(
        "bshd,bthd->bhst",
        qf,
        jnp.repeat(k.astype(jnp.float32), rep, axis=2),
    )
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, jnp.repeat(v.astype(jnp.float32), rep, axis=2))
    return out.astype(q.dtype)


def _causal_mask(s: int, t: int, window: int, q_offset: int = 0) -> jax.Array:
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    return mask[None, None]  # (1,1,s,t)


def attn_forward(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    causal: bool = True,
    use_kernel: bool = True,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x, positions)
    scale = hd**-0.5
    if use_kernel:
        from repro.kernels import ops

        out = ops.flash_attention(
            q, k, v, causal=causal, window=cfg.sliding_window, scale=scale
        )
    else:
        if causal:
            mask = _causal_mask(s, s, cfg.sliding_window)
        else:
            mask = jnp.ones((1, 1, s, s), bool)
        out = _sdpa(q, k, v, mask, scale)
    out = out.reshape(b, s, h * hd)
    out = out @ params["wo"].astype(x.dtype)
    if return_kv:
        return out, k, v
    return out


def cross_attn_forward(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    kv_src: jax.Array | tuple[jax.Array, jax.Array],
) -> jax.Array:
    """Cross attention (enc-dec). ``kv_src`` is the encoder output (prefill)
    or a precomputed (k, v) cache tuple (decode)."""
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    if isinstance(kv_src, tuple):
        k, v = kv_src
    else:
        k, v = cross_attn_kv(params, cfg, kv_src)
    t = k.shape[1]
    mask = jnp.ones((1, 1, s, t), bool)
    out = _sdpa(q, k, v, mask, hd**-0.5).reshape(b, s, h * hd)
    return out @ params["wo"].astype(x.dtype)


def cross_attn_kv(params: dict, cfg: ModelConfig, enc_out: jax.Array):
    kh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    b, t, _ = enc_out.shape
    k = (enc_out @ params["wk"].astype(enc_out.dtype)).reshape(b, t, kh, hd)
    v = (enc_out @ params["wv"].astype(enc_out.dtype)).reshape(b, t, kh, hd)
    return k, v


def attn_chunk(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (b, C, d) — chunk of the prompt
    cache_k: jax.Array,  # (b, T, kh, hd)
    cache_v: jax.Array,
    pos0: int,  # static: absolute position of the chunk's first token
):
    """Chunked-prefill attention: write the chunk's K/V into the cache and
    attend its queries against everything cached so far (ring-aware for
    sliding windows). Returns (out, new_k, new_v)."""
    b, C, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    T = cache_k.shape[1]
    positions = jnp.broadcast_to(
        pos0 + jnp.arange(C, dtype=jnp.int32), (b, C)
    )
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    qpos = pos0 + jnp.arange(C)
    ring = bool(cfg.sliding_window) and T < pos0 + C

    if ring:
        # Writing the chunk would evict ring entries the chunk's EARLY
        # queries still need (q at pos0 wants window ending at pos0, the
        # write installs up to pos0+C-1). Attend against the pre-write ring
        # ⊕ the fresh chunk, then commit the write.
        assert C <= T and T % C == 0, (C, T)
        idx = jnp.arange(T)
        prev = pos0 - 1
        abs_cache = prev - ((prev - idx) % T)  # ring contents BEFORE write
        k_ext = jnp.concatenate([cache_k.astype(q.dtype), k_new], axis=1)
        v_ext = jnp.concatenate([cache_v.astype(q.dtype), v_new], axis=1)
        abs_ext = jnp.concatenate([abs_cache, qpos])
        mask = (abs_ext[None, :] <= qpos[:, None]) & (abs_ext[None, :] >= 0)
        mask &= abs_ext[None, :] > qpos[:, None] - cfg.sliding_window
        out = _sdpa(q, k_ext, v_ext, mask[None, None], hd**-0.5)
        slot = pos0 % T
    else:
        slot = pos0
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k_new.astype(cache_k.dtype), slot, axis=1
    )
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v_new.astype(cache_v.dtype), slot, axis=1
    )
    if not ring:
        abs_pos = jnp.arange(T)
        mask = (abs_pos[None, :] <= qpos[:, None]) & (abs_pos[None, :] >= 0)
        if cfg.sliding_window:
            mask &= abs_pos[None, :] > qpos[:, None] - cfg.sliding_window
        out = _sdpa(
            q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
            mask[None, None], hd**-0.5,
        )
    out = out.reshape(b, C, h * hd)
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v


# ---------------------------------------------------------------------------
# Decode path (single new token, KV cache)
# ---------------------------------------------------------------------------


def attn_decode(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,  # (b, 1, d)
    cache_k: jax.Array,  # (b, T, kh, hd)  T = cache capacity
    cache_v: jax.Array,
    pos: jax.Array,  # scalar int32: index of the new token
):
    """One decode step. Returns (out, new_k, new_v).

    For sliding-window models the cache is a ring buffer of capacity
    ``min(seq, window)``; positions are stored modulo capacity and masking
    uses absolute positions tracked via ``pos``.
    """
    b = x.shape[0]
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    T = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions)
    slot = pos % T if cfg.sliding_window else pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), slot, axis=1)

    # absolute position of every cache slot
    idx = jnp.arange(T)
    if cfg.sliding_window:
        # slot i holds absolute position: the latest p <= pos with p % T == i
        abs_pos = pos - ((pos - idx) % T)
    else:
        abs_pos = idx
    valid = (abs_pos <= pos) & (abs_pos >= 0)
    if cfg.sliding_window:
        valid &= abs_pos > pos - cfg.sliding_window
    mask = valid[None, None, None, :]  # (1,1,1,T)

    from repro.kernels import ops

    out = ops.decode_attention(
        q, cache_k.astype(q.dtype), cache_v.astype(q.dtype), mask, hd**-0.5
    )
    out = out.reshape(b, 1, h * hd)
    return out @ params["wo"].astype(x.dtype), cache_k, cache_v
