"""Pytree helpers shared across the framework (DESIGN.md §2).

We use plain nested dicts of jnp arrays as parameter containers (no flax).
Leaf naming follows ``a/b/c`` path strings derived from jax.tree_util key
paths; these names are the identities used by the Abstract Resource View
(paper §4.6.1), the checkpoint manifests and the sharding rules.
"""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import numpy as np


def path_str(key_path) -> str:
    """Render a jax.tree_util key path as 'a/b/c'."""
    parts = []
    for k in key_path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        else:  # pragma: no cover - future key kinds
            parts.append(str(k))
    return "/".join(parts)


def tree_paths(tree: Any, is_leaf=None) -> dict[str, Any]:
    """Flatten a pytree into {path_string: leaf}."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    return {path_str(kp): leaf for kp, leaf in flat}


def axes_paths(axes_tree: Any) -> dict[str, tuple]:
    """Flatten a logical-axes tree (tuple leaves) into {path: axes tuple}."""
    return tree_paths(axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def tree_from_paths(paths: dict[str, Any], like: Any) -> Any:
    """Rebuild a pytree with the same structure as ``like`` from a path map."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = [paths[path_str(kp)] for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: fn(path_str(kp), leaf), tree
    )


def _leaf_size_bytes(leaf: Any) -> int:
    shape = getattr(leaf, "shape", ())
    dtype = getattr(leaf, "dtype", np.dtype("float32"))
    return int(math.prod(shape)) * np.dtype(dtype).itemsize


def tree_bytes(tree: Any) -> int:
    return sum(_leaf_size_bytes(l) for l in jax.tree_util.tree_leaves(tree))


def tree_param_count(tree: Any) -> int:
    return sum(
        int(math.prod(getattr(l, "shape", ()))) for l in jax.tree_util.tree_leaves(tree)
    )
