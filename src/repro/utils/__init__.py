from repro.utils.pytree import (
    axes_paths,
    tree_paths,
    tree_bytes,
    tree_param_count,
    path_str,
)
from repro.utils.timing import Timer, now

__all__ = [
    "axes_paths",
    "tree_paths",
    "tree_bytes",
    "tree_param_count",
    "path_str",
    "Timer",
    "now",
]
