"""Wall-clock timing utilities used by benchmarks and the controller —
the measurement substrate behind the paper's §6 latency breakdowns
(``ReconfigRecord`` phase timings, Figs. 6a–6d)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field


def now() -> float:
    return time.perf_counter()


@dataclass
class Timer:
    """Accumulating named-phase timer.

    >>> t = Timer()
    >>> with t.phase("compile"): ...
    >>> t.totals["compile"]
    """

    totals: dict[str, float] = field(default_factory=dict)
    counts: dict[str, int] = field(default_factory=dict)

    class _Phase:
        def __init__(self, timer: "Timer", name: str):
            self.timer, self.name = timer, name

        def __enter__(self):
            self.t0 = now()
            return self

        def __exit__(self, *exc):
            dt = now() - self.t0
            self.timer.totals[self.name] = self.timer.totals.get(self.name, 0.0) + dt
            self.timer.counts[self.name] = self.timer.counts.get(self.name, 0) + 1
            return False

    def phase(self, name: str) -> "Timer._Phase":
        return Timer._Phase(self, name)

    def report(self) -> str:
        lines = []
        for k in sorted(self.totals):
            lines.append(f"{k:<32s} {self.totals[k]*1e3:10.2f} ms  x{self.counts[k]}")
        return "\n".join(lines)
