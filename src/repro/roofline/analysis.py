"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are NOT in cost_analysis — we parse the post-partitioning optimized
HLO (``compiled.as_text()``) and sum the *result shapes* of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# e.g.  "%all-reduce.42 = f32[512,1024]{1,0} all-reduce(...)"
#       "... = (f32[8,128]{1,0}, f32[8,128]{1,0}) all-to-all(...)"
_OP_LINE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+([a-z\-]+)\("
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Per-op-kind wire-byte totals, per chip.

    Shapes in partitioned HLO are per-participant, so result-shape bytes are
    already per-chip. Wire-cost weights (ring-algorithm approximations):
      all-gather          ≈ 1× result bytes   ((n-1)/n ≈ 1)
      all-reduce          ≈ 2× result bytes   (reduce-scatter + all-gather)
      reduce-scatter      ≈ 1× operand bytes  (parsed from the call args)
      all-to-all          ≈ 1× result bytes
      collective-permute  = 1× result bytes
    """
    out: dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_LINE.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        if op not in out:
            continue
        if op == "reduce-scatter":
            # operand shapes appear inside the call parens
            paren = line[line.index(op) + len(op):]
            out[op] += _shape_bytes(paren)
        elif op == "all-reduce":
            out[op] += 2 * _shape_bytes(shape_txt)
        else:
            out[op] += _shape_bytes(shape_txt)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: int
    per_collective: dict = field(default_factory=dict)
    model_flops: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # global wire bytes over aggregate link bandwidth (assignment form)
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the dominant term allows, for *useful* FLOPs:
        model_flops_time / max(term)s."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS_BF16)
        worst = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / worst if worst else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "per_collective": self.per_collective,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(
    arch: str,
    shape: str,
    mesh_desc: str,
    chips: int,
    cost_analysis: dict,
    hlo_text: str,
    model_flops: float,
) -> RooflineReport:
    per = collective_bytes_from_hlo(hlo_text)
    flops = float(cost_analysis.get("flops", 0.0))
    nbytes = float(cost_analysis.get("bytes accessed", 0.0))
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=nbytes,
        collective_bytes=int(sum(per.values())),
        per_collective=per,
        model_flops=model_flops,
    )


# ---------------------------------------------------------------------------
# Analytic scaling curves (fleet arbitration value function, DESIGN.md §18)
# ---------------------------------------------------------------------------

# At the calibration point the collective term is a fixed fraction of the
# step — the paper's measured DP overhead at full scale (§6.3's sub-1%
# steady overhead excludes gradient sync; profile-level splits on the
# testbed put allreduce near 15% of the iteration).
_COLLECTIVE_FRACTION = 0.15


def analytic_step_time(
    params: float, world: int, cluster, ref_world: int = 32
) -> float:
    """Roofline-style step-time decomposition for a ``params``-sized job
    on ``world`` devices of ``cluster`` (a ``sim.cluster.ClusterModel``).

    The calibrated ``cluster.step_time_s`` at ``ref_world`` anchors the
    magnitude; the split follows the roofline terms above: the compute
    term shards perfectly (∝ 1/world) while the data-parallel gradient
    all-reduce follows the ring cost ∝ (world-1)/world — asymptotically
    FLAT in world. Throughput per device therefore *falls* as a job
    grows, which is the concavity the fleet arbiter's marginal-value
    function needs: past the knee, the next device is worth more to a
    smaller job.
    """
    if world <= 0:
        return float("inf")
    base = cluster.step_time_s(params, ref_world, ref_world=ref_world)
    comp_1dev = base * (1.0 - _COLLECTIVE_FRACTION) * ref_world
    ring_coeff = base * _COLLECTIVE_FRACTION * ref_world / (ref_world - 1)
    return comp_1dev / world + ring_coeff * (world - 1) / world


def analytic_throughput(
    params: float,
    world: int,
    cluster,
    global_batch: int,
    ref_world: int = 32,
) -> float:
    """Samples/second of the job at ``world`` devices (0 when idle)."""
    if world <= 0:
        return 0.0
    return global_batch / analytic_step_time(params, world, cluster, ref_world)


def marginal_throughput(
    params: float,
    world: int,
    cluster,
    global_batch: int,
    delta: int = 1,
    ref_world: int = 32,
) -> float:
    """Samples/second per *additional device* for growing ``world`` by
    ``delta`` — the fleet arbiter's value function (strictly decreasing
    in ``world`` under the ring model above)."""
    if delta <= 0:
        return 0.0
    lo = analytic_throughput(params, world, cluster, global_batch, ref_world)
    hi = analytic_throughput(
        params, world + delta, cluster, global_batch, ref_world
    )
    return (hi - lo) / delta
