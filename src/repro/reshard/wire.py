"""Per-kind wire policy for the compressed reshard data plane.

A :class:`WirePolicy` decides, per state collection, which on-the-wire
format a transfer task's bytes travel in: lossless (``"none"``), symmetric
int8, or fp8-e4m3 (kernels in ``repro.kernels.reshard_quant``). The policy
rides from :class:`~repro.core.intersection.TransferTask` through chunk
budgeting (:mod:`repro.reshard.chunking`), the engine's staging accounting,
and both executors, so every byte counter can report *wire* bytes (what
crossed the interconnect, payload + sidecar scales) next to *logical* bytes
(what the plan says moved).

Defaults follow the tolerance of each collection: optimizer moments
(``mu``/``nu``) quantize to int8 — after the delta planner they dominate
remaining plan bytes and Adam's moment estimates tolerate ~1/254 relative
rounding — while parameters stay lossless unless the caller opts into a
bounded-error format. The scalar ``step`` counter and the plan-less
``state`` collection are always lossless. A policy of ``None`` anywhere in
the data plane means fully lossless (the byte-oracle default): constructing
a ``WirePolicy()`` is the opt-in.

Only remote tasks ever consult the policy: resident cells move no bytes and
local cells relayout on-device without crossing a wire.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

# Mirrors repro.kernels.reshard_quant: both wire formats are 1-byte payloads
# with one float32 scale per tile (= per row in the executor's collapsed-2D
# streaming path). Kept as plain ints here so plan-time accounting never
# imports the kernel package.
WIRE_FORMATS = ("none", "int8", "fp8_e4m3")
QUANT_ITEMSIZE = 1
SIDECAR_BYTES_PER_TILE = 4


@dataclass(frozen=True)
class WirePolicy:
    """Per-collection wire formats for streamed remote bytes.

    ``moments`` applies to the ``mu``/``nu`` collections, ``params`` to
    ``params``; everything else (``step``, ``state``) is forced lossless.
    """

    moments: str = "int8"
    params: str = "none"

    def __post_init__(self):
        for fmt in (self.moments, self.params):
            if fmt not in WIRE_FORMATS:
                raise ValueError(
                    f"unknown wire format {fmt!r}; expected one of {WIRE_FORMATS}"
                )

    def format_for(self, collection: str) -> str:
        if collection in ("mu", "nu"):
            return self.moments
        if collection == "params":
            return self.params
        return "none"

    # -- byte accounting ----------------------------------------------------

    def wire_row_bytes(self, collection: str, row_elems: int, raw_row_bytes: int) -> int:
        """Wire bytes for one row (= one tile) of a remote task."""
        if self.format_for(collection) == "none":
            return raw_row_bytes
        return row_elems * QUANT_ITEMSIZE + SIDECAR_BYTES_PER_TILE

    def wire_nbytes(self, task) -> int:
        """Wire bytes for a whole remote task (payload + sidecar scales).

        Logical bytes for lossless collections; for quantized ones, one
        byte per element plus one sidecar scale per leading-dim row. Scalar
        (rank-0) tasks count as a single tile.
        """
        if self.format_for(task.collection) == "none":
            return task.nbytes
        shape = task.shape()
        elems = math.prod(shape) if shape else 1
        rows = shape[0] if shape else 1
        return elems * QUANT_ITEMSIZE + rows * SIDECAR_BYTES_PER_TILE


def wire_nbytes(policy: "WirePolicy | None", task) -> int:
    """Wire bytes under ``policy`` (``None`` = fully lossless)."""
    if policy is None or getattr(task, "kind", "remote") != "remote":
        return task.nbytes
    return policy.wire_nbytes(task)
