"""Execution backends for the ReshardEngine.

SimExecutor — the byte-level oracle: simulated ranks own numpy shards
(``RankStore``); every planned chunk is copied shard-to-shard exactly as a
real send/recv would. This is the semantics reference the property tests
exercise and the live path is validated against.

LiveExecutor — the live path over global ``jax.Array``s. Plan cells are
per-(tensor, destination-rank); on live arrays the same bytes exist once,
so the executor deduplicates replica fan-out, merges each layer's cells
into row-range groups on the stacked dim, and moves them:

  * scattered rows  -> Pallas ``pack_rows`` gather into a contiguous
    staging buffer, ``device_put`` onto the target mesh, then per-run
    overwrite scatter into the destination storage (idempotent, so dirty
    layers can re-stream),
  * contiguous runs -> slice + ``device_put`` + donated
    dynamic-update-slice (the fallback path; also used for cells that do
    not decompose into full-width rows).

Destination storage is pre-allocated with the target sharding (required
for training regardless — Theorem 1, item 2); staging is bounded by the
engine's budget. On TPU backends ``ops.pack_rows``/``unpack_rows`` run the
Pallas kernels natively; on CPU they run the jnp reference (or interpret
mode under ``REPRO_FORCE_PALLAS_INTERPRET=1``).
"""

from __future__ import annotations

import math
from typing import Any

from repro.core.intersection import TransferTask
from repro.core.resource_view import TensorSpec
from repro.reshard.chunking import rows_per_budget


# ---------------------------------------------------------------------------
# Sim backend
# ---------------------------------------------------------------------------


class SimExecutor:
    """Copy planned chunks between per-rank numpy shard stores."""

    def __init__(self, src_stores: dict[int, Any], dst_stores: dict[int, Any]):
        self.src_stores = src_stores
        self.dst_stores = dst_stores
        self.executed_bytes = 0

    def begin_layer(self, layer: int) -> None:
        pass

    def apply(self, task: TransferTask) -> None:
        src = self.src_stores[task.src_rank]
        dst = self.dst_stores[task.dst_rank]
        shape = task.shape()
        ssl = tuple(slice(o, o + s) for o, s in zip(task.src_offset, shape))
        dsl = tuple(slice(o, o + s) for o, s in zip(task.dst_offset, shape))
        dst.shards[task.tensor][dsl] = src.shards[task.tensor][ssl]
        self.executed_bytes += task.nbytes

    def end_layer(self, layer: int) -> None:
        pass


# ---------------------------------------------------------------------------
# Live backend
# ---------------------------------------------------------------------------


def _jit_helpers():
    """Module-level jitted copy helpers (cached across executor instances)."""
    global _DUS0, _DUS_ND
    if "_DUS0" in globals():
        return
    import jax

    _DUS0 = jax.jit(
        lambda carry, chunk, start: jax.lax.dynamic_update_slice_in_dim(
            carry, chunk, start, axis=0
        ),
        donate_argnums=(0,),
    )
    # starts is a traced 1-D index array; carry.ndim is static per trace,
    # so this caches per (carry shape, chunk shape) pair
    _DUS_ND = jax.jit(
        lambda carry, chunk, starts: jax.lax.dynamic_update_slice(
            carry, chunk, tuple(starts[i] for i in range(carry.ndim))
        ),
        donate_argnums=(0,),
    )


class LiveExecutor:
    """Execute plan regions on live jax.Arrays.

    src: {tensor name: global jax.Array on the source mesh}
    target_shardings: {tensor name: Sharding on the target mesh}
    """

    def __init__(
        self,
        specs: dict[str, TensorSpec],
        src: dict[str, Any],
        target_shardings: dict[str, Any],
        staging_bytes: int,
        free_sources: bool = False,
    ):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        _jit_helpers()
        self.specs = specs
        self.src = src
        self.target_shardings = target_shardings
        self.staging_bytes = staging_bytes
        self.free_sources = free_sources
        self.dst: dict[str, Any] = {}
        self.executed_bytes = 0
        self.generic_cells = 0  # cells that fell off the row-merge fast path
        self._seen: set[tuple] = set()
        self._cells: dict[str, list[TransferTask]] = {}
        # destinations produced by a bare device_put may ALIAS source
        # buffers on devices common to both meshes — deleting such sources
        # would poison the destination (these are scalars; skip the free)
        self._no_release: set[str] = set()
        # last-resort staging layout: replicated on the target mesh (used
        # for the packed 2-D buffer whose collapsed dims defeat the spec);
        # sliced chunks stage in the target's own non-dim0 layout instead
        any_sh = next(iter(target_shardings.values()))
        self._replicated_sh = NamedSharding(any_sh.mesh, P())
        self._jnp = jnp
        self._jax = jax

    def _stage_sharding(self, name: str, chunk_shape: tuple[int, ...]):
        """Staging layout for a chunk of ``name``: the destination's own
        sharding with dim 0 unsharded (chunks are row-slices smaller than a
        dim-0 partition in general) and non-dividing axes dropped — so each
        target device only receives its slice of the chunk, not the whole
        chunk replicated."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = self.target_shardings[name]
        if not isinstance(sh, NamedSharding):
            return self._replicated_sh
        spec = list(sh.spec) + [None] * (len(chunk_shape) - len(sh.spec))
        spec = spec[: len(chunk_shape)]
        if spec:
            spec[0] = None
        sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            factor = 1
            for a in axes:
                factor *= sizes.get(a, 1)
            if factor == 0 or chunk_shape[d] % factor != 0:
                spec[d] = None
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(sh.mesh, P(*spec))

    def release(self, name: str) -> None:
        """Engine hook: this tensor's sources are no longer needed by the
        current run. Only frees device buffers when the caller opted in
        (``free_sources`` — donation semantics: the source tree must not be
        used again)."""
        if not self.free_sources or name in self._no_release:
            return
        leaf = self.src.pop(name, None)
        if leaf is not None and hasattr(leaf, "delete"):
            # drain the consumers first: deleting a buffer with dispatched
            # reads still in flight poisons the destination arrays
            dst = self.dst.get(name)
            if dst is not None and hasattr(dst, "block_until_ready"):
                dst.block_until_ready()
            leaf.delete()

    def update_sources(self, src: dict[str, Any]) -> None:
        """Swap in fresh source leaves (the previous generation's arrays are
        invalidated by step-function donation between streaming rounds)."""
        self.src = src

    def reset_round(self) -> None:
        """Start a new streaming round: layers streamed before may be
        re-streamed (dirty re-sync), so the replica-dedupe set resets."""
        self._seen = set()

    # -- engine protocol ------------------------------------------------
    def begin_layer(self, layer: int) -> None:
        self._cells = {}

    def apply(self, chunk: TransferTask) -> None:
        key = (chunk.tensor, chunk.bounds)
        if key in self._seen:  # replica fan-out: same bytes, other dst rank
            return
        self._seen.add(key)
        self._cells.setdefault(chunk.tensor, []).append(chunk)

    def end_layer(self, layer: int) -> None:
        for name, cells in self._cells.items():
            self._move_tensor(name, cells)
        self._cells = {}

    # -- movement -------------------------------------------------------
    def _dst_carry(self, name: str):
        if name not in self.dst:
            spec = self.specs[name]
            zeros = self._jnp.zeros(spec.shape, spec.dtype)
            self.dst[name] = self._jax.device_put(
                zeros, self.target_shardings[name]
            )
        return self.dst[name]

    def _move_tensor(self, name: str, cells: list[TransferTask]) -> None:
        spec = self.specs[name]
        leaf = self.src[name]
        if leaf.ndim == 0:
            self.dst[name] = self._jax.device_put(
                leaf, self.target_shardings[name]
            )
            self._no_release.add(name)
            self.executed_bytes += spec.nbytes
            return
        # row-merge: do this layer's cells tile full-width rows of dim 0?
        rows: set[int] = set()
        for c in cells:
            rows.update(range(c.bounds[0][0], c.bounds[0][1]))
        per_row = spec.nbytes // spec.shape[0]
        covered = sum(c.nbytes for c in cells)
        if covered == per_row * len(rows):
            self._move_rows(name, sorted(rows))
        else:
            # partial-width cells (no full-row union): per-cell fallback
            self.generic_cells += len(cells)
            for c in cells:
                self._move_cell(name, c)

    def _move_rows(self, name: str, rows: list[int]) -> None:
        jnp, jax = self._jnp, self._jax
        spec = self.specs[name]
        leaf = self.src[name]
        R = spec.shape[0]
        tail = spec.shape[1:]
        C = int(math.prod(tail)) if tail else 1
        per_row = spec.nbytes // R
        carry = self._dst_carry(name)
        max_rows = rows_per_budget(per_row, self.staging_bytes)
        for i in range(0, len(rows), max_rows):
            batch = rows[i : i + max_rows]
            runs = _runs(batch)
            if len(runs) == 1:
                lo, hi = runs[0]
                chunk_shape = (hi - lo,) + tail
                chunk = jax.device_put(
                    leaf[lo:hi], self._stage_sharding(name, chunk_shape)
                )
                carry = _DUS0(carry, chunk, lo)
            else:
                # scattered rows (dirty-layer re-sync): gather through the
                # pack kernel into one contiguous staging buffer, then
                # scatter each run back with overwrite semantics. (An
                # unpack_rows + add scatter would be cheaper but is NOT
                # idempotent: re-streaming a dirty layer would accumulate
                # onto the stale pre-copied value instead of replacing it.)
                from repro.kernels import ops

                src2d = leaf.reshape(R, C)
                starts = jnp.asarray(batch, jnp.int32)
                buf = ops.pack_rows(src2d, starts, 1)
                buf = jax.device_put(buf, self._replicated_sh)
                off = 0
                for lo, hi in runs:
                    k = hi - lo
                    chunk = buf[off : off + k].reshape((k,) + tail)
                    carry = _DUS0(carry, chunk, lo)
                    off += k
            self.executed_bytes += per_row * len(batch)
        self.dst[name] = carry

    def _move_cell(self, name: str, cell: TransferTask) -> None:
        jax = self._jax
        carry = self._dst_carry(name)
        sl = tuple(slice(lo, hi) for lo, hi in cell.bounds)
        chunk_shape = cell.shape()
        chunk = jax.device_put(
            self.src[name][sl], self._stage_sharding(name, chunk_shape)
        )
        starts = self._jnp.asarray([lo for lo, _ in cell.bounds], self._jnp.int32)
        self.dst[name] = _DUS_ND(carry, chunk, starts)
        self.executed_bytes += cell.nbytes

    # -- results --------------------------------------------------------
    def results(self) -> dict[str, Any]:
        """Destination leaves (tensors never planned keep no entry)."""
        return self.dst

    def block_until_ready(self) -> None:
        for v in self.dst.values():
            v.block_until_ready()


def _runs(sorted_rows: list[int]) -> list[tuple[int, int]]:
    """Collapse a sorted unique row list into contiguous [lo, hi) runs."""
    runs: list[tuple[int, int]] = []
    for r in sorted_rows:
        if runs and runs[-1][1] == r:
            runs[-1] = (runs[-1][0], r + 1)
        else:
            runs.append((r, r + 1))
    return runs
