"""Execution backends for the ReshardEngine.

SimExecutor — the byte-level oracle: simulated ranks own numpy shards
(``RankStore``); every planned chunk is copied shard-to-shard exactly as a
real send/recv would. This is the semantics reference the property tests
exercise and the live path is validated against.

LiveExecutor — the live path over global ``jax.Array``s. Plan cells are
per-(tensor, destination-rank); on live arrays the same bytes exist once,
so the executor deduplicates replica fan-out, merges each layer's cells
into row-range groups on the stacked dim, and moves them:

  * scattered rows  -> ONE compiled program chain per staging batch:
    Pallas ``pack_rows`` gather into a contiguous staging buffer, one
    staged ``device_put`` onto the target mesh, one overwrite-semantics
    ``scatter_rows`` into the (donated) destination carry. Overwrite makes
    re-streaming a dirty layer idempotent; the fused form replaces the
    per-run dynamic-update-slice chain that used to cost O(runs) host
    dispatches per batch.
  * contiguous runs -> slice + ``device_put`` + donated
    dynamic-update-slice (already a 3-dispatch path; also used for cells
    that do not decompose into full-width rows).

Destination carries are allocated device-side under the target sharding
(jitted sharded ``jnp.zeros`` — no host materialization or host->device
round trip of the full buffer). All jit helpers live in module-level
caches keyed by destination sharding, so retraces are cached per shape
family across executor instances and streaming rounds.

Everything the executor emits is an *async dispatch*: nothing here waits
on destination writes. The only waits are staging backpressure — at most
two staged buffers stay pinned (double buffering; ``_stage`` waits on the
oldest beyond that, whose consumer is already dispatched, so a full plan's
staging can never accumulate on device) — and the explicit round hooks:
callers that pipeline rounds use ``begin_round``/``sync_staging``/
``round_touched``, where ``sync_staging`` waits only until this round's
staged buffers are materialized (after which the round no longer reads its
source leaves and they are safe to donate to the next train step), while
the scatters into the destination carries keep draining in the background.

Staging is bounded by the engine's budget. On TPU backends
``ops.pack_rows``/``scatter_rows`` run the Pallas kernels natively; on CPU
they run the jnp reference (or interpret mode under
``REPRO_FORCE_PALLAS_INTERPRET=1``).
"""

from __future__ import annotations

import math
from typing import Any

from repro.core.intersection import TransferTask
from repro.core.resource_view import TensorSpec
from repro.reshard.chunking import rows_per_budget
from repro.reshard.wire import wire_nbytes


# ---------------------------------------------------------------------------
# Sim backend
# ---------------------------------------------------------------------------


class SimExecutor:
    """Copy planned chunks between per-rank numpy shard stores.

    The sim always copies losslessly (it is the byte-level semantics
    oracle), but it *prices* wire bytes under the given policy: its
    ``wire_bytes`` counter reports what a compressed wire would have
    carried for the same plan, so sim↔live accounting comparisons hold
    with or without quantization.
    """

    def __init__(
        self,
        src_stores: dict[int, Any],
        dst_stores: dict[int, Any],
        wire_policy=None,
    ):
        self.src_stores = src_stores
        self.dst_stores = dst_stores
        self.wire_policy = wire_policy
        self.executed_bytes = 0
        self.wire_bytes = 0

    def begin_layer(self, layer: int) -> None:
        pass

    def apply(self, task: TransferTask) -> None:
        src = self.src_stores[task.src_rank]
        dst = self.dst_stores[task.dst_rank]
        shape = task.shape()
        ssl = tuple(slice(o, o + s) for o, s in zip(task.src_offset, shape))
        dsl = tuple(slice(o, o + s) for o, s in zip(task.dst_offset, shape))
        dst.shards[task.tensor][dsl] = src.shards[task.tensor][ssl]
        # resident cells are already in place on the real device — the sim
        # still performs the copy (its per-rank stores are distinct buffers,
        # and the oracle must produce complete destination shards) but the
        # byte oracle counts them as zero moved bytes (DESIGN.md §13)
        if not task.resident:
            self.executed_bytes += task.nbytes
            self.wire_bytes += wire_nbytes(self.wire_policy, task)

    def end_layer(self, layer: int) -> None:
        pass


# ---------------------------------------------------------------------------
# Live backend
# ---------------------------------------------------------------------------

# Module-level jit caches: shared across executor instances and streaming
# rounds so every round after the first hits warm executables. _DUS0/_DUS_ND
# rely on jax.jit's own per-shape cache; zeros/scatter need explicit
# out_shardings (a trace-time constant), so they are additionally keyed by
# the destination sharding. Bounded: an elastic job cycles through many
# world configurations, and an unbounded cache would pin every historical
# mesh (and its executables) for process lifetime.
_ZEROS_CACHE: dict = {}
_SCATTER_CACHE: dict = {}
_RELAYOUT_CACHE: dict = {}
_RELAYOUT_ND_CACHE: dict = {}
_DEQ_SCATTER_CACHE: dict = {}
_JIT_CACHE_MAX = 64


def _cache_put(cache: dict, key, fn):
    if len(cache) >= _JIT_CACHE_MAX:
        cache.pop(next(iter(cache)))  # FIFO: oldest shape family retraces
    cache[key] = fn
    return fn


def _await_staged(buf) -> float:
    """Wait for a staged buffer unless it was already deleted: a staged
    device_put with a matching layout returns its input array, which the
    plan-less path's ``release`` may legitimately delete — only ever after
    the consuming destination drained, so a deleted buffer means 'done'.
    Returns the seconds spent blocked (drain-side time, not dispatch)."""
    import time

    if hasattr(buf, "block_until_ready") and not (
        hasattr(buf, "is_deleted") and buf.is_deleted()
    ):
        t0 = time.perf_counter()
        buf.block_until_ready()
        return time.perf_counter() - t0
    return 0.0


def _jit_helpers():
    """Module-level jitted copy helpers (cached across executor instances)."""
    global _DUS0, _DUS_ND, _PACK2D, _PACKQ2D
    if "_DUS0" in globals():
        return
    import jax

    _DUS0 = jax.jit(
        lambda carry, chunk, start: jax.lax.dynamic_update_slice_in_dim(
            carry, chunk, start, axis=0
        ),
        donate_argnums=(0,),
    )
    # starts is a traced 1-D index array; carry.ndim is static per trace,
    # so this caches per (carry shape, chunk shape) pair
    _DUS_ND = jax.jit(
        lambda carry, chunk, starts: jax.lax.dynamic_update_slice(
            carry, chunk, tuple(starts[i] for i in range(carry.ndim))
        ),
        donate_argnums=(0,),
    )

    def _pack2d(leaf, starts):
        from repro.kernels import ops

        return ops.pack_rows(leaf.reshape(leaf.shape[0], -1), starts, 1)

    # collapse-to-2D + row gather as one compiled program on the source mesh
    # (caches per (leaf shape, starts length) family)
    _PACK2D = jax.jit(_pack2d)

    def _packq2d(leaf, starts, fmt):
        from repro.kernels import ops

        return ops.pack_quant_rows(leaf.reshape(leaf.shape[0], -1), starts, 1, fmt)

    # compressed-wire pack: gather + per-row quantize in one program on the
    # source mesh, returning (int8/fp8 payload, float32 sidecar scales)
    _PACKQ2D = jax.jit(_packq2d, static_argnums=(2,))


def _zeros_fn(shape: tuple, dtype: str, sharding):
    """Jitted device-side allocation of a zeroed carry directly under the
    target sharding — the old host-side ``jnp.zeros`` + ``device_put``
    double-materialized every destination tensor."""
    key = (shape, dtype, sharding)
    fn = _ZEROS_CACHE.get(key)
    if fn is None:
        import jax
        import jax.numpy as jnp

        fn = _cache_put(
            _ZEROS_CACHE,
            key,
            jax.jit(lambda: jnp.zeros(shape, dtype), out_shardings=sharding),
        )
    return fn


def _scatter_fn(sharding):
    """Jitted fused overwrite-scatter: collapse the carry to 2-D, scatter
    the packed row buffer at the given offsets, restore the carry shape.
    The carry is donated and the output pinned to the destination sharding
    (reshape round-trips must not let GSPMD re-decide the layout).
    jax.jit caches traces per (carry, buf, starts) shape family underneath
    the per-sharding entry."""
    fn = _SCATTER_CACHE.get(sharding)
    if fn is None:
        import jax

        def f(carry, buf, starts):
            from repro.kernels import ops

            c2 = carry.reshape(carry.shape[0], -1)
            c2 = ops.scatter_rows(c2, buf, starts, 1)
            return c2.reshape(carry.shape)

        fn = _cache_put(
            _SCATTER_CACHE,
            sharding,
            jax.jit(f, donate_argnums=(0,), out_shardings=sharding),
        )
    return fn


def _dequant_scatter_fn(sharding):
    """Jitted fused dequant + overwrite-scatter for the compressed wire
    path: collapse the donated carry to 2-D, dequantize each staged tile
    with its sidecar scale and scatter it at the given row offsets, restore
    the carry shape. Same overwrite/idempotence semantics as
    ``_scatter_fn`` — dequant is a deterministic elementwise map, so
    re-applying the same payload lands bitwise-identical bytes."""
    fn = _DEQ_SCATTER_CACHE.get(sharding)
    if fn is None:
        import jax

        def f(carry, buf, scales, starts):
            from repro.kernels import ops

            c2 = carry.reshape(carry.shape[0], -1)
            c2 = ops.dequant_scatter_rows(c2, buf, scales, starts, 1)
            return c2.reshape(carry.shape)

        fn = _cache_put(
            _DEQ_SCATTER_CACHE,
            sharding,
            jax.jit(f, donate_argnums=(0,), out_shardings=sharding),
        )
    return fn


def _relayout_fn(sharding):
    """Jitted fused on-device relayout for "local" plan cells: gather the
    named rows from the SOURCE leaf and overwrite-scatter them into the
    donated destination carry at the same global offsets — one compiled
    program, no staging buffer, no cross-mesh device_put hop. Legal only
    when source and target meshes flatten to the same device assignment
    (the caller guards via ``_same_device_assignment``)."""
    fn = _RELAYOUT_CACHE.get(sharding)
    if fn is None:
        import jax

        def f(carry, leaf, starts):
            from repro.kernels import ops

            c2 = carry.reshape(carry.shape[0], -1)
            l2 = leaf.reshape(leaf.shape[0], -1)
            c2 = ops.relayout_rows(c2, l2, starts, 1)
            return c2.reshape(carry.shape)

        fn = _cache_put(
            _RELAYOUT_CACHE,
            sharding,
            jax.jit(f, donate_argnums=(0,), out_shardings=sharding),
        )
    return fn


def _relayout_nd_fn(sharding, chunk_shape: tuple[int, ...]):
    """Jitted fused slice+update for a "local" cell that does not decompose
    into full-width rows: dynamic_slice the SOURCE leaf at the cell's global
    origin and dynamic_update_slice it into the donated carry at the same
    origin — one program instead of the slice/device_put/DUS chain."""
    key = (sharding, chunk_shape)
    fn = _RELAYOUT_ND_CACHE.get(key)
    if fn is None:
        import jax

        def f(carry, leaf, starts):
            idx = tuple(starts[i] for i in range(carry.ndim))
            chunk = jax.lax.dynamic_slice(leaf, idx, chunk_shape)
            return jax.lax.dynamic_update_slice(carry, chunk, idx)

        fn = _cache_put(
            _RELAYOUT_ND_CACHE,
            key,
            jax.jit(f, donate_argnums=(0,), out_shardings=sharding),
        )
    return fn


def _same_device_assignment(sh_a, sh_b) -> bool:
    """True when two NamedShardings flatten to the identical ordered device
    list — the precondition for putting both arrays through one jitted
    program (jax rejects mixed device assignments)."""
    from jax.sharding import NamedSharding

    if not isinstance(sh_a, NamedSharding) or not isinstance(sh_b, NamedSharding):
        return False
    a = sh_a.mesh.devices.ravel().tolist()
    b = sh_b.mesh.devices.ravel().tolist()
    return len(a) == len(b) and all(x is y for x, y in zip(a, b))


class LiveExecutor:
    """Execute plan regions on live jax.Arrays.

    src: {tensor name: global jax.Array on the source mesh}
    target_shardings: {tensor name: Sharding on the target mesh}
    fused: route scattered-row batches through the pack -> staged put ->
        overwrite-scatter program chain (default); ``False`` keeps the
        legacy per-run dynamic-update-slice chain (benchmark baseline).
    """

    def __init__(
        self,
        specs: dict[str, TensorSpec],
        src: dict[str, Any],
        target_shardings: dict[str, Any],
        staging_bytes: int,
        free_sources: bool = False,
        fused: bool = True,
        wire_policy=None,
        wire_bw_bytes_s: float | None = None,
    ):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P

        _jit_helpers()
        self.specs = specs
        self.src = src
        self.target_shardings = target_shardings
        self.staging_bytes = staging_bytes
        self.free_sources = free_sources
        self.fused = fused
        # per-kind wire policy: None = fully lossless (the byte-oracle
        # default). With a policy, remote row batches of quantized
        # collections go through the fused pack-quant -> staged put ->
        # dequant-scatter chain; the generic per-cell fallback and the
        # legacy (fused=False) baseline stay lossless.
        self.wire_policy = wire_policy
        # emulated interconnect: when set, every staged wire transfer
        # blocks for wire_bytes / wire_bw_bytes_s. This container's host
        # "transfers" are memcpys, so without an emulated wire the payload
        # size cannot show up in wall time; benches set this to measure
        # compression as effective bandwidth (documented deviation,
        # DESIGN.md §14).
        self.wire_bw_bytes_s = wire_bw_bytes_s
        self.dst: dict[str, Any] = {}
        self.executed_bytes = 0
        # bytes that physically crossed the (possibly emulated) wire:
        # quantized payload + sidecar for compressed batches, raw bytes for
        # lossless ones; on-device relayouts cross no wire and count zero
        self.wire_bytes = 0
        self.generic_cells = 0  # cells that fell off the row-merge fast path
        # blocking time spent in staging backpressure — drain-side wall
        # clock; the engine subtracts its delta from the loop time so
        # dispatch_seconds stays pure dispatch
        self.stage_wait_seconds = 0.0
        # count of resident pass-through refreshes (tests/benchmarks)
        self.resident_passthroughs = 0
        # replica-dedupe: region key -> strongest kind seen ("resident" is
        # upgraded in place if another dst rank genuinely needs the bytes)
        self._seen: dict[tuple, str] = {}
        self._cells: dict[str, dict[tuple, TransferTask]] = {}
        # tensors already refreshed via the resident pass-through this round
        self._resident_done: set[str] = set()
        # async round tracking: staged buffers whose readiness implies this
        # round's source reads completed, and the dst names it touched
        self._round_staged: list[Any] = []
        self._round_touched: set[str] = set()
        # destinations produced by a bare device_put may ALIAS source
        # buffers on devices common to both meshes — deleting such sources
        # would poison the destination (these are scalars; skip the free)
        self._no_release: set[str] = set()
        # last-resort staging layout: replicated on the target mesh (used
        # for the packed 2-D buffer whose collapsed dims defeat the spec);
        # sliced chunks stage in the target's own non-dim0 layout instead
        any_sh = next(iter(target_shardings.values()))
        self._replicated_sh = NamedSharding(any_sh.mesh, P())
        self._jnp = jnp
        self._jax = jax

    def _stage_sharding(self, name: str, chunk_shape: tuple[int, ...]):
        """Staging layout for a chunk of ``name``: the destination's own
        sharding with dim 0 unsharded (chunks are row-slices smaller than a
        dim-0 partition in general) and non-dividing axes dropped — so each
        target device only receives its slice of the chunk, not the whole
        chunk replicated."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = self.target_shardings[name]
        if not isinstance(sh, NamedSharding):
            return self._replicated_sh
        spec = list(sh.spec) + [None] * (len(chunk_shape) - len(sh.spec))
        spec = spec[: len(chunk_shape)]
        if spec:
            spec[0] = None
        sizes = dict(zip(sh.mesh.axis_names, sh.mesh.devices.shape))
        for d, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            factor = 1
            for a in axes:
                factor *= sizes.get(a, 1)
            if factor == 0 or chunk_shape[d] % factor != 0:
                spec[d] = None
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(sh.mesh, P(*spec))

    def release(self, name: str) -> None:
        """Engine hook: this tensor's sources are no longer needed by the
        current run. Only frees device buffers when the caller opted in
        (``free_sources`` — donation semantics: the source tree must not be
        used again)."""
        if not self.free_sources or name in self._no_release:
            return
        leaf = self.src.pop(name, None)
        if leaf is not None and hasattr(leaf, "delete"):
            # drain the consumers first: deleting a buffer with dispatched
            # reads still in flight poisons the destination arrays
            dst = self.dst.get(name)
            if dst is not None and hasattr(dst, "block_until_ready"):
                dst.block_until_ready()
            leaf.delete()

    def update_sources(self, src: dict[str, Any]) -> None:
        """Swap in fresh source leaves (the previous generation's arrays are
        invalidated by step-function donation between streaming rounds).
        Resident destinations must re-alias the NEW leaves, so their
        pass-through marks reset too."""
        self.src = src
        self._resident_done = set()

    def reset_round(self) -> None:
        """Start a new streaming round: layers streamed before may be
        re-streamed (dirty re-sync), so the replica-dedupe set resets and
        resident tensors are refreshed from the new cut."""
        self._seen = {}
        self._resident_done = set()

    # -- async round protocol -------------------------------------------
    def begin_round(self) -> None:
        """Open a dispatch round: forget the previous round's staged-buffer
        and touched-destination bookkeeping (NOT the replica-dedupe set —
        see ``reset_round``)."""
        self._round_staged = []
        self._round_touched = set()

    def round_touched(self) -> set[str]:
        """Destination tensors this round dispatched writes into."""
        return set(self._round_touched)

    def _emulate_wire(self, nbytes: int) -> None:
        """Account a wire crossing; block for its emulated transfer time."""
        self.wire_bytes += nbytes
        if self.wire_bw_bytes_s:
            import time

            time.sleep(nbytes / self.wire_bw_bytes_s)

    def _stage(self, buf):
        """Track a staged buffer, keeping at most two pinned (double
        buffering). Beyond that the oldest is waited on and dereferenced;
        per-device program order then frees it as soon as its (already
        dispatched) consumer retires. Live staging is therefore bounded by
        a small constant multiple of the budget — ~3 chunks: two pinned
        here plus at most one whose consumer is still retiring — not by
        the plan size; callers that never round-sync (the stop-copy paths)
        cannot accumulate a whole plan's staging on device. (The engine's
        ``peak_staging_bytes`` accounts the logical per-flush bound; this
        constant factor is the pipelining price on top.)"""
        self._round_staged.append(buf)
        if len(self._round_staged) > 2:
            self.stage_wait_seconds += _await_staged(self._round_staged.pop(0))
        return buf

    def sync_staging(self) -> None:
        """Block until this round's staged buffers are materialized. A
        staged buffer being ready implies the pack/slice that produced it
        — i.e. every read of this round's SOURCE leaves — has completed,
        so the caller may let the training step donate those sources while
        the scatters into the destination carries keep draining."""
        for buf in self._round_staged:
            self.stage_wait_seconds += _await_staged(buf)
        self._round_staged = []

    # -- engine protocol ------------------------------------------------
    def begin_layer(self, layer: int) -> None:
        self._cells = {}

    def apply(self, chunk: TransferTask) -> None:
        key = (chunk.tensor, chunk.bounds)
        prev = self._seen.get(key)
        if prev is not None:  # replica fan-out: same bytes, other dst rank
            if prev == "resident" and chunk.kind != "resident":
                # the region first showed up as resident, but this replica
                # lands on a device that does NOT already hold it — one
                # move on the global array covers every destination device
                # (including the resident one), so upgrade in place
                self._seen[key] = chunk.kind
                self._cells[chunk.tensor][chunk.bounds] = chunk
            return
        self._seen[key] = chunk.kind
        self._cells.setdefault(chunk.tensor, {})[chunk.bounds] = chunk

    def end_layer(self, layer: int) -> None:
        for name, regions in self._cells.items():
            cells = list(regions.values())
            if all(c.resident for c in cells):
                # every byte of this tensor's layer is already on the right
                # device: refresh the destination by aliasing the live
                # source instead of streaming (DESIGN.md §13)
                self._adopt_resident(name)
            else:
                self._move_tensor(name, cells)
        self._cells = {}

    def _adopt_resident(self, name: str) -> None:
        self._round_touched.add(name)
        if name in self._resident_done:
            return
        self._resident_done.add(name)
        # a same-layout device_put aliases per-device buffers where the
        # target already holds the bytes — near-free, and exactly why the
        # sources of a resident destination must never be force-freed
        self.dst[name] = self._jax.device_put(
            self.src[name], self.target_shardings[name]
        )
        self._no_release.add(name)
        self._stage(self.dst[name])
        self.resident_passthroughs += 1

    # -- movement -------------------------------------------------------
    def _dst_carry(self, name: str):
        if name not in self.dst:
            spec = self.specs[name]
            # allocated directly under the target sharding inside jit: no
            # host-side zeros buffer, no host->device transfer of the full
            # tensor, and the executable is cached per shape family
            self.dst[name] = _zeros_fn(
                spec.shape, spec.dtype, self.target_shardings[name]
            )()
        return self.dst[name]

    def _move_tensor(self, name: str, cells: list[TransferTask]) -> None:
        spec = self.specs[name]
        leaf = self.src[name]
        self._round_touched.add(name)
        if leaf.ndim == 0:
            self.dst[name] = self._jax.device_put(
                leaf, self.target_shardings[name]
            )
            self._stage(self.dst[name])
            self._no_release.add(name)
            self.executed_bytes += spec.nbytes
            self._emulate_wire(spec.nbytes)  # scalars are always lossless
            return
        # classified routing: same-rank cells ("local" relayouts, plus the
        # rare resident cell sharing a layer with moved regions) can take
        # the fused on-device relayout — one program, no staging hop —
        # when both meshes flatten to the same device assignment (a jitted
        # program cannot span two device sets) AND splitting them off does
        # not break the row-merge fast path for either partition.
        here = [c for c in cells if c.kind in ("local", "resident")]
        if here and self._relayout_ok(name):
            rest = [c for c in cells if c.kind == "remote"]
            rows_here = _full_rows(spec, here)
            rows_rest = _full_rows(spec, rest) if rest else []
            if rows_here is not None and rows_rest is not None:
                self._relayout_rows(name, rows_here)
                if not rest:
                    return
                cells = rest
            elif _full_rows(spec, cells) is None:
                # everything is generic either way: at least fuse the
                # same-device cells into single-program relayouts
                self.generic_cells += len(cells)
                for c in here:
                    self._relayout_cell(name, c)
                for c in rest:
                    self._move_cell(name, c)
                return
            # else: local+remote jointly tile full rows — the combined
            # staged row path beats two per-partition generic paths
        # row-merge: do this layer's cells tile full-width rows of dim 0?
        rows = _full_rows(spec, cells)
        if rows is not None:
            self._move_rows(name, rows)
        else:
            # partial-width cells (no full-row union): per-cell fallback
            self.generic_cells += len(cells)
            for c in cells:
                self._move_cell(name, c)

    # -- fused on-device relayout (classified "local" cells) ------------
    def _relayout_ok(self, name: str) -> bool:
        sh_src = getattr(self.src[name], "sharding", None)
        return _same_device_assignment(sh_src, self.target_shardings[name])

    def _relayout_rows(self, name: str, rows: list[int]) -> None:
        jnp = self._jnp
        spec = self.specs[name]
        leaf = self.src[name]
        per_row = spec.nbytes // spec.shape[0]
        carry = self._dst_carry(name)
        fn = _relayout_fn(self.target_shardings[name])
        max_rows = rows_per_budget(per_row, self.staging_bytes)
        for i in range(0, len(rows), max_rows):
            batch = rows[i : i + max_rows]
            starts = self._jax.device_put(
                jnp.asarray(batch, jnp.int32), self._replicated_sh
            )
            carry = fn(carry, leaf, starts)
            self.executed_bytes += per_row * len(batch)
        self.dst[name] = carry
        # the carry's readiness implies every source read of the relayout
        # chain retired — that is what sync_staging promises callers
        self._stage(carry)

    def _relayout_cell(self, name: str, cell: TransferTask) -> None:
        carry = self._dst_carry(name)
        starts = self._jax.device_put(
            self._jnp.asarray([lo for lo, _ in cell.bounds], self._jnp.int32),
            self._replicated_sh,
        )
        fn = _relayout_nd_fn(self.target_shardings[name], cell.shape())
        self.dst[name] = fn(carry, self.src[name], starts)
        self._stage(self.dst[name])
        self.executed_bytes += cell.nbytes

    def _wire_format(self, name: str) -> str:
        if self.wire_policy is None or not self.fused:
            return "none"
        return self.wire_policy.format_for(self.specs[name].collection)

    def _move_rows(self, name: str, rows: list[int]) -> None:
        jnp, jax = self._jnp, self._jax
        spec = self.specs[name]
        leaf = self.src[name]
        tail = spec.shape[1:]
        per_row = spec.nbytes // spec.shape[0]
        fmt = self._wire_format(name)
        if fmt != "none":
            # one sidecar float32 scale per row-tile rides with the payload
            row_elems = int(math.prod(tail)) if tail else 1
            wire_per_row = row_elems + 4
        else:
            wire_per_row = per_row
        carry = self._dst_carry(name)
        # the staging budget bounds wire bytes — what is physically staged —
        # so a quantized tensor packs ~4x more logical rows per batch
        max_rows = rows_per_budget(wire_per_row, self.staging_bytes)
        for i in range(0, len(rows), max_rows):
            batch = rows[i : i + max_rows]
            runs = _runs(batch)
            if fmt != "none":
                # compressed wire path: pack-quantize on the source mesh
                # (payload + sidecar scales), stage the small buffers, then
                # one fused dequant + overwrite-scatter into the donated
                # carry. Used for contiguous runs too — the wire transfer,
                # not the dispatch count, is what compression shrinks.
                starts = jnp.asarray(batch, jnp.int32)
                qbuf, scales = _PACKQ2D(leaf, starts, fmt)
                qbuf = jax.device_put(qbuf, self._replicated_sh)
                scales = jax.device_put(scales, self._replicated_sh)
                starts_dev = jax.device_put(starts, self._replicated_sh)
                carry = _dequant_scatter_fn(self.target_shardings[name])(
                    carry, qbuf, scales, starts_dev
                )
                self._stage(qbuf)
                self._emulate_wire(wire_per_row * len(batch))
            elif len(runs) == 1:
                lo, hi = runs[0]
                chunk_shape = (hi - lo,) + tail
                chunk = jax.device_put(
                    leaf[lo:hi], self._stage_sharding(name, chunk_shape)
                )
                carry = _DUS0(carry, chunk, lo)
                self._stage(chunk)
            elif self.fused:
                # scattered rows (dirty-layer re-sync): one pack on the
                # source mesh, one staged put, one overwrite scatter into
                # the donated carry — 3 dispatches per batch instead of
                # O(runs). (An accumulate scatter would be cheaper on TPU
                # but is NOT idempotent: re-streaming a dirty layer would
                # compound onto the stale pre-copied value.)
                starts = jnp.asarray(batch, jnp.int32)
                buf = jax.device_put(
                    _PACK2D(leaf, starts), self._replicated_sh
                )
                starts_dev = jax.device_put(starts, self._replicated_sh)
                carry = _scatter_fn(self.target_shardings[name])(
                    carry, buf, starts_dev
                )
                self._stage(buf)
            else:
                # legacy baseline (bench_dataplane's "per-run DUS" path):
                # pack once, then per-run slice + dynamic-update-slice
                from repro.kernels import ops

                R = spec.shape[0]
                C = int(math.prod(tail)) if tail else 1
                src2d = leaf.reshape(R, C)
                starts = jnp.asarray(batch, jnp.int32)
                buf = ops.pack_rows(src2d, starts, 1)
                buf = jax.device_put(buf, self._replicated_sh)
                self._stage(buf)
                off = 0
                for lo, hi in runs:
                    k = hi - lo
                    chunk = buf[off : off + k].reshape((k,) + tail)
                    carry = _DUS0(carry, chunk, lo)
                    off += k
            self.executed_bytes += per_row * len(batch)
            if fmt == "none":
                self._emulate_wire(per_row * len(batch))
        self.dst[name] = carry

    def _move_cell(self, name: str, cell: TransferTask) -> None:
        jax = self._jax
        carry = self._dst_carry(name)
        sl = tuple(slice(lo, hi) for lo, hi in cell.bounds)
        chunk_shape = cell.shape()
        chunk = jax.device_put(
            self.src[name][sl], self._stage_sharding(name, chunk_shape)
        )
        starts = self._jnp.asarray([lo for lo, _ in cell.bounds], self._jnp.int32)
        self.dst[name] = _DUS_ND(carry, chunk, starts)
        self._stage(chunk)
        self.executed_bytes += cell.nbytes
        # the generic fallback stays lossless regardless of policy
        self._emulate_wire(cell.nbytes)

    # -- results --------------------------------------------------------
    def results(self) -> dict[str, Any]:
        """Destination leaves (tensors never planned keep no entry)."""
        return self.dst

    def block_until_ready(self) -> None:
        self._round_staged = []
        for v in self.dst.values():
            v.block_until_ready()


def _full_rows(spec, cells: list[TransferTask]) -> list[int] | None:
    """The sorted dim-0 rows these cells tile at full width, or None if the
    union does not decompose into complete rows (the generic-cell case)."""
    rows: set[int] = set()
    for c in cells:
        rows.update(range(c.bounds[0][0], c.bounds[0][1]))
    per_row = spec.nbytes // spec.shape[0]
    covered = sum(c.nbytes for c in cells)
    if covered == per_row * len(rows):
        return sorted(rows)
    return None


def _runs(sorted_rows: list[int]) -> list[tuple[int, int]]:
    """Collapse a sorted unique row list into contiguous [lo, hi) runs."""
    runs: list[tuple[int, int]] = []
    for r in sorted_rows:
        if runs and runs[-1][1] == r:
            runs[-1] = (runs[-1][0], r + 1)
        else:
            runs.append((r, r + 1))
    return runs
