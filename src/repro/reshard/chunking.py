"""The single chunking implementation shared by every reshard backend.

Oversized payloads are split into fixed-budget row batches along one dim
(paper §5: fixed-size chunks, default 512 MB). Formerly duplicated between
``core/streaming._chunk_task`` (sim) and ``core/reshard._reshard_chunked``
(live); both now call here.
"""

from __future__ import annotations

import numpy as np

from repro.core.intersection import TransferTask


def rows_per_budget(per_row_bytes: int, budget: int) -> int:
    """Rows of ``per_row_bytes`` that fit the staging budget (≥1)."""
    return max(1, budget // max(per_row_bytes, 1))


def row_batches(
    lo: int, hi: int, per_row_bytes: int, budget: int
) -> list[tuple[int, int]]:
    """Split the index range [lo, hi) into consecutive batches whose payload
    (``per_row_bytes`` each) stays within ``budget`` (≥1 row per batch)."""
    rows = rows_per_budget(per_row_bytes, budget)
    out = []
    start = lo
    while start < hi:
        end = min(start + rows, hi)
        out.append((start, end))
        start = end
    return out


def chunk_task(task: TransferTask, budget: int) -> list[TransferTask]:
    """Split a task whose payload exceeds the staging budget into sub-slices
    along its largest dim."""
    if task.nbytes <= budget:
        return [task]
    shape = task.shape()
    d = int(np.argmax(shape))
    per_row = task.nbytes // shape[d]
    lo, hi = task.bounds[d]
    out = []
    for start, end in row_batches(lo, hi, per_row, budget):
        bounds = list(task.bounds)
        bounds[d] = (start, end)
        out.append(
            TransferTask(
                tensor=task.tensor,
                collection=task.collection,
                src_rank=task.src_rank,
                dst_rank=task.dst_rank,
                bounds=tuple(bounds),
                src_offset=tuple(
                    o + (start - lo if i == d else 0)
                    for i, o in enumerate(task.src_offset)
                ),
                dst_offset=tuple(
                    o + (start - lo if i == d else 0)
                    for i, o in enumerate(task.dst_offset)
                ),
                nbytes=task.nbytes * (end - start) // shape[d],
                layer=task.layer,
                kind=task.kind,
            )
        )
    return out
