"""The single chunking implementation shared by every reshard backend.

Oversized payloads are split into fixed-budget row batches along one dim
(paper §5: fixed-size chunks, default 512 MB). Formerly duplicated between
``core/streaming._chunk_task`` (sim) and ``core/reshard._reshard_chunked``
(live); both now call here.
"""

from __future__ import annotations

import numpy as np

from repro.core.intersection import TransferTask
from repro.reshard.wire import wire_nbytes


def rows_per_budget(per_row_bytes: int, budget: int) -> int:
    """Rows of ``per_row_bytes`` that fit the staging budget (≥1)."""
    return max(1, budget // max(per_row_bytes, 1))


def row_batches(
    lo: int, hi: int, per_row_bytes: int, budget: int
) -> list[tuple[int, int]]:
    """Split the index range [lo, hi) into consecutive batches whose payload
    (``per_row_bytes`` each) stays within ``budget`` (≥1 row per batch)."""
    rows = rows_per_budget(per_row_bytes, budget)
    out = []
    start = lo
    while start < hi:
        end = min(start + rows, hi)
        out.append((start, end))
        start = end
    return out


def chunk_task(
    task: TransferTask, budget: int, wire_policy=None
) -> list[TransferTask]:
    """Split a task whose payload exceeds the staging budget into sub-slices
    along its largest dim.

    The budget bounds what is physically *staged*: under a quantizing
    ``wire_policy`` a remote task's staged payload is its wire bytes
    (packed elements + sidecar scales), so chunk boundaries are computed
    from the wire size — a quantized task packs ~4× more logical rows into
    the same staging budget. The emitted chunks still carry logical
    ``nbytes`` (the plan's accounting unit); ``wire_policy=None`` preserves
    the historical lossless arithmetic exactly.
    """
    staged = wire_nbytes(wire_policy, task)
    if staged <= budget:
        return [task]
    shape = task.shape()
    d = int(np.argmax(shape))
    if (
        wire_policy is not None
        and getattr(task, "kind", "remote") == "remote"
        and wire_policy.format_for(task.collection) != "none"
        and len(shape) > 0
        and shape[0] > 1
    ):
        # sidecar scales are per dim-0 row: splitting any other dim keeps
        # the full sidecar in every chunk and overshoots the budget, so a
        # quantized task always splits along the leading dim (where
        # staged // shape[0] is its exact per-row wire cost)
        d = 0
    per_row = max(1, staged // shape[d])
    lo, hi = task.bounds[d]
    out = []
    for start, end in row_batches(lo, hi, per_row, budget):
        bounds = list(task.bounds)
        bounds[d] = (start, end)
        out.append(
            TransferTask(
                tensor=task.tensor,
                collection=task.collection,
                src_rank=task.src_rank,
                dst_rank=task.dst_rank,
                bounds=tuple(bounds),
                src_offset=tuple(
                    o + (start - lo if i == d else 0)
                    for i, o in enumerate(task.src_offset)
                ),
                dst_offset=tuple(
                    o + (start - lo if i == d else 0)
                    for i, o in enumerate(task.dst_offset)
                ),
                nbytes=task.nbytes * (end - start) // shape[d],
                layer=task.layer,
                kind=task.kind,
            )
        )
    return out
