"""Plan-driven reshard engine (paper §4.6, Algorithm 1) — one subsystem
behind both execution backends.

The planner (core/intersection.py) emits a :class:`TransferPlan`; this
package executes it:

  * :class:`ReshardEngine`   — backend-agnostic Algorithm 1 driver: layer
    ordering, staging-budget chunking (Theorem 1 accounting), barriers,
    :class:`StreamStats` byte/phase accounting.
  * :class:`SimExecutor`     — multi-rank byte-level oracle over
    ``RankStore`` numpy shards (the semantics reference; property-tested).
  * :class:`LiveExecutor`    — the live path over global ``jax.Array``s:
    deduplicates replica fan-out, merges plan cells into contiguous
    row-range groups, and moves each staging batch as a small fixed
    number of compiled programs — Pallas ``pack_rows`` gather, staged
    ``device_put``, overwrite-semantics ``scatter_rows`` into the
    donated destination carry (interpret / reference mode on CPU) —
    with a ``device_put`` + dynamic-update-slice path for contiguous
    runs and generic cells. Dispatch-only: callers own every barrier.
  * :class:`OverlapSession`  — asynchronous, double-buffered layer
    streaming for the live controller: K layers dispatched per
    iteration boundary (pre-copy), at most one round's scatters in
    flight, dirty-layer re-sync overlapped with the final grad
    computation, single drain at commit (DESIGN.md §9).
  * :class:`WirePolicy`      — per-collection compressed wire format for
    remote chunks (optimizer moments int8 by default, params lossless),
    executed by the ``pack_quant_rows``/``dequant_scatter_rows`` kernels
    and priced by every byte counter as wire vs logical bytes.
  * :func:`tune_operating_point` — measured-bandwidth tuner that picks
    ``stream_k``, chunk size and staging budget per (plan bytes, window)
    instead of the hand-set constants (DESIGN.md §14).

See DESIGN.md §9 for the architecture and the commit protocol.
"""

from repro.reshard.autotune import OperatingPoint, tune_operating_point
from repro.reshard.chunking import chunk_task, row_batches
from repro.reshard.engine import ReshardEngine, StreamStats, DEFAULT_STAGING_BYTES
from repro.reshard.executors import LiveExecutor, SimExecutor
from repro.reshard.overlap import OverlapSession
from repro.reshard.wire import WirePolicy, wire_nbytes

__all__ = [
    "ReshardEngine",
    "StreamStats",
    "DEFAULT_STAGING_BYTES",
    "SimExecutor",
    "LiveExecutor",
    "OverlapSession",
    "OperatingPoint",
    "WirePolicy",
    "chunk_task",
    "row_batches",
    "tune_operating_point",
    "wire_nbytes",
]
