"""Plan-driven reshard engine (paper §4.6, Algorithm 1) — one subsystem
behind both execution backends.

The planner (core/intersection.py) emits a :class:`TransferPlan`; this
package executes it:

  * :class:`ReshardEngine`   — backend-agnostic Algorithm 1 driver: layer
    ordering, staging-budget chunking (Theorem 1 accounting), barriers,
    :class:`StreamStats` byte/phase accounting.
  * :class:`SimExecutor`     — multi-rank byte-level oracle over
    ``RankStore`` numpy shards (the semantics reference; property-tested).
  * :class:`LiveExecutor`    — the live path over global ``jax.Array``s:
    deduplicates replica fan-out, merges plan cells into contiguous
    row-range groups, routes them through the Pallas ``pack_rows`` /
    ``unpack_rows`` kernels (interpret / reference mode on CPU) with a
    ``device_put`` + dynamic-update-slice fallback.
  * :class:`OverlapSession`  — overlapped layer streaming for the live
    controller: K layers per iteration boundary (pre-copy), dirty-layer
    re-sync, residual-tail commit (DESIGN.md §9).

See DESIGN.md §9 for the architecture and the commit protocol.
"""

from repro.reshard.chunking import chunk_task, row_batches
from repro.reshard.engine import ReshardEngine, StreamStats, DEFAULT_STAGING_BYTES
from repro.reshard.executors import LiveExecutor, SimExecutor
from repro.reshard.overlap import OverlapSession

__all__ = [
    "ReshardEngine",
    "StreamStats",
    "DEFAULT_STAGING_BYTES",
    "SimExecutor",
    "LiveExecutor",
    "OverlapSession",
    "chunk_task",
    "row_batches",
]
