"""Plan-driven reshard engine (paper §4.6, Algorithm 1) — one subsystem
behind both execution backends.

The planner (core/intersection.py) emits a :class:`TransferPlan`; this
package executes it:

  * :class:`ReshardEngine`   — backend-agnostic Algorithm 1 driver: layer
    ordering, staging-budget chunking (Theorem 1 accounting), barriers,
    :class:`StreamStats` byte/phase accounting.
  * :class:`SimExecutor`     — multi-rank byte-level oracle over
    ``RankStore`` numpy shards (the semantics reference; property-tested).
  * :class:`LiveExecutor`    — the live path over global ``jax.Array``s:
    deduplicates replica fan-out, merges plan cells into contiguous
    row-range groups, and moves each staging batch as a small fixed
    number of compiled programs — Pallas ``pack_rows`` gather, staged
    ``device_put``, overwrite-semantics ``scatter_rows`` into the
    donated destination carry (interpret / reference mode on CPU) —
    with a ``device_put`` + dynamic-update-slice path for contiguous
    runs and generic cells. Dispatch-only: callers own every barrier.
  * :class:`OverlapSession`  — asynchronous, double-buffered layer
    streaming for the live controller: K layers dispatched per
    iteration boundary (pre-copy), at most one round's scatters in
    flight, dirty-layer re-sync overlapped with the final grad
    computation, single drain at commit (DESIGN.md §9).

See DESIGN.md §9 for the architecture and the commit protocol.
"""

from repro.reshard.chunking import chunk_task, row_batches
from repro.reshard.engine import ReshardEngine, StreamStats, DEFAULT_STAGING_BYTES
from repro.reshard.executors import LiveExecutor, SimExecutor
from repro.reshard.overlap import OverlapSession

__all__ = [
    "ReshardEngine",
    "StreamStats",
    "DEFAULT_STAGING_BYTES",
    "SimExecutor",
    "LiveExecutor",
    "OverlapSession",
    "chunk_task",
    "row_batches",
]
