"""Overlapped layer streaming for live reconfiguration (DESIGN.md §9).

Stop-copy moves the entire state inside the commit pause. An
:class:`OverlapSession` instead streams the plan's layers *between*
training steps while the Active World keeps stepping (pre-copy rounds),
tracks which layers the optimizer dirtied afterwards (a layer streamed at
step ``s`` is stale once the optimizer has stepped past ``s``), and
re-syncs only the dirty set at commit time — ideally overlapped with the
final gradient computation, so the blocking pause shrinks to the residual
tail plus the pointer swap.

Note the honest limit: under a dense optimizer (AdamW updates every
element every step) a pre-copied layer is always dirty by commit, so
pre-copy rounds cannot reduce commit *bytes* — what shrinks the pause is
re-syncing those bytes concurrently with the last step's gradient
computation (split-step commit, LiveRController._split_step_commit) while
destination storage and copy executables are already warm. With sparse or
infrequent updates (embedding rows, frozen adapters, accumulation
windows) the dirty set genuinely shrinks and pre-copy pays off directly;
the per-round byte accounting below reports both regimes truthfully.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.core.intersection import TransferPlan
from repro.core.resource_view import TensorSpec
from repro.reshard.engine import ReshardEngine, StreamStats
from repro.reshard.executors import LiveExecutor


@dataclass
class OverlapReport:
    precopy_rounds: int = 0
    precopy_bytes: int = 0
    precopy_seconds: float = 0.0
    resync_layers: int = 0
    resync_bytes: int = 0
    resync_seconds: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.precopy_bytes + self.resync_bytes


class OverlapSession:
    """Drives one live reconfiguration's streaming across iteration
    boundaries. The controller owns the schedule (when boundaries happen);
    the session owns what moves at each one."""

    def __init__(
        self,
        specs: list[TensorSpec],
        plan: TransferPlan,
        src_leaves: dict[str, Any],
        target_shardings: dict[str, Any],
        staging_bytes: int,
        stream_k: int = 4,
    ):
        self.spec_map = {s.name: s for s in specs}
        self.plan = plan
        self.executor = LiveExecutor(
            self.spec_map, src_leaves, target_shardings, staging_bytes
        )
        self.engine = ReshardEngine(plan, self.executor, staging_bytes)
        self.stream_k = max(1, stream_k)
        self.pending: list[int] = self.engine.layers()
        self.streamed_at: dict[int, int] = {}
        self.stats = StreamStats()
        self.report = OverlapReport()

    @property
    def done_precopy(self) -> bool:
        return not self.pending

    def dirty_layers(self, step: int) -> list[int]:
        """Layers whose stream predates the optimizer's latest update."""
        return sorted(l for l, s in self.streamed_at.items() if s < step)

    # ------------------------------------------------------------------
    def stream_next(self, src_leaves: dict[str, Any], step: int) -> int:
        """One pre-copy round at an iteration boundary: stream the next K
        pending layers from the current state. Returns layers streamed."""
        if not self.pending:
            return 0
        batch, self.pending = self.pending[: self.stream_k], self.pending[self.stream_k :]
        self.executor.update_sources(src_leaves)
        t0 = time.perf_counter()
        s = self.engine.run(batch)
        self.executor.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats.merge(s)
        for l in batch:
            self.streamed_at[l] = step
        self.report.precopy_rounds += 1
        self.report.precopy_bytes += s.network_bytes + s.local_bytes
        self.report.precopy_seconds += dt
        return len(batch)

    def resync(self, src_leaves: dict[str, Any], step: int) -> StreamStats:
        """Re-stream every dirty layer (plus any remaining pending tail)
        from the boundary-consistent state at ``step``. After this, the
        destination holds a byte-exact copy of the step-``step`` cut."""
        layers = sorted(set(self.dirty_layers(step)) | set(self.pending))
        self.pending = []
        self.executor.update_sources(src_leaves)
        self.executor.reset_round()
        t0 = time.perf_counter()
        s = self.engine.run(layers)
        self.executor.block_until_ready()
        dt = time.perf_counter() - t0
        self.stats.merge(s)
        for l in layers:
            self.streamed_at[l] = step
        self.report.resync_layers += len(layers)
        self.report.resync_bytes += s.network_bytes + s.local_bytes
        self.report.resync_seconds += dt
        return s

    def results(self) -> dict[str, Any]:
        return self.executor.results()
