"""Overlapped layer streaming for live reconfiguration (DESIGN.md §9).

Stop-copy moves the entire state inside the commit pause. An
:class:`OverlapSession` instead streams the plan's layers *between*
training steps while the Active World keeps stepping (pre-copy rounds),
tracks which layers the optimizer dirtied afterwards (a layer streamed at
step ``s`` is stale once the optimizer has stepped past ``s``), and
re-syncs only the dirty set at commit time — ideally overlapped with the
final gradient computation, so the blocking pause shrinks to the residual
tail plus the pointer swap.

Rounds are **asynchronous and double-buffered**: ``stream_next`` only
dispatches a round's pack/put/scatter programs, then (a) waits for the
round's *staging* buffers to materialize — the point after which the round
no longer reads its source leaves, so the next train step may donate them
— and (b) drains the round-before-last's destination writes, keeping at
most one round's scatters in flight. The full barrier exists only at
``resync``/``drain`` (commit). The invariant that makes this safe: a
staging buffer is reusable (and its sources donatable) only after the
scatter consuming it has been *dispatched* — which ``stream_next``
guarantees by ordering the scatter dispatch before ``sync_staging``.

Note the honest limit: under a dense optimizer (AdamW updates every
element every step) a pre-copied layer is always dirty by commit, so
pre-copy rounds cannot reduce commit *bytes* — what shrinks the pause is
re-syncing those bytes concurrently with the last step's gradient
computation (split-step commit, LiveRController._split_step_commit) while
destination storage and copy executables are already warm. With sparse or
infrequent updates (embedding rows, frozen adapters, accumulation
windows) the dirty set genuinely shrinks and pre-copy pays off directly;
the per-round byte accounting below reports both regimes truthfully.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.core.intersection import TransferPlan
from repro.core.records import ReuseRecordMixin
from repro.core.resource_view import TensorSpec
from repro.reshard.engine import ReshardEngine, StreamStats
from repro.reshard.executors import LiveExecutor


def _layout_agrees(sh_old, sh_new, shape: tuple) -> bool:
    """True when two shardings lay the same logical shape out identically
    on the same devices — carries transfer between them zero-copy. Sharding
    equality is sufficient; otherwise compare the device→index maps (two
    NamedShardings over differently-factored meshes can still place every
    byte identically, e.g. fully-replicated tensors on the same device
    set)."""
    if sh_old is sh_new or sh_old == sh_new:
        return True
    try:
        return sh_old.devices_indices_map(shape) == sh_new.devices_indices_map(shape)
    except Exception:
        return False


def _carry_alive(leaf) -> bool:
    """True when every buffer backing ``leaf`` is still readable.
    ``is_deleted()`` alone is not enough: a zero-copy alias shares
    buffers with the leaf it aliases, and a donating train step deletes
    those buffers without marking the alias object itself deleted."""
    try:
        if getattr(leaf, "is_deleted", None) and leaf.is_deleted():
            return False
        for s in getattr(leaf, "addressable_shards", ()):
            data = s.data
            if data is None:
                return False
            if hasattr(data, "is_deleted") and data.is_deleted():
                return False
    except Exception:
        return False
    return True


@dataclass
class OverlapReport(ReuseRecordMixin):
    # reused_layers / resident_layers / skipped_bytes come from the shared
    # ReuseRecordMixin: resident layers never stream; adopt() adds layers
    # inherited from a superseded session at retarget
    precopy_rounds: int = 0
    precopy_bytes: int = 0
    precopy_seconds: float = 0.0
    resync_layers: int = 0
    resync_bytes: int = 0
    resync_seconds: float = 0.0
    # dispatch-vs-drain attribution across all rounds (pre-copy + re-sync):
    # dispatch = host time issuing device programs, drain = blocking waits
    # (staging syncs, double-buffer backpressure, final commit drain)
    dispatch_seconds: float = 0.0
    drain_seconds: float = 0.0

    @property
    def total_bytes(self) -> int:
        return self.precopy_bytes + self.resync_bytes


class OverlapSession:
    """Drives one live reconfiguration's streaming across iteration
    boundaries. The controller owns the schedule (when boundaries happen);
    the session owns what moves at each one."""

    def __init__(
        self,
        specs: list[TensorSpec],
        plan: TransferPlan,
        src_leaves: dict[str, Any],
        target_shardings: dict[str, Any],
        staging_bytes: int,
        stream_k: int = 4,  # documented fallback; autotune picks per-window
        max_inflight_rounds: int = 2,
        wire_policy=None,
        wire_bw_bytes_s: float | None = None,
    ):
        self.spec_map = {s.name: s for s in specs}
        self.plan = plan
        self.executor = LiveExecutor(
            self.spec_map, src_leaves, target_shardings, staging_bytes,
            wire_policy=wire_policy, wire_bw_bytes_s=wire_bw_bytes_s,
        )
        self.engine = ReshardEngine(
            plan, self.executor, staging_bytes, wire_policy=wire_policy
        )
        self.stream_k = max(1, stream_k)
        self.max_inflight_rounds = max(1, max_inflight_rounds)
        # fully-resident layers never enter the pre-copy schedule: their
        # bytes are already in place and the commit-time resync refreshes
        # them from the final cut with a near-free aliasing pass-through
        # (re-classification, not a re-stream — DESIGN.md §13)
        resident = set(plan.resident_layers())
        self.resident_layers: list[int] = sorted(
            l for l in self.engine.layers() if l in resident
        )
        self.pending: list[int] = [
            l for l in self.engine.layers() if l not in resident
        ]
        self.streamed_at: dict[int, int] = {}
        self.stats = StreamStats()
        self.report = OverlapReport()
        self.report.resident_layers = len(self.resident_layers)
        self.report.reused_layers = len(self.resident_layers)
        # rounds whose destination writes may still be in flight: each
        # entry is the set of tensor names the round touched
        self._inflight: list[set[str]] = []

    @property
    def done_precopy(self) -> bool:
        return not self.pending

    # ------------------------------------------------------------------
    def adopt(
        self,
        carries: dict[str, Any],
        old_targets: dict[str, Any],
        streamed_at: dict[int, int],
    ) -> int:
        """Retarget reuse (DESIGN.md §10): seed this session from a
        superseded session's already-streamed intersection state instead of
        restarting the stream from scratch.

        A destination carry is a *global* array — its streamed rows hold the
        step-``s`` values of the logical tensor regardless of which plan
        decomposition wrote them — so carries transfer between targets:
        zero-copy where the old and new target shardings agree
        (:func:`_layout_agrees`), via a single device-side relayout
        (``device_put``) where they do not; both are cheaper than re-pulling
        the bytes from the source through the engine. A layer counts as
        already streamed iff the old session streamed it, and keeps its
        original ``streamed_at`` step so the commit-time dirty re-sync still
        refreshes anything the optimizer has since touched (reuse shortens
        the pre-copy schedule — time-to-commit under a deadline — never the
        re-sync correctness). Returns the number of reused layers.

        Must be called before the first ``stream_next``; the caller is
        responsible for having drained the old session first (its scatters
        must have landed before its carries are re-homed)."""
        import jax

        assert not self.streamed_at, "adopt() must precede streaming"
        adopted: set[str] = set()
        relayout: list[tuple[str, Any, Any]] = []  # (name, leaf, sh_new)
        for name, sh_new in self.executor.target_shardings.items():
            leaf = carries.get(name)
            sh_old = old_targets.get(name)
            if leaf is None or sh_old is None:
                continue
            spec = self.spec_map.get(name)
            if spec is None or tuple(leaf.shape) != tuple(spec.shape):
                continue
            if not _carry_alive(leaf):
                # a superseded carry can be a zero-copy alias of a live
                # leaf (resident pass-through) that a donating train step
                # has since consumed — unadoptable, so its layers simply
                # re-stream
                continue
            if _layout_agrees(sh_old, sh_new, tuple(leaf.shape)):
                self.executor.dst[name] = leaf
            else:
                relayout.append((name, leaf, sh_new))
            adopted.add(name)
        if relayout:
            # one batched relayout: device_put takes a pytree of arrays and
            # a matching pytree of shardings, so every mismatched carry
            # moves in a single dispatch instead of one host round-trip
            # per leaf
            try:
                moved = jax.device_put(
                    [leaf for _, leaf, _ in relayout],
                    [sh for _, _, sh in relayout],
                )
                for (name, _, _), leaf in zip(relayout, moved):
                    self.executor.dst[name] = leaf
            except RuntimeError:
                # a carry died between the liveness probe and the dispatch
                # (an alias whose shared buffers a train step donated);
                # retry per-leaf so one dead carry doesn't void the batch
                for name, leaf, sh in relayout:
                    try:
                        self.executor.dst[name] = jax.device_put(leaf, sh)
                    except RuntimeError:
                        adopted.discard(name)
        # a layer is reused iff the old session streamed it AND every
        # tensor its tasks touch has an adopted carry
        reused = [
            l
            for l in self.pending
            if l in streamed_at
            and {t.tensor for t in self.plan.by_layer(l)} <= adopted
        ]
        for l in reused:
            self.pending.remove(l)
            self.streamed_at[l] = streamed_at[l]
        # += : resident layers were already counted as reused at __init__
        self.report.reused_layers += len(reused)
        return len(reused)

    def dirty_layers(self, step: int) -> list[int]:
        """Layers whose stream predates the optimizer's latest update."""
        return sorted(l for l, s in self.streamed_at.items() if s < step)

    # ------------------------------------------------------------------
    def _drain_rounds(self, keep: int) -> float:
        """Block until all but the newest ``keep`` rounds' destination
        writes have landed. Later rounds donate earlier carries, so
        round-granular handles cannot be kept; tensors a newer in-flight
        round re-touched are skipped — their current dst leaf is the newer
        round's output, and waiting on it would degenerate double buffering
        into a full per-round barrier for stacked tensors that span every
        round. Those tensors' backpressure comes from the executor's
        bounded staging instead (per-device program order retires their
        scatters before anything newer)."""
        t0 = time.perf_counter()
        while len(self._inflight) > keep:
            names = self._inflight.pop(0)
            for newer in self._inflight:
                names -= newer
            for n in names:
                leaf = self.executor.dst.get(n)
                if leaf is not None and hasattr(leaf, "block_until_ready"):
                    leaf.block_until_ready()
        return time.perf_counter() - t0

    def drain(self) -> float:
        """Full barrier: every dispatched round has landed. The only sync
        points are here and in ``resync`` — commit-time calls."""
        dt = self._drain_rounds(0)
        t0 = time.perf_counter()
        self.executor.block_until_ready()
        return dt + (time.perf_counter() - t0)

    # ------------------------------------------------------------------
    def stream_next(self, src_leaves: dict[str, Any], step: int) -> int:
        """One pre-copy round at an iteration boundary: dispatch the next K
        pending layers from the current state, wait only until the round's
        staging is materialized (sources safe to donate) and the
        round-before-last has drained (double buffering). Returns layers
        streamed."""
        if not self.pending:
            return 0
        batch, self.pending = self.pending[: self.stream_k], self.pending[self.stream_k :]
        self.executor.update_sources(src_leaves)
        self.executor.begin_round()
        t0 = time.perf_counter()
        s = self.engine.run(batch)
        dispatch_dt = time.perf_counter() - t0
        self._inflight.append(self.executor.round_touched())
        t1 = time.perf_counter()
        self.executor.sync_staging()
        drain_dt = time.perf_counter() - t1
        drain_dt += self._drain_rounds(self.max_inflight_rounds - 1)
        s.drain_seconds += drain_dt
        self.stats.merge(s)
        for l in batch:
            self.streamed_at[l] = step
        self.report.precopy_rounds += 1
        self.report.precopy_bytes += s.network_bytes + s.local_bytes
        # skipped bytes accrue per resident CELL — partially-resident layers
        # contribute here without counting in resident_layers (the
        # skipped_bytes ⟺ resident_cells identity, core/records.py)
        self.report.skipped_bytes += s.resident_bytes
        self.report.resident_cells += s.resident_cells
        self.report.logical_bytes += s.logical_bytes
        self.report.wire_bytes += s.wire_bytes
        self.report.precopy_seconds += dispatch_dt + drain_dt
        # the engine self-reports pure dispatch; staging backpressure hit
        # inside its loop belongs on the drain side
        self.report.dispatch_seconds += s.dispatch_seconds
        self.report.drain_seconds += drain_dt + max(
            0.0, dispatch_dt - s.dispatch_seconds
        )
        return len(batch)

    def resync(
        self, src_leaves: dict[str, Any], step: int, drain: bool = True
    ) -> StreamStats:
        """Re-stream every dirty layer (plus any remaining pending tail)
        from the boundary-consistent state at ``step``. After this, the
        destination holds a byte-exact copy of the step-``step`` cut.
        With ``drain=False`` only the dispatch (and the staging sync that
        frees the sources) happens — the caller overlaps the scatter drain
        with other work and must call :meth:`drain` before consuming
        :meth:`results`."""
        # resident layers join every resync: their refresh is a re-classify
        # (an aliasing pass-through from the step-``step`` cut), never a
        # byte re-stream — even when the optimizer dirtied them
        layers = sorted(
            set(self.dirty_layers(step))
            | set(self.pending)
            | set(self.resident_layers)
        )
        self.pending = []
        self.executor.update_sources(src_leaves)
        self.executor.reset_round()
        self.executor.begin_round()
        t0 = time.perf_counter()
        s = self.engine.run(layers)
        dispatch_dt = time.perf_counter() - t0
        self._inflight.append(self.executor.round_touched())
        t1 = time.perf_counter()
        self.executor.sync_staging()
        drain_dt = time.perf_counter() - t1
        if drain:
            drain_dt += self.drain()
        s.drain_seconds += drain_dt
        self.stats.merge(s)
        for l in layers:
            self.streamed_at[l] = step
        self.report.resync_layers += len(layers)
        self.report.resync_bytes += s.network_bytes + s.local_bytes
        self.report.skipped_bytes += s.resident_bytes
        self.report.resident_cells += s.resident_cells
        self.report.logical_bytes += s.logical_bytes
        self.report.wire_bytes += s.wire_bytes
        self.report.resync_seconds += dispatch_dt + drain_dt
        self.report.dispatch_seconds += s.dispatch_seconds
        self.report.drain_seconds += drain_dt + max(
            0.0, dispatch_dt - s.dispatch_seconds
        )
        return s

    def results(self) -> dict[str, Any]:
        return self.executor.results()
