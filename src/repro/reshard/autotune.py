"""Self-tuning operating point for the streaming data plane (DESIGN.md §14).

The fallback lattice (elastic/scheduler.py) picks a *rung* — stream,
stop-copy, checkpoint — but until now the rung's *operating point* was
hand-set: ``stream_k = 4`` layers per pre-copy round (overlap.py) and the
paper's 512 MB staging budget (engine.py). Both are now documented
fallbacks: when the :class:`~repro.elastic.scheduler.DeadlineEstimator`
has measured bandwidth and step-time history, :func:`tune_operating_point`
derives the round size, chunk size and staging budget for a specific
(plan remote bytes, warning window) pair.

The tuning model is deliberately simple and monotone:

* A pre-copy round should take a bounded fraction of the window
  (``ROUND_WINDOW_FRAC``), so tight windows run many small rounds — each
  iteration boundary is a deadline check and an abort point — while wide
  windows amortize per-round staging syncs over more layers.
  ``stream_k = bytes_per_round / bytes_per_layer``, clamped to the plan.
* A chunk should take a bounded fraction of the window on the measured
  wire (``CHUNK_WINDOW_FRAC``), clamped between 1 MB and the fallback
  budget: backpressure granularity tracks how much slack the window has.
* The staging budget pins ``STAGING_DEPTH`` chunks (double buffering plus
  headroom), never exceeding the paper's 512 MB default.

Every derived quantity is a clamp of a function non-decreasing in
``window_s`` at fixed bytes/bandwidth, so the chosen ``stream_k`` and
chunk size are monotone non-decreasing in window size — the property the
tuner tests pin.

With no measured bandwidth (cold estimator, duck-typed test controllers)
the tuner returns the historical constants with ``source="fallback"``.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.reshard.engine import DEFAULT_STAGING_BYTES

# fraction of the warning window one pre-copy round may spend on the wire
ROUND_WINDOW_FRAC = 0.10
MIN_ROUND_S = 0.05
MAX_ROUND_S = 30.0
# fraction of the window one staged chunk may spend on the wire
CHUNK_WINDOW_FRAC = 0.01
MIN_CHUNK_S = 0.01
MAX_CHUNK_S = 2.0
MIN_CHUNK_BYTES = 1 << 20  # 1 MB
# staged chunks the budget should hold: two pinned by double buffering,
# plus headroom so backpressure does not serialize dispatch
STAGING_DEPTH = 4
FALLBACK_STREAM_K = 4


@dataclass(frozen=True)
class OperatingPoint:
    """One rung's tuned data-plane parameters."""

    stream_k: int
    chunk_bytes: int
    staging_bytes: int
    source: str  # "measured" | "fallback"

    def to_dict(self) -> dict:
        return {
            "stream_k": self.stream_k,
            "chunk_bytes": self.chunk_bytes,
            "staging_bytes": self.staging_bytes,
            "source": self.source,
        }


FALLBACK = OperatingPoint(
    stream_k=FALLBACK_STREAM_K,
    chunk_bytes=DEFAULT_STAGING_BYTES,
    staging_bytes=DEFAULT_STAGING_BYTES,
    source="fallback",
)


def _clamp(x: float, lo: float, hi: float) -> float:
    return max(lo, min(hi, x))


def tune_operating_point(
    plan_bytes: int,
    layers: int,
    window_s: float,
    bw_bytes_s: float | None,
    step_s: float | None = None,
) -> OperatingPoint:
    """Pick (stream_k, chunk_bytes, staging_bytes) for one reconfiguration.

    ``plan_bytes``/``layers`` describe the remote (wire-priced) work the
    plan still has to move; ``window_s`` is the warning window;
    ``bw_bytes_s`` the estimator's measured effective bandwidth (None or
    <= 0 → fallback constants). ``step_s`` is accepted for interface
    completeness (round pacing is boundary-driven, so the window fraction
    already encodes it).
    """
    del step_s
    if not bw_bytes_s or bw_bytes_s <= 0 or plan_bytes <= 0 or layers <= 0:
        return FALLBACK
    window_s = max(0.0, float(window_s))

    round_s = _clamp(window_s * ROUND_WINDOW_FRAC, MIN_ROUND_S, MAX_ROUND_S)
    bytes_per_round = bw_bytes_s * round_s
    bytes_per_layer = max(1.0, plan_bytes / layers)
    stream_k = int(_clamp(round(bytes_per_round / bytes_per_layer), 1, layers))

    chunk_s = _clamp(window_s * CHUNK_WINDOW_FRAC, MIN_CHUNK_S, MAX_CHUNK_S)
    chunk_bytes = int(
        _clamp(bw_bytes_s * chunk_s, MIN_CHUNK_BYTES, DEFAULT_STAGING_BYTES)
    )
    staging_bytes = int(
        _clamp(chunk_bytes * STAGING_DEPTH, chunk_bytes, DEFAULT_STAGING_BYTES)
    )
    return OperatingPoint(
        stream_k=stream_k,
        chunk_bytes=chunk_bytes,
        staging_bytes=staging_bytes,
        source="measured",
    )
