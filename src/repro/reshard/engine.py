"""Backend-agnostic Algorithm 1 driver (paper §4.6.2).

The engine owns everything that is *protocol*: layer ordering, per-layer
barriers, staging-budget chunking and its bounded-memory accounting
(Theorem 1), and the byte/phase statistics. Executors own everything that
is *mechanism*: how one planned chunk's bytes actually move (numpy shard
copies for the sim oracle, jax.Array relayouts for the live path).

One engine + plan therefore produces identical `StreamStats` byte
accounting regardless of backend — the "plan-vs-live agreement" the
benchmarks report.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Iterable, Optional, Protocol

from repro.core.errors import RecoveryError
from repro.core.intersection import TransferPlan, TransferTask
from repro.reshard.chunking import chunk_task
from repro.reshard.wire import wire_nbytes

# Documented fallback (paper default B = 512 MB): the autotuner
# (repro.reshard.autotune) picks a measured staging budget per reconfig
# when bandwidth history exists; this constant is what every path uses
# when it does not.
DEFAULT_STAGING_BYTES = 512 * 1024 * 1024


@dataclass
class StreamStats:
    layers_streamed: int = 0
    network_bytes: int = 0
    local_bytes: int = 0
    # compressed wire format (DESIGN.md §14): logical_bytes is what the plan
    # says streamed (== network_bytes), wire_bytes is what physically crossed
    # the interconnect under the wire policy (quantized payload + sidecar
    # scales; equal to logical_bytes when lossless) — the ratio of the two is
    # the stream's compression factor
    wire_bytes: int = 0
    logical_bytes: int = 0
    # bytes whose cells were classified resident: already in place on the
    # right device, counted here and moved nowhere (DESIGN.md §13)
    resident_bytes: int = 0
    resident_cells: int = 0
    peak_staging_bytes: int = 0
    barriers: int = 0
    chunks: int = 0
    per_layer_bytes: dict[int, int] = field(default_factory=dict)
    # backend-reported: bytes the executor physically moved (the live path
    # moves each deduplicated region once; the sim oracle moves per-rank)
    executed_bytes: int = 0
    seconds: float = 0.0
    # host time spent issuing the round's device programs (engine loop) vs
    # waiting for them to land — the async data plane's win is dispatch
    # shrinking while drain overlaps useful work. Filled by the engine
    # (dispatch) and whichever caller performs the blocking wait (drain).
    dispatch_seconds: float = 0.0
    drain_seconds: float = 0.0
    # cells that fell off the row-merge fast path onto the generic per-cell
    # fallback (surfaced so slow-path regressions show up in benchmarks)
    generic_cells: int = 0

    def assert_bounded(self, budget: int) -> None:
        assert self.peak_staging_bytes <= budget, (
            f"staging {self.peak_staging_bytes} exceeded budget {budget} "
            "(Theorem 1 violated)"
        )

    def merge(self, other: "StreamStats") -> None:
        self.layers_streamed += other.layers_streamed
        self.network_bytes += other.network_bytes
        self.local_bytes += other.local_bytes
        self.wire_bytes += other.wire_bytes
        self.logical_bytes += other.logical_bytes
        self.resident_bytes += other.resident_bytes
        self.resident_cells += other.resident_cells
        self.peak_staging_bytes = max(
            self.peak_staging_bytes, other.peak_staging_bytes
        )
        self.barriers += other.barriers
        self.chunks += other.chunks
        for k, v in other.per_layer_bytes.items():
            self.per_layer_bytes[k] = self.per_layer_bytes.get(k, 0) + v
        self.executed_bytes += other.executed_bytes
        self.seconds += other.seconds
        self.dispatch_seconds += other.dispatch_seconds
        self.drain_seconds += other.drain_seconds
        self.generic_cells += other.generic_cells


class Executor(Protocol):
    """What a backend must provide; all protocol logic stays in the engine."""

    def begin_layer(self, layer: int) -> None: ...

    def apply(self, chunk: TransferTask) -> None: ...

    def end_layer(self, layer: int) -> None: ...

    @property
    def executed_bytes(self) -> int: ...


class ReshardEngine:
    """Execute a TransferPlan through a pluggable executor, one layer at a
    time, with bounded staging (Algorithm 1)."""

    def __init__(
        self,
        plan: TransferPlan,
        executor,
        staging_bytes: int = DEFAULT_STAGING_BYTES,
        zero_copy_local: bool = True,
        delta: bool = True,
        wire_policy=None,
    ):
        self.plan = plan
        self.executor = executor
        self.staging_bytes = staging_bytes
        self.zero_copy_local = zero_copy_local
        # delta=False demotes resident cells to the pre-classification local
        # path — the full-copy baseline benchmarks compare against
        self.delta = delta
        # None = fully lossless wire (the byte-oracle default); a WirePolicy
        # quantizes remote chunks of its configured collections on the wire,
        # shrinking both staged bytes and the staging budget they count
        # against (Theorem 1 bounds *wire* bytes — that is what is staged)
        self.wire_policy = wire_policy

    def layers(self) -> list[int]:
        return self.plan.layers()

    def run(self, layers: Optional[Iterable[int]] = None) -> StreamStats:
        """Stream the given layers (default: all, ascending; -1 = non-layer
        state first). Each layer ends with a barrier; the staging buffer is
        reused across layers so peak memory never scales with model size."""
        stats = StreamStats()
        t0 = time.perf_counter()
        run_layers = list(self.layers() if layers is None else layers)
        # source-release schedule: a tensor's sources may be freed after its
        # last layer of THIS run (only executors that opted in act on it)
        release = getattr(self.executor, "release", None)
        releasable: dict[int, list[str]] = {}
        if release is not None:
            in_run = set(run_layers)
            last_layer: dict[str, int] = {}
            for t in self.plan.tasks:
                if t.layer in in_run and t.layer >= last_layer.get(t.tensor, -(1 << 62)):
                    last_layer[t.tensor] = t.layer
            for name, ll in last_layer.items():
                releasable.setdefault(ll, []).append(name)
        exec0 = getattr(self.executor, "executed_bytes", 0)
        gen0 = getattr(self.executor, "generic_cells", 0)
        wait0 = getattr(self.executor, "stage_wait_seconds", 0.0)
        for layer in run_layers:
            self.run_layer(layer, stats)
            for name in releasable.get(layer, ()):
                release(name)
        stats.seconds = time.perf_counter() - t0
        # the engine loop only *issues* work on an async backend — except
        # staging backpressure, which the executor self-reports so those
        # blocked seconds land on the drain side of the attribution
        waited = getattr(self.executor, "stage_wait_seconds", 0.0) - wait0
        stats.dispatch_seconds = stats.seconds - waited
        stats.drain_seconds += waited
        # delta, not lifetime total: the same executor may serve many runs
        # (overlap pre-copy rounds) and per-run stats are merged downstream
        stats.executed_bytes = getattr(self.executor, "executed_bytes", 0) - exec0
        stats.generic_cells = getattr(self.executor, "generic_cells", 0) - gen0
        return stats

    def run_layer(self, layer: int, stats: StreamStats) -> None:
        tasks = self.plan.by_layer(layer)
        if not tasks:
            return
        self.executor.begin_layer(layer)
        # group by destination rank — each dst drains its own staging buffer
        by_dst: dict[int, list[TransferTask]] = {}
        for t in tasks:
            by_dst.setdefault(t.dst_rank, []).append(t)
        for dst_rank, dtasks in by_dst.items():
            staging_used = 0
            for task in dtasks:
                if task.kind == "lost":
                    # survivor-constrained plan with an unrepaired hole
                    # (DESIGN.md §15): executing it would read a dead rank.
                    raise RecoveryError(
                        f"plan has a lost cell for {task.tensor} dst rank "
                        f"{task.dst_rank} ({task.nbytes} bytes): no surviving "
                        "source; repair from parity or fall back before "
                        "executing"
                    )
                if task.resident:
                    if self.delta:
                        # bytes already in place: account, never chunk/move
                        self.executor.apply(task)
                        stats.resident_bytes += task.nbytes
                        stats.resident_cells += 1
                        continue
                    # full-copy baseline: demote to the pre-classification
                    # local path so the executor physically moves the bytes
                    task = dataclasses.replace(task, kind="local")
                if task.local and self.zero_copy_local:
                    self.executor.apply(task)
                    stats.local_bytes += task.nbytes
                    continue
                for chunk in chunk_task(task, self.staging_bytes, self.wire_policy):
                    stats.chunks += 1
                    staged = wire_nbytes(self.wire_policy, chunk)
                    if staging_used + staged > self.staging_bytes:
                        # flush: everything staged so far is assembled into
                        # the destination shard; buffer is reused
                        staging_used = 0
                    staging_used += staged
                    stats.peak_staging_bytes = max(
                        stats.peak_staging_bytes, staging_used
                    )
                    self.executor.apply(chunk)
                    stats.network_bytes += chunk.nbytes
                    stats.logical_bytes += chunk.nbytes
                    stats.wire_bytes += staged
            stats.per_layer_bytes[layer] = stats.per_layer_bytes.get(
                layer, 0
            ) + sum(t.nbytes for t in dtasks)
        self.executor.end_layer(layer)
        stats.barriers += 1
        stats.layers_streamed += 1
