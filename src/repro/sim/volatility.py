"""Volatility trace generation (paper §6.4 regimes, §6.5 24-h trace).

Deterministic (seeded) so benchmark outputs are reproducible.
"""

from __future__ import annotations

import numpy as np

REGIMES = {
    "low": 60 * 60.0,  # ~hourly events
    "medium": 30 * 60.0,
    "high": 10 * 60.0,
}


def make_trace(
    duration_s: float,
    mean_interval_s: float,
    world_choices: tuple[int, ...] = (16, 24, 32),
    seed: int = 0,
) -> list[tuple[float, int]]:
    """Poisson-ish arrival of resize events with jittered intervals."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out: list[tuple[float, int]] = []
    world = world_choices[-1]
    while True:
        t += rng.uniform(0.5, 1.5) * mean_interval_s
        if t >= duration_s:
            break
        choices = [w for w in world_choices if w != world]
        world = int(rng.choice(choices))
        out.append((t, world))
    return out


def spot_trace(
    duration_s: float,
    mean_interval_s: float,
    world_choices: tuple[int, ...] = (16, 24, 32),
    seed: int = 0,
    warning_s: float = 120.0,
    failstop_every: int = 5,
    emit_lost: bool = False,
) -> list[tuple]:
    """Spot-market style event stream for the live scheduler (paper §4.1).

    Like :func:`make_trace` but each row carries an event kind and warning
    window: resizes arrive with the spot notice (AWS's 2-minute default);
    every ``failstop_every``-th event is an unannounced fail-stop dropping
    to the smallest pool (warning 0). Rows are ``(t, world, kind,
    warning_s)`` — ``elastic.events_from_trace`` turns them into typed
    events with concrete topologies. With ``emit_lost=True`` fail-stop rows
    grow a fifth element naming the dead ranks (a seeded draw from the
    pre-failure world's upper ranks) so fault-injection replays get a
    deterministic peer-recovery donor geometry; resize rows keep the
    4-tuple shape either way.
    """
    rng = np.random.default_rng(seed)
    t = 0.0
    out: list[tuple] = []
    world = world_choices[-1]
    n = 0
    while True:
        t += rng.uniform(0.5, 1.5) * mean_interval_s
        if t >= duration_s:
            break
        n += 1
        if failstop_every and n % failstop_every == 0:
            prev = world
            world = min(world_choices)
            pool = list(range(world, prev))
            if emit_lost and pool:
                # prefix allocation: survivors are ranks 0..world-1, so the
                # dead set is drawn from the complement [world, prev)
                k = int(rng.integers(1, len(pool) + 1))
                lost = tuple(
                    sorted(int(r) for r in rng.choice(pool, size=k, replace=False))
                )
                out.append((t, world, "fail_stop", 0.0, lost))
            else:
                out.append((t, world, "fail_stop", 0.0))
        else:
            choices = [w for w in world_choices if w != world]
            world = int(rng.choice(choices))
            out.append((t, world, "resize", warning_s))
    return out


def paper_24h_trace(seed: int = 1) -> list[tuple[float, int]]:
    """~47 events over 24 h (paper Fig. 8: GPT-14B, 32 GPUs, 47 reconfigs)."""
    duration = 24 * 3600.0
    trace = make_trace(duration, duration / 48.0, seed=seed)
    return trace[:47]
