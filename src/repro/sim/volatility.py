"""Volatility trace generation (paper §6.4 regimes, §6.5 24-h trace).

Deterministic (seeded) so benchmark outputs are reproducible.
"""

from __future__ import annotations

import numpy as np

REGIMES = {
    "low": 60 * 60.0,  # ~hourly events
    "medium": 30 * 60.0,
    "high": 10 * 60.0,
}


def make_trace(
    duration_s: float,
    mean_interval_s: float,
    world_choices: tuple[int, ...] = (16, 24, 32),
    seed: int = 0,
) -> list[tuple[float, int]]:
    """Poisson-ish arrival of resize events with jittered intervals."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out: list[tuple[float, int]] = []
    world = world_choices[-1]
    while True:
        t += rng.uniform(0.5, 1.5) * mean_interval_s
        if t >= duration_s:
            break
        choices = [w for w in world_choices if w != world]
        world = int(rng.choice(choices))
        out.append((t, world))
    return out


def paper_24h_trace(seed: int = 1) -> list[tuple[float, int]]:
    """~47 events over 24 h (paper Fig. 8: GPT-14B, 32 GPUs, 47 reconfigs)."""
    duration = 24 * 3600.0
    trace = make_trace(duration, duration / 48.0, seed=seed)
    return trace[:47]
