from repro.sim.des import Simulator
from repro.sim.cluster import ClusterModel, PAPER_TESTBED, TPU_V5E_POD
from repro.sim.liver_sim import (
    reconfig_downtime,
    volatility_run,
    SystemKind,
)
from repro.sim.volatility import make_trace, REGIMES

__all__ = [
    "Simulator",
    "ClusterModel",
    "PAPER_TESTBED",
    "TPU_V5E_POD",
    "reconfig_downtime",
    "volatility_run",
    "SystemKind",
    "make_trace",
    "REGIMES",
]
