"""Minimal deterministic discrete-event simulation engine — the substrate
under the paper's §5 simulator (``sim/liver_sim.py``, Figs. 10–11).

(SimPy — used by the paper — is not installed here; this heapq-based engine
provides the same primitives we need: scheduled callbacks, processes as
generators yielding delays, and resources with FIFO queues.)
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Generator, Optional


class Simulator:
    def __init__(self):
        self.now: float = 0.0
        self._heap: list = []
        self._counter = itertools.count()

    def schedule(self, delay: float, fn: Callable, *args) -> None:
        assert delay >= 0
        heapq.heappush(self._heap, (self.now + delay, next(self._counter), fn, args))

    def process(self, gen: Generator) -> None:
        """Run a generator-style process: ``yield delay`` suspends."""

        def step(g):
            try:
                delay = next(g)
            except StopIteration:
                return
            self.schedule(float(delay), step, g)

        step(gen)

    def run(self, until: Optional[float] = None) -> float:
        while self._heap:
            t, _, fn, args = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return self.now
            heapq.heappop(self._heap)
            self.now = t
            fn(*args)
        if until is not None:
            self.now = until
        return self.now
