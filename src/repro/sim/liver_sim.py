"""Reconfiguration-system models over the DES engine: LiveR vs the two
checkpoint baselines (Megatron-LM Checkpoint restart, UCP reshape-on-load),
reproducing the paper's evaluation figures at arbitrary scale.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field

from repro.core.downtime import GoodputLedger
from repro.sim.cluster import ClusterModel, model_state_bytes
from repro.sim.des import Simulator


class SystemKind(str, enum.Enum):
    LIVER = "liver"
    MEGATRON_CKPT = "megatron_ckpt"
    UCP = "ucp"


@dataclass
class Downtime:
    phases: dict[str, float]

    @property
    def total(self) -> float:
        return sum(self.phases.values())


def reconfig_downtime(
    system: SystemKind,
    cluster: ClusterModel,
    params: float,
    world_before: int,
    world_after: int,
    move_fraction: float = 1.0,
    storage_bw_override: float | None = None,
) -> Downtime:
    """Downtime (training paused) for one resize event.

    LiveR streams the bf16 parameter state P2P (paper §6.3: ~28 GB for 14B);
    restart systems reload the FULL mixed-precision training state
    (≈10 B/param) from shared storage. move_fraction: fraction of state
    bytes that actually moves under the intersection plan (1.0 = worst case;
    the measured fraction for a given transition can be plugged in from
    core/intersection.py).
    """
    world = max(world_before, world_after)
    cl = cluster
    if storage_bw_override is not None:
        cl = _with_storage(cluster, storage_bw_override)

    if system is SystemKind.LIVER:
        live_state = model_state_bytes(params)  # bf16 params, P2P
        return Downtime(
            {
                "drain": cl.drain_s,
                "transfer": cl.transfer_s(live_state * move_fraction, world),
                "switch": cl.switch_s,
            }
        )
    full_state = model_state_bytes(params, with_optimizer=True)
    load = cl.ckpt_load_s(full_state, world_after)
    if system is SystemKind.UCP:
        load *= 0.55  # parallel reshape-on-load (paper: narrows reload gap)
    return Downtime(
        {
            "ckpt_load": load,
            "proc_spawn": cl.proc_spawn_s,
            "cuda_init": cl.cuda_init_s,
            "dist_init": cl.dist_init_s(world_after),
            "misc": cl.misc_s,
        }
    )


def _with_storage(cluster: ClusterModel, bw: float) -> ClusterModel:
    import dataclasses

    return dataclasses.replace(cluster, storage_bw_gbps_per_gpu=bw)


# ---------------------------------------------------------------------------
# Volatility runs (Figs. 7 & 8)
# ---------------------------------------------------------------------------


@dataclass
class VolatilityResult:
    ledger: GoodputLedger
    events: int
    reconfig_pause_s: float
    goodput: float
    wasted_gpu_hours: float


def volatility_run(
    system: SystemKind,
    cluster: ClusterModel,
    params: float,
    trace: list[tuple[float, int]],  # (event time, new world size)
    duration_s: float,
    initial_world: int,
    ckpt_interval_s: float = 300.0,
) -> VolatilityResult:
    """Discrete-event run of a volatility trace.

    Each event pauses training for the system's reconfiguration downtime.
    Checkpoint-based systems additionally *lose progress back to the last
    durable checkpoint* (the preemption warning is too short to finish a
    full distributed save, so they fall back — the paper's own baseline
    setting: "we choose to fallback to previous checkpoint"); the lost work
    is re-computed, accounted as idle GPU area. LiveR loses nothing (the
    live handoff preserves iteration N state) and pays only the measured
    0.28 % steady-state overhead while the shadow world prepares.
    """
    sim = Simulator()
    ledger = GoodputLedger()
    state = {"world": initial_world, "pause_total": 0.0}

    t_prev = 0.0
    events = sorted(trace)
    for ev_time, new_world in events:
        if ev_time >= duration_s:
            break
        if ev_time > t_prev:
            ledger.record(t_prev, ev_time, "train", state["world"])
        dt = reconfig_downtime(
            system, cluster, params, state["world"], new_world
        ).total
        if system is SystemKind.LIVER:
            prep = cluster.prepare_s(max(state["world"], new_world))
            dt += prep * cluster.steady_overhead
            lost = 0.0
        else:
            # progress since the last checkpoint is recomputed
            lost = min(ev_time - t_prev, (ev_time - t_prev) % ckpt_interval_s)
        end = min(ev_time + dt, duration_s)
        ledger.record(ev_time, end, "pause", max(state["world"], new_world))
        if lost > 0:
            ledger.record(end, end, "idle", 0)  # marker
            # recomputation: training time that produces no new progress
            redo_end = min(end + lost, duration_s)
            ledger.record(end, redo_end, "idle", new_world)
            end = redo_end
        state["pause_total"] += dt if ev_time + dt <= duration_s else duration_s - ev_time
        state["world"] = new_world
        t_prev = end
    if t_prev < duration_s:
        ledger.record(t_prev, duration_s, "train", state["world"])

    return VolatilityResult(
        ledger=ledger,
        events=len([e for e in events if e[0] < duration_s]),
        reconfig_pause_s=state["pause_total"],
        goodput=ledger.goodput,
        wasted_gpu_hours=ledger.wasted_gpu_hours(),
    )
