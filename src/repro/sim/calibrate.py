"""Host calibration for the simulator (paper §5 'Simulator Calibration').

Measures on THIS machine: process spawn, jax import+init, XLA compile-time
scaling with model size, and host memcpy/device_put bandwidth. Constants are
cached to JSON; the Fig. 10-style validation benchmark
(benchmarks/bench_simvalidate.py) compares simulator predictions against
live LiveR reconfigurations measured by the controller on host devices.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

CACHE = "results/calibration.json"


def measure(force: bool = False) -> dict:
    if not force and os.path.exists(CACHE):
        with open(CACHE) as f:
            return json.load(f)

    out: dict = {}

    # process spawn + interpreter boot
    t0 = time.perf_counter()
    subprocess.run([sys.executable, "-c", "pass"], check=True)
    out["proc_spawn_s"] = time.perf_counter() - t0

    # jax import + backend init in a fresh process
    t0 = time.perf_counter()
    subprocess.run(
        [sys.executable, "-c", "import jax; jax.devices()"], check=True
    )
    out["jax_init_s"] = time.perf_counter() - t0

    # host memcpy bandwidth (the staging-buffer assemble cost)
    buf = np.random.default_rng(0).random(64 * 1024 * 1024 // 8)
    t0 = time.perf_counter()
    for _ in range(4):
        _ = buf.copy()
    dt = (time.perf_counter() - t0) / 4
    out["memcpy_gbps"] = buf.nbytes / dt / 1e9 * 8

    # compile-time scaling: lower+compile a 2-layer block at two widths
    import jax

    import jax.numpy as jnp

    def compile_probe(d):
        def f(x, w1, w2):
            def body(c, _):
                return jnp.tanh(c @ w1) @ w2, None
            c, _ = jax.lax.scan(body, x, None, length=2)
            return c.sum()
        args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in ((8, d), (d, d), (d, d))]
        t0 = time.perf_counter()
        jax.jit(jax.grad(f, argnums=(1, 2))).lower(*args).compile()
        return time.perf_counter() - t0

    t_small, t_big = compile_probe(256), compile_probe(1024)
    out["compile_base_s"] = t_small
    out["compile_scale"] = max(t_big - t_small, 1e-3)

    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(out, f, indent=2)
    return out
