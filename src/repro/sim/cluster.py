"""Cluster hardware models for the reconfiguration simulator.

Two calibrations ship:
  * PAPER_TESTBED — the paper's 4×A800 (32 GPU) cluster, constants fitted to
    the paper's own measurements (Table 1 breakdown, §2.2.1's "~60 s init for
    32 GPUs/14B", §6.3's 2–4 s transfer for 28 GB) so the simulator can be
    validated against every published figure;
  * TPU_V5E_POD — this repo's deployment target, constants from the v5e
    datasheet + compile/restart timings measured on this host
    (sim/calibrate.py) scaled per DESIGN.md.

Distributed-init scaling follows the paper's observation that communicator
construction grows with world size (NCCL tree setup ~log + per-rank
handshakes ~linear).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ClusterModel:
    name: str
    gpus_per_node: int
    # training
    step_time_s_per_1e9_params: float  # iteration time scale (measured)
    # restart path
    storage_bw_gbps_per_gpu: float  # checkpoint read bandwidth per GPU
    proc_spawn_s: float  # process relaunch + framework import
    cuda_init_s: float  # CUDA context + cuDNN/JIT warmup per restart
    nccl_base_s: float  # communicator setup base
    nccl_per_rank_s: float  # per-rank handshake cost
    nccl_log_s: float  # topology-discovery log term
    misc_s: float  # setup/sync residue (paper Table 1: 2.4 s)
    # live path
    interconnect_gbps_per_gpu: float  # P2P streaming bandwidth per GPU
    drain_s: float  # in-flight drain at iteration boundary
    switch_s: float  # atomic metadata swap
    plan_s: float  # CPU transfer planning
    steady_overhead: float  # fractional iteration slowdown during prepare
    # shadow prepare (overlapped; relevant vs warning window)
    prepare_base_s: float
    prepare_per_rank_s: float

    def dist_init_s(self, world: int) -> float:
        return (
            self.nccl_base_s
            + self.nccl_per_rank_s * world
            + self.nccl_log_s * math.log2(max(world, 2))
        )

    def ckpt_load_s(self, model_bytes: float, world: int) -> float:
        bw = self.storage_bw_gbps_per_gpu * 1e9 / 8 * world
        return model_bytes / bw

    def transfer_s(self, moved_bytes: float, world: int) -> float:
        bw = self.interconnect_gbps_per_gpu * 1e9 / 8 * world
        return moved_bytes / bw

    def prepare_s(self, world: int) -> float:
        return self.prepare_base_s + self.prepare_per_rank_s * world

    def step_time_s(self, params: float, world: int, ref_world: int = 32) -> float:
        # fixed global batch: time ∝ params / world (weak efficiency factor)
        eff = (ref_world / world) ** 0.05 if world else 1.0
        return self.step_time_s_per_1e9_params * (params / 1e9) * (ref_world / max(world, 1)) * eff


# --- paper testbed: constants solved against the paper's measurements -----
# Table 1 (GPT-20B, 32 GPUs): ckpt load 54.6 s, dist init+warmup 70.1 s,
# misc 2.4 s. §2.2.1: 14B/32 GPUs init ≈ 60 s. §6.3: 28 GB transfer ≈ 2 s,
# switch < 0.5 s, steady-state overhead 0.28 %. Model state ≈ 2 bytes/param
# (bf16) × (1 + optimizer partition share) ≈ paper's "~28 GB for 14B".
PAPER_TESTBED = ClusterModel(
    name="a800x32",
    gpus_per_node=8,
    step_time_s_per_1e9_params=0.55,
    # restart reloads the FULL distributed state (fp16 params + fp32 master
    # + Adam moments ≈ 10 B/param, see model_state_bytes(with_optimizer));
    # 0.915 Gb/s/GPU reproduces Table 1's 54.6 s for GPT-20B on 32 GPUs.
    storage_bw_gbps_per_gpu=0.915,
    proc_spawn_s=8.0,
    cuda_init_s=12.0,
    nccl_base_s=20.0,
    nccl_per_rank_s=0.55,
    nccl_log_s=2.5,
    misc_s=2.4,   # Table 1 misc
    # LiveR streams bf16 params P2P: 28 GB in ~2 s for 14B (paper §6.3)
    interconnect_gbps_per_gpu=4.7,
    drain_s=4.0,   # finish iteration N + drain in-flight work (~1 iter)
    switch_s=0.4,  # sub-second metadata swap (Fig. 6c)
    plan_s=0.6,
    steady_overhead=0.0028,  # §6.3: 0.28 % iteration-time delta
    prepare_base_s=25.0,
    prepare_per_rank_s=0.9,
)

# --- TPU v5e pod target (per-chip ICI ~50 GB/s, compile measured locally) --
TPU_V5E_POD = ClusterModel(
    name="tpu-v5e-pod",
    gpus_per_node=4,
    step_time_s_per_1e9_params=0.12,
    storage_bw_gbps_per_gpu=4.0,
    proc_spawn_s=3.0,
    cuda_init_s=0.0,  # no CUDA; runtime init folded into compile
    nccl_base_s=15.0,  # XLA compile+load base (measured scaling locally)
    nccl_per_rank_s=0.08,
    nccl_log_s=6.0,
    misc_s=1.5,
    interconnect_gbps_per_gpu=400.0,  # 50 GB/s ICI per chip
    drain_s=0.2,
    switch_s=0.05,
    plan_s=0.4,
    steady_overhead=0.003,
    prepare_base_s=20.0,
    prepare_per_rank_s=0.05,
)


def model_state_bytes(params: float, with_optimizer: bool = False) -> float:
    """bf16 params; with_optimizer adds fp32 master + Adam moments
    (mixed-precision training state ≈ 10 B/param, what a restart reloads)."""
    per = 2.0 + (8.0 if with_optimizer else 0.0)
    return params * per
