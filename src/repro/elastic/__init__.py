"""Trace-driven elasticity scheduling (paper §2.3 event streams, §4.1).

The controller executes ONE reconfiguration; this package turns streams of
elasticity events — spot-market warnings, preemptions, fail-stops — into
deadline-aware decisions over the live :class:`LiveRController`: overlapped
streaming when the warning window allows, stop-copy when it is tight,
durable checkpoint when nothing else fits (DESIGN.md §10).
"""

from repro.elastic.scheduler import (
    DeadlineEstimator,
    ElasticScheduler,
    EventOutcome,
    PrefetchPolicy,
    ReconfigEstimate,
    ScheduleReport,
    choose_mode,
)
from repro.elastic.trace import events_from_trace

__all__ = [
    "DeadlineEstimator",
    "ElasticScheduler",
    "EventOutcome",
    "PrefetchPolicy",
    "ReconfigEstimate",
    "ScheduleReport",
    "choose_mode",
    "events_from_trace",
]
