"""Trace-driven elasticity scheduling (paper §2.3 event streams, §4.1).

The controller executes ONE reconfiguration; this package turns streams of
elasticity events — spot-market warnings, preemptions, fail-stops — into
deadline-aware decisions, spoken over a serializable command/response
protocol (``protocol.py``, DESIGN.md §17) to an endpoint
(``endpoint.py``) fronting the live :class:`LiveRController`, the serving
controller, or a calibrated DES model: overlapped streaming when the
warning window allows, stop-copy when it is tight, peer-replica recovery
when the window is gone but survivors still cover the state (DESIGN.md
§15), durable checkpoint only when nothing else fits (DESIGN.md §10).
"""

from repro.elastic.endpoint import (
    ControllerEndpoint,
    DeadlineEstimator,
    Endpoint,
    PrefetchPolicy,
    ServeEndpoint,
    SimEndpoint,
    WireEndpoint,
    as_endpoint,
)
from repro.elastic.faults import FaultInjector, InjectionReport, controller_phase
from repro.elastic.protocol import ReconfigEstimate, RecordView
from repro.elastic.redundancy import (
    ParityStore,
    RecoveryError,
    RedundancyMap,
    balance_donors,
    heal_plan,
    survivors_for,
)
from repro.elastic.scheduler import (
    ElasticScheduler,
    EventOutcome,
    ScheduleReport,
    choose_mode,
)
from repro.elastic.trace import events_from_trace

__all__ = [
    "ControllerEndpoint",
    "DeadlineEstimator",
    "ElasticScheduler",
    "Endpoint",
    "EventOutcome",
    "FaultInjector",
    "InjectionReport",
    "ParityStore",
    "PrefetchPolicy",
    "ReconfigEstimate",
    "RecordView",
    "RecoveryError",
    "RedundancyMap",
    "ScheduleReport",
    "ServeEndpoint",
    "SimEndpoint",
    "WireEndpoint",
    "as_endpoint",
    "balance_donors",
    "choose_mode",
    "controller_phase",
    "events_from_trace",
    "survivors_for",
    "heal_plan",
]
