"""Trace-driven elasticity scheduling (paper §2.3 event streams, §4.1).

The controller executes ONE reconfiguration; this package turns streams of
elasticity events — spot-market warnings, preemptions, fail-stops — into
deadline-aware decisions over the live :class:`LiveRController`: overlapped
streaming when the warning window allows, stop-copy when it is tight,
peer-replica recovery when the window is gone but survivors still cover the
state (DESIGN.md §15), durable checkpoint only when nothing else fits
(DESIGN.md §10).
"""

from repro.elastic.faults import FaultInjector, InjectionReport, controller_phase
from repro.elastic.redundancy import (
    ParityStore,
    RecoveryError,
    RedundancyMap,
    balance_donors,
    heal_plan,
    survivors_for,
)
from repro.elastic.scheduler import (
    DeadlineEstimator,
    ElasticScheduler,
    EventOutcome,
    PrefetchPolicy,
    ReconfigEstimate,
    ScheduleReport,
    choose_mode,
)
from repro.elastic.trace import events_from_trace

__all__ = [
    "DeadlineEstimator",
    "ElasticScheduler",
    "EventOutcome",
    "FaultInjector",
    "InjectionReport",
    "ParityStore",
    "PrefetchPolicy",
    "ReconfigEstimate",
    "RecoveryError",
    "RedundancyMap",
    "ScheduleReport",
    "balance_donors",
    "choose_mode",
    "controller_phase",
    "events_from_trace",
    "heal_plan",
    "survivors_for",
]
