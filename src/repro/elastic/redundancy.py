"""Peer-redundancy recovery layer (DESIGN.md §15).

The fallback lattice used to bottom out in the checkpoint rung for every
unannounced fail-stop: save/restore through storage, minutes of pause at
paper scale. But the redundancy needed to recover is usually already in
device memory — DP replicas hold full copies of params and (non-ZeRO)
optimizer moments, and the intersection planner knows exactly which ranks
those are. This module turns that observation into a recovery path:

* :class:`RedundancyMap` — for one source world and one survivor set,
  which surviving rank holds a valid replica of each distinct shard
  (computed from the planner's src views, grouped by view bounds).
* :func:`survivors_for` — the survivor set implied by a fail-stop event
  (explicit ``lost_ranks``, or the prefix-allocation default: the ranks
  beyond the target world died).
* :func:`balance_donors` — post-pass over a survivor-constrained
  :class:`~repro.core.intersection.TransferPlan` that spreads remote cells
  across the surviving replicas of each cell so no single donor serializes
  the recovery stream (greedy least-loaded-by-bytes).
* :class:`ParityStore` — the spare-shard/erasure scheme for worlds with no
  replica axis (dp=1): a periodic XOR parity of the distinct shard images
  of every tensor, staged off the owning replicas during idle step
  boundaries. A shard whose entire replica group died is reconstructed as
  ``parity XOR (all surviving groups)`` and patched back into the live
  arrays before the recovery stream runs.
* :func:`heal_plan` — after parity repair, rewrites ``kind == "lost"``
  cells into executable remote cells sourced from the (repaired) owner
  rank, with the repaired bytes tracked separately as ``parity_bytes``.

``RecoveryError`` (re-exported from :mod:`repro.core.errors`) is the typed
"no rung left" failure: no surviving replica, no fresh parity, no
checkpoint directory.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional

import numpy as np

from repro.configs.base import ParallelConfig
from repro.core.errors import RecoveryError
from repro.core.intersection import (
    TransferPlan,
    TransferTask,
    replica_candidates,
)
from repro.core.resource_view import TensorSpec, view_of

__all__ = [
    "RecoveryError",
    "RedundancyMap",
    "ParityStore",
    "survivors_for",
    "balance_donors",
    "heal_plan",
]


def survivors_for(
    cfg_src: ParallelConfig,
    lost_ranks: Iterable[int] = (),
    target: Optional[ParallelConfig] = None,
    devices_failed: bool = True,
) -> frozenset[int]:
    """Survivor ranks of ``cfg_src`` after a fail-stop.

    Explicit ``lost_ranks`` win. Otherwise, under the prefix device
    allocation (rank r ↔ devices[r] in every world), an unannounced
    fail-stop that forces a shrink to ``target`` means the ranks beyond the
    target prefix died. With ``devices_failed=False`` (warned event past
    its window: the machines are still up) everyone survives.
    """
    lost = set(int(r) for r in lost_ranks)
    if not lost and devices_failed and target is not None:
        lost = set(range(target.world_size, cfg_src.world_size))
    return frozenset(range(cfg_src.world_size)) - lost


# ---------------------------------------------------------------------------
# Redundancy map
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardCover:
    """One distinct shard image of one tensor and who can still donate it."""

    tensor: str
    bounds: tuple[tuple[int, int], ...]
    owners: tuple[int, ...]  # the full replica group in cfg_src
    donors: tuple[int, ...]  # owners ∩ survivors
    nbytes: int


@dataclass
class RedundancyMap:
    """Which surviving device holds a valid replica of each shard.

    Shards are grouped by view bounds — ranks with byte-identical views
    form one replica group (DP for params/moments on the live path, plus
    EP for non-expert tensors). ``complete`` iff every group kept at least
    one survivor; ``uncovered`` lists the holes parity must fill.
    """

    cfg: ParallelConfig
    survivors: frozenset[int]
    covers: list[ShardCover] = field(default_factory=list)

    @classmethod
    def build(
        cls,
        specs: Iterable[TensorSpec],
        cfg: ParallelConfig,
        survivors: frozenset[int],
    ) -> "RedundancyMap":
        covers: list[ShardCover] = []
        for spec in specs:
            itemsize = np.dtype(spec.dtype).itemsize
            groups: dict[tuple, list[int]] = {}
            for r in range(cfg.world_size):
                v = view_of(spec, cfg, r)
                if v is None or v.size == 0:
                    continue
                groups.setdefault(v.bounds, []).append(r)
            for bounds, owners in groups.items():
                donors = tuple(r for r in owners if r in survivors)
                nbytes = itemsize
                for lo, hi in bounds:
                    nbytes *= hi - lo
                covers.append(
                    ShardCover(
                        tensor=spec.name,
                        bounds=bounds,
                        owners=tuple(owners),
                        donors=donors,
                        nbytes=nbytes,
                    )
                )
        return cls(cfg=cfg, survivors=survivors, covers=covers)

    @property
    def complete(self) -> bool:
        return all(c.donors for c in self.covers)

    def uncovered(self) -> list[ShardCover]:
        return [c for c in self.covers if not c.donors]

    @property
    def uncovered_bytes(self) -> int:
        return sum(c.nbytes for c in self.uncovered())

    def donor_load(self) -> dict[int, int]:
        """Bytes each survivor would send if it donated every shard it
        holds exactly once (an upper bound used for balance sanity)."""
        load: dict[int, int] = {}
        for c in self.covers:
            for r in c.donors:
                load[r] = load.get(r, 0) + c.nbytes
        return load


# ---------------------------------------------------------------------------
# Donor balancing
# ---------------------------------------------------------------------------


def balance_donors(
    plan: TransferPlan,
    specs: Iterable[TensorSpec],
    survivors: frozenset[int],
) -> TransferPlan:
    """Spread remote cells across surviving replicas, least-loaded first.

    The planner's per-cell hash policy is donor-oblivious; after a
    fail-stop the surviving replica groups shrink and a single donor can
    end up sourcing most of the stream. This pass reassigns each remote
    cell (largest first) to the surviving candidate with the least bytes
    already assigned, recomputing the source offset from the chosen
    donor's view. Resident/local cells are left alone — moving them to a
    remote donor would turn free work into wire bytes.
    """
    by_name = {s.name: s for s in specs}
    load: dict[int, int] = {r: 0 for r in survivors}
    # non-remote work is fixed; seed the load with nothing (resident/local
    # cells cost no wire time), then place remote cells greedily
    remote = [t for t in plan.tasks if t.kind == "remote"]
    keep = [t for t in plan.tasks if t.kind != "remote"]
    out: list[TransferTask] = list(keep)
    for t in sorted(remote, key=lambda t: -t.nbytes):
        spec = by_name.get(t.tensor)
        if spec is None:
            out.append(t)
            load[t.src_rank] = load.get(t.src_rank, 0) + t.nbytes
            continue
        cands = [
            r
            for r in replica_candidates(spec, plan.cfg_src, t.bounds)
            if r in survivors
        ]
        if not cands:
            out.append(t)
            continue
        src = min(cands, key=lambda r: (load.get(r, 0), r))
        if src != t.src_rank:
            v_src = view_of(spec, plan.cfg_src, src)
            assert v_src is not None
            t = dataclasses.replace(
                t,
                src_rank=src,
                src_offset=tuple(
                    b[0] - v[0] for b, v in zip(t.bounds, v_src.bounds)
                ),
            )
        load[src] = load.get(src, 0) + t.nbytes
        out.append(t)
    return TransferPlan(tasks=out, cfg_src=plan.cfg_src, cfg_dst=plan.cfg_dst)


# ---------------------------------------------------------------------------
# Spare-shard / erasure scheme
# ---------------------------------------------------------------------------


def _shard_groups(
    spec: TensorSpec, cfg: ParallelConfig
) -> list[tuple[tuple[tuple[int, int], ...], list[int]]]:
    """Distinct shard images of ``spec`` under ``cfg``: (bounds, owners).

    Parity is computed over *distinct* images, one per replica group —
    XOR-ing identical replicas would cancel them out of the parity word.
    Deterministic order (sorted by bounds) so refresh and repair agree.
    """
    groups: dict[tuple, list[int]] = {}
    for r in range(cfg.world_size):
        v = view_of(spec, cfg, r)
        if v is None or v.size == 0:
            continue
        groups.setdefault(v.bounds, []).append(r)
    return sorted(groups.items())


def _shard_bytes(arr: Any, bounds: tuple[tuple[int, int], ...]) -> np.ndarray:
    sl = tuple(slice(lo, hi) for lo, hi in bounds)
    host = np.ascontiguousarray(np.asarray(arr[sl]))
    return host.view(np.uint8).reshape(-1)


class ParityStore:
    """Periodic XOR parity over the distinct shard images of each tensor.

    ``refresh(named, step)`` snapshots one parity word per tensor —
    byte-XOR of every distinct shard image, zero-padded to the largest —
    at an idle step boundary. The words live off the owning replicas (host
    memory here; a real deployment stages them onto spare devices), so
    when an entire replica group dies its image is reconstructible as
    ``parity XOR (surviving groups)``.

    Parity is a consistent cut: repair is only valid when the snapshot
    step equals the step the survivors are at (``covers(step)``), because
    reconstruction mixes the stored word with the survivors' *live*
    bytes. The controller refreshes at every boundary for dp=1 worlds
    (cheap at repro scale; the paper's scheme rate-limits by staleness
    tolerance), so an inter-step fail-stop always finds fresh parity.
    """

    def __init__(self, specs: Iterable[TensorSpec], cfg: ParallelConfig):
        self.specs = {s.name: s for s in specs}
        self.cfg = cfg
        self.step: Optional[int] = None
        self._parity: dict[str, np.ndarray] = {}
        self.last_refresh_s = 0.0
        self.refreshed_bytes = 0

    def covers(self, step: int) -> bool:
        return self.step == step and bool(self._parity)

    def refresh(self, named: dict[str, Any], step: int) -> int:
        """Rebuild every parity word from the live state at ``step``."""
        t0 = time.perf_counter()
        total = 0
        parity: dict[str, np.ndarray] = {}
        for name, spec in self.specs.items():
            arr = named.get(name)
            if arr is None:
                continue
            # one group (fully replicated or unsplit tensor) degenerates to
            # a full spare copy — still the only redundancy such state has
            groups = _shard_groups(spec, self.cfg)
            width = 0
            images = []
            for bounds, _owners in groups:
                img = _shard_bytes(arr, bounds)
                width = max(width, img.size)
                images.append(img)
            word = np.zeros(width, dtype=np.uint8)
            for img in images:
                word[: img.size] ^= img
            parity[name] = word
            total += width
        self._parity = parity
        self.step = step
        self.refreshed_bytes = total
        self.last_refresh_s = time.perf_counter() - t0
        return total

    def dead_groups(
        self, lost_ranks: frozenset[int]
    ) -> list[tuple[str, tuple[tuple[int, int], ...], list[int]]]:
        """(tensor, bounds, owners) of every group wholly inside the loss."""
        out = []
        for name, spec in self.specs.items():
            for bounds, owners in _shard_groups(spec, self.cfg):
                if all(r in lost_ranks for r in owners):
                    out.append((name, bounds, owners))
        return out

    def repair(
        self,
        named: dict[str, Any],
        lost_ranks: frozenset[int],
        step: int,
    ) -> tuple[dict[str, Any], int]:
        """Reconstruct every dead group's image and patch it into ``named``.

        Returns (patched leaves, repaired bytes). Raises
        :class:`RecoveryError` when parity is stale or more than one group
        of the same tensor died (single-parity-word erasure limit).
        """
        if not self.covers(step):
            raise RecoveryError(
                f"parity snapshot at step {self.step} cannot repair state at "
                f"step {step}: stale or never refreshed"
            )
        patched = dict(named)
        repaired = 0
        by_tensor: dict[str, list] = {}
        for name, bounds, owners in self.dead_groups(lost_ranks):
            by_tensor.setdefault(name, []).append((bounds, owners))
        for name, dead in by_tensor.items():
            if len(dead) > 1:
                raise RecoveryError(
                    f"{name}: {len(dead)} shard groups lost at once — single "
                    "XOR parity can reconstruct at most one"
                )
            spec = self.specs[name]
            arr = patched.get(name)
            if arr is None:
                raise RecoveryError(f"{name}: no live leaf to repair into")
            (bounds, _owners) = dead[0]
            word = self._parity[name].copy()
            for g_bounds, g_owners in _shard_groups(spec, self.cfg):
                if g_bounds == bounds:
                    continue
                if not any(r not in lost_ranks for r in g_owners):
                    raise RecoveryError(
                        f"{name}: surviving group needed for parity repair "
                        "also died"
                    )
                img = _shard_bytes(arr, g_bounds)
                word[: img.size] ^= img
            shape = tuple(hi - lo for lo, hi in bounds)
            nbytes = int(np.prod(shape)) * np.dtype(spec.dtype).itemsize
            vals = (
                word[:nbytes].copy().view(np.dtype(spec.dtype)).reshape(shape)
            )
            sl = tuple(slice(lo, hi) for lo, hi in bounds)
            if hasattr(arr, "at"):  # jax.Array
                patched[name] = arr.at[sl].set(vals)
            else:
                host = np.array(arr, copy=True)
                host[sl] = vals
                patched[name] = host
            repaired += nbytes
        return patched, repaired


def heal_plan(
    plan: TransferPlan, specs: Iterable[TensorSpec]
) -> tuple[TransferPlan, int]:
    """Rewrite ``lost`` cells as executable remote cells after parity repair.

    Once :meth:`ParityStore.repair` has patched the reconstructed bytes
    back into the (global) source arrays, each lost cell can stream like
    any other remote cell; we source it from the original owner rank —
    the bytes are byte-identical to what that rank held, they just arrived
    via the parity word. Returns (healed plan, parity-sourced bytes).
    """
    by_name = {s.name: s for s in specs}
    healed: list[TransferTask] = []
    parity_bytes = 0
    for t in plan.tasks:
        if t.kind != "lost":
            healed.append(t)
            continue
        spec = by_name[t.tensor]
        owner = None
        for r in replica_candidates(spec, plan.cfg_src, t.bounds):
            v = view_of(spec, plan.cfg_src, r)
            if v is not None:
                owner = (r, v)
                break
        if owner is None:
            raise RecoveryError(f"{t.tensor}: lost cell has no owner view")
        r, v = owner
        healed.append(
            dataclasses.replace(
                t,
                kind="remote",
                src_rank=r,
                src_offset=tuple(
                    b[0] - vb[0] for b, vb in zip(t.bounds, v.bounds)
                ),
            )
        )
        parity_bytes += t.nbytes
    return (
        TransferPlan(tasks=healed, cfg_src=plan.cfg_src, cfg_dst=plan.cfg_dst),
        parity_bytes,
    )
