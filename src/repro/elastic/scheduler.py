"""Deadline-aware elasticity scheduler over the live controller (DESIGN.md
§10; paper §2.3 event streams, §4.1 warning windows).

The paper's volatility numbers assume every event lands inside its warning
window; this module is the event loop that makes that true on the *real*
``LiveRController`` rather than the analytic simulator. For each event it

  1. estimates trigger-to-safe time for each rung of the fallback lattice
     (overlapped streaming -> stop-copy -> durable checkpoint) from the
     intersection plan's byte counts and the recent ``ReconfigRecord``
     history,
  2. picks the highest rung whose estimate (x safety margin) fits the
     warning window,
  3. coalesces duplicate events and retargets the in-flight reconfiguration
     when a newer event supersedes it (``retarget_resize`` adopts the
     already-streamed intersection state so the stream continues instead of
     restarting), and
  4. escalates mid-stream to stop-copy (``escalate_commit``) when the
     remaining window no longer covers the pre-copy schedule.

Trace times run on a *virtual clock*: ``clock += wall_dt * time_scale``, so
a compressed trace replays in CI while deadline arithmetic stays in trace
units. Measured goodput comes from the controller's ``GoodputLedger`` —
real pauses, not modeled ones — which ``benchmarks/bench_goodput.py``
reports next to the analytic ``sim.liver_sim.volatility_run`` prediction.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.errors import RecoveryError
from repro.core.events import FailStopEvent, ResizeEvent, sort_trace
from repro.core.records import ReuseRecordMixin
from repro.reshard.autotune import tune_operating_point


# ---------------------------------------------------------------------------
# Estimation + the fallback-lattice decision (pure; unit-testable)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ReconfigEstimate:
    """Trigger-to-safe time estimates for one candidate reconfiguration.

    All in real seconds; the scheduler converts with its ``time_scale``
    before comparing to a (virtual-time) warning window.
    """

    prepare_s: float  # shadow build: mesh + lower + compile
    precopy_s: float  # streaming rounds riding iteration boundaries
    stream_pause_s: float  # commit pause of the overlapped path
    stop_copy_pause_s: float  # whole transfer inside one pause
    plan_bytes: int
    rounds: int
    step_s: float
    # prepare_s is the WARM estimate: the controller's pool holds a ready
    # world for the target, so Prepare skips lower+compile
    warm: bool = False
    # wire pricing (DESIGN.md §14): the pause estimates above are priced on
    # wire_bytes (what crosses the interconnect under the controller's
    # WirePolicy); lossless_transfer_s is what the same plan would cost
    # uncompressed, so the scheduler can report which rung the event would
    # have gotten without compression
    wire_bytes: int = 0
    layers: int = 0
    lossless_transfer_s: float = 0.0
    # peer_recover rung (DESIGN.md §15): True when the survivor set (plus
    # fresh parity) covers the state, so an in-memory donor stream can
    # replace the checkpoint round-trip; peer_pause_s prices that stream
    # (warm/cold prepare + donor bytes at measured bandwidth, lossless —
    # the recovery stream never compresses)
    peer_ok: bool = False
    peer_bytes: int = 0
    peer_pause_s: float = 0.0

    @property
    def stream_total_s(self) -> float:
        """Trigger -> committed via overlapped streaming."""
        return self.prepare_s + self.precopy_s + self.stream_pause_s

    @property
    def stop_copy_total_s(self) -> float:
        """Trigger -> committed via stop-copy (no boundary rounds)."""
        return self.prepare_s + self.stop_copy_pause_s

    @property
    def stream_total_lossless_s(self) -> float:
        """stream_total_s had the plan moved uncompressed."""
        return self.prepare_s + self.precopy_s + self.lossless_transfer_s

    @property
    def stop_copy_total_lossless_s(self) -> float:
        """stop_copy_total_s had the plan moved uncompressed."""
        return self.prepare_s + self.lossless_transfer_s


def choose_mode(
    est: ReconfigEstimate,
    window_s: float,
    safety: float = 1.25,
    time_scale: float = 1.0,
    lossless: bool = False,
) -> str:
    """The fallback lattice: highest rung whose estimate fits the window.

    overlap ("stream") completes slowest but pauses least; stop-copy
    completes right after Prepare at the price of one long pause;
    peer_recover (DESIGN.md §15) needs nothing inside the window at all —
    the survivors retain the state in device memory past the deadline and
    the donor stream runs after it — so like checkpoint it always *fits*,
    but it is only *available* when the survivor set covers the state
    (``est.peer_ok``); the checkpoint rung (durable save, restart on the
    target) is the unconditional last resort beneath it.

    ``lossless=True`` re-ranks the lattice on the uncompressed transfer
    estimates — the counterfactual decision the scheduler reports so the
    benchmark can show which events the compressed wire promoted a rung.
    """
    stream_s = est.stream_total_lossless_s if lossless else est.stream_total_s
    stop_s = (
        est.stop_copy_total_lossless_s if lossless else est.stop_copy_total_s
    )
    if stream_s * safety * time_scale <= window_s:
        return "stream"
    if stop_s * safety * time_scale <= window_s:
        return "stop_copy"
    if est.peer_ok:
        return "peer_recover"
    return "checkpoint"


def _median(xs: list[float]) -> Optional[float]:
    xs = sorted(x for x in xs if x > 0)
    return xs[len(xs) // 2] if xs else None


class DeadlineEstimator:
    """prepare+stream estimates from plan metadata and reconfig history.

    Bytes come from the same ``plan_state_transfer`` machinery that fills
    the shadow world's ``plan_bundle`` (a ready bundle for the right target
    is used as-is); seconds come from the recent ``ReconfigRecord``s —
    median prepare time and effective transfer bandwidth — falling back to
    the constructor defaults until history exists.
    """

    def __init__(
        self,
        controller,
        default_prepare_s: float = 20.0,
        default_warm_prepare_s: float = 1.0,
        default_bw_bytes_s: float = 1e9,
        default_step_s: float = 0.25,
        history: int = 8,
    ):
        self.ctrl = controller
        self.default_prepare_s = default_prepare_s
        self.default_warm_prepare_s = default_warm_prepare_s
        self.default_bw = default_bw_bytes_s
        self.default_step_s = default_step_s
        self.history = history

    # -- history --------------------------------------------------------
    def _recent(self, warm: Optional[bool] = None) -> list:
        # every record whose Prepare actually completed is a valid sample,
        # not just committed ones: after a retarget-heavy stretch the
        # committed subset can be empty and a committed-only filter made
        # the estimator silently fall back to its defaults. ``fell_back``
        # on a live mode means an escalated commit (prepare finished);
        # ``retargeted`` records count only when their prepare finished
        # before supersession (prepare_s > 0 — mid-prepare retargets
        # carry no timing).
        recs = [
            r
            for r in self.ctrl.records
            if r.mode in ("live", "live_overlap")
            and (r.outcome in ("committed", "fell_back") or r.prepare_s > 0)
        ]
        if warm is not None:
            if warm:
                recs = [r for r in recs if getattr(r, "warm_hit", False)]
            else:
                # a speculative join measures neither a warm Prepare (the
                # compile ran) nor a cold one (only the residual wait was
                # timed) — sampling it as cold would drag the cold median
                # toward zero and mis-rank the lattice for true cold events
                recs = [
                    r
                    for r in recs
                    if not getattr(r, "warm_hit", False)
                    and getattr(r, "prepare_source", "cold")
                    != "speculative_join"
                ]
        return recs[-self.history :]

    def prepare_estimate(self, warm: bool = False) -> float:
        """Median prepare time over recent records of the requested kind:
        warm (pool hit — lower+compile skipped) and cold prepares differ by
        orders of magnitude, so one blended median would make the lattice
        reject the overlap rung exactly when a warm world makes it cheap."""
        m = _median([r.prepare_s for r in self._recent(warm=warm)])
        if m is not None:
            return m
        if warm:
            # no warm history yet: a pool hit skips lower+compile, leaving
            # planning + bookkeeping — bounded above by the cold estimate
            return min(self.prepare_estimate(warm=False),
                       self.default_warm_prepare_s)
        # cold start: the gen-0 world's own build timings are the best proxy
        t = self.ctrl.world.timings
        seed = sum(t.get(k, 0.0) for k in ("mesh_s", "lower_s", "compile_s"))
        return seed or self.default_prepare_s

    def measured_bandwidth(self) -> Optional[float]:
        """Median transfer bandwidth over recent records, or ``None`` with
        no history yet (the operating-point tuner treats None as "fall back
        to the hand-set constants").

        With a wire policy on the controller, bandwidth is measured in
        PHYSICAL wire bytes per second so that pricing ``est.wire_bytes``
        and the lossless counterfactual against it stay on one scale;
        lossless controllers keep the historical moved-bytes measure."""
        compressed = getattr(self.ctrl, "wire_policy", None) is not None
        bws = []
        for r in self._recent():
            moved = r.moved_bytes
            if compressed:
                moved = getattr(r, "wire_bytes", 0) or r.moved_bytes
            secs = r.transfer_s + r.resync_s + r.precopy_s
            if moved > 0 and secs > 0:
                bws.append(moved / secs)
        return _median(bws)

    def bandwidth_estimate(self) -> float:
        return self.measured_bandwidth() or self.default_bw

    def step_estimate(self) -> float:
        return _median(list(self.ctrl.iteration_times)[-16:]) or self.default_step_s

    # -- the estimate ---------------------------------------------------
    def _price_plan(self, plan) -> tuple[int, int, int]:
        """(logical bytes, wire bytes, streaming layers) of a plan.

        Priced on the classified plan IR (DESIGN.md §13): bytes are REMOTE
        only — resident cells never move and local relayouts never cross a
        wire — and fully-resident layers need no pre-copy rounds. This is
        what lets a tp-preserving resize fit the overlap rung inside a
        warning window its full-copy byte count would have blown. Wire
        bytes price the same remote tasks under the controller's WirePolicy
        (DESIGN.md §14); equal to logical bytes when lossless."""
        from repro.reshard.wire import wire_nbytes

        policy = getattr(self.ctrl, "wire_policy", None)
        logical = plan.network_bytes
        if policy is None:
            wire = logical
        else:
            wire = sum(
                wire_nbytes(policy, t)
                for t in plan.tasks
                if getattr(t, "kind", "remote") == "remote"
            )
        return logical, wire, len(plan.layers()) - len(plan.resident_layers())

    def _plan_for(self, target) -> tuple[int, int, int]:
        """(logical bytes, wire bytes, layers) for current-world -> target."""
        b = getattr(self.ctrl, "_builder", None)
        if b is not None and b.ready and not b.abandoned:
            handle = b.result()
            bundle = handle.plan_bundle
            if (
                handle.parallel == target
                and bundle is not None
                and bundle[0] == self.ctrl.world.parallel
            ):
                return self._price_plan(bundle[2])
        from repro.core.reshard import plan_state_transfer

        _, plan = plan_state_transfer(
            self.ctrl.cfg, self.ctrl.world.parallel, target,
            source_policy=self.ctrl.source_policy,
        )
        return self._price_plan(plan)

    def _pool_warm(self, target) -> bool:
        """True when the controller's warm pool holds a ready world for
        ``target`` (Prepare will skip lower+compile)."""
        pool = getattr(self.ctrl, "world_pool", None)
        if pool is None or not hasattr(self.ctrl, "pool_key"):
            return False
        return pool.contains(self.ctrl.pool_key(target))

    def estimate(self, target) -> ReconfigEstimate:
        plan_bytes, wire_bytes, layers = self._plan_for(target)
        bw = self.bandwidth_estimate()
        step_s = self.step_estimate()
        rounds = math.ceil(layers / max(1, self.ctrl.stream_k))
        # the rungs are priced on what actually crosses the wire under the
        # controller's WirePolicy; the lossless figure is kept alongside so
        # the decision can be compared to its uncompressed counterfactual
        transfer_s = wire_bytes / bw
        warm = self._pool_warm(target)
        # peer_recover rung pricing (DESIGN.md §15): coverage from the
        # controller's survivor-constrained plan (fail-stop geometry — the
        # ranks beyond the target prefix die), donor bytes at measured
        # bandwidth, lossless (the recovery stream never compresses).
        # Duck-typed controllers without peer recovery price it
        # unavailable and keep the checkpoint rung.
        peer_ok, peer_bytes = False, 0
        cov = getattr(self.ctrl, "peer_coverage", None)
        if cov is not None:
            peer_ok, peer_bytes = cov(target)
        return ReconfigEstimate(
            prepare_s=self.prepare_estimate(warm=warm),
            warm=warm,
            # one pre-copy round per iteration boundary, each hiding its
            # bytes under a training step (dispatch rides the boundary)
            precopy_s=rounds * step_s,
            # dense-optimizer worst case: every layer is dirty at commit,
            # so the commit pause re-moves the plan (overlap.py's honest
            # limit) — minus nothing we can promise in advance
            stream_pause_s=transfer_s,
            stop_copy_pause_s=transfer_s,
            plan_bytes=plan_bytes,
            rounds=rounds,
            step_s=step_s,
            wire_bytes=wire_bytes,
            layers=layers,
            lossless_transfer_s=plan_bytes / bw,
            peer_ok=peer_ok,
            peer_bytes=peer_bytes,
            peer_pause_s=self.prepare_estimate(warm=warm) + peer_bytes / bw,
        )


# ---------------------------------------------------------------------------
# Speculative warm-pool prefetch (DESIGN.md §12)
# ---------------------------------------------------------------------------


class PrefetchPolicy:
    """Fills the controller's warm world pool while the event loop is idle.

    Each ``tick`` (called by the scheduler on steps with no pending event)
    asks the topology search for the likely next targets — the failover
    standby (:func:`failover_target`, the prefix-survivor world a
    fail-stop would recover into, DESIGN.md §15) first, then the best
    feasible configurations at the walk-down/walk-up neighbor device
    counts of the current world (:func:`likely_next_targets`) — and starts
    speculative builds via ``controller.prefetch_world``. Targets already
    pooled get their transfer executables pre-compiled instead
    (``controller.prewarm_transfer``), so a recovery into a warm world
    pays neither the Prepare nor the first-pair reshard compiles. The
    controller enforces the guardrails: never while a real reconfiguration
    is in flight, at most ``max_spec_builds`` concurrent compiles, skip
    targets already pooled or building. Candidate enumeration is
    re-planned per tick because the current world (and hence its
    neighbors) changes with every commit; the search itself is
    metadata-only and cheap.
    """

    def __init__(
        self,
        controller,
        k: int = 2,
        factors: tuple[float, ...] = (0.5, 2.0),
        max_pp: int = 8,
    ):
        self.ctrl = controller
        self.k = k
        self.factors = factors
        # must cover the pp range of the event stream's own targets (e.g.
        # events_from_trace's max_pp) or a prefetched pp=1 world can never
        # match a pp>1 event's pool key — wasted builds that evict genuinely
        # useful entries. Pass the same bound you give the trace mapper.
        self.max_pp = max_pp
        self.started = 0
        # candidates only change when the active world does (a commit);
        # cache them so idle ticks don't re-run the topology search
        self._cands_for = None
        self._cands: list = []

    def candidates(self) -> list:
        from repro.core.topology_search import (
            failover_target,
            likely_next_targets,
        )

        ctrl = self.ctrl
        cands = likely_next_targets(
            ctrl.cfg,
            ctrl.world.parallel,
            len(ctrl.devices),
            ctrl.global_batch,
            ctrl.seq_len,
            k=self.k,
            factors=self.factors,
            max_pp=self.max_pp,
        )
        # failover standbys (DESIGN.md §15): the prefix-survivor worlds an
        # unannounced fail-stop would recover into, chained one level (a
        # failure can take more than one replica group). Keeping them warm
        # ahead of the walk-down/walk-up guesses bounds the fail-stop
        # pause to the transfer itself, never a cold Prepare — except a
        # world_size-1 standby, which protects only against losing all but
        # one device: it queues BEHIND the walk candidates so it cannot
        # hog the single speculative-build slot right before a walk-up.
        front: list = []
        back: list = []
        cur = ctrl.world.parallel
        for _ in range(2):
            cur = failover_target(
                ctrl.cfg, cur, ctrl.global_batch, max_pp=self.max_pp
            )
            if cur is None or cur == ctrl.world.parallel:
                break
            (front if cur.world_size > 1 else back).append(cur)
        seen = set(front) | set(back)
        return front + [c for c in cands if c not in seen] + back

    def tick(self) -> int:
        """Start speculative builds for the current candidates; returns
        how many were started (0 when pooled/building/busy)."""
        if getattr(self.ctrl, "reconfig_pending", False):
            # builds would be refused mid-resize, but the INCOMING world's
            # failover pairs can (and should) warm now: a window-0 event
            # right after the commit pays any cold transfer compile inside
            # its pause, and the post-commit gap is shorter than a compile
            getattr(self.ctrl, "prewarm_failover_ahead", lambda: 0)()
            return 0
        current = self.ctrl.world.parallel
        # warm transfer pairs into already-pooled worlds FIRST: a window-0
        # recovery pays any cold transfer compile inside its pause, while
        # a standby world build overlaps training — the prewarm is
        # pause-critical, the build is not. (pool_key index 1 is the
        # ParallelConfig; keys built for another device fingerprint
        # peek-miss inside prewarm_transfer)
        pool = getattr(self.ctrl, "world_pool", None)
        if pool is not None:
            # only non-growing pairs: the zero-warning consumers of these
            # executables are fail-stops, shrinks and same-size
            # retopologies — grows come with warning windows and stream,
            # so warming them here would spend the compile budget the
            # standby build needs. Nearest-size first: a same-size
            # retopology has zero capacity slack and is the likeliest
            # window-0 target, deeper-shrink pairs only matter after
            # deeper failures (prewarms run one at a time, so order is
            # priority)
            keys = sorted(
                (
                    k
                    for k in pool.keys()
                    if k[1] != current
                    and k[1].world_size <= current.world_size
                ),
                key=lambda k: current.world_size - k[1].world_size,
            )
            for key in keys:
                self.ctrl.prewarm_transfer(key[1])
        # while a prewarm is compiling, hold off on starting new cold
        # builds — two concurrent XLA compiles contend for the same host
        # cores and both slow down, and only the prewarm is on the
        # recovery-pause path
        thread = getattr(self.ctrl, "_prewarm_thread", None)
        if thread is not None and thread.is_alive():
            return 0
        if current != self._cands_for:
            self._cands_for = current
            self._cands = self.candidates()
        started = 0
        for target in self._cands:
            if self.ctrl.prefetch_world(target):
                started += 1
            else:
                # already pooled (or building): warm the TRANSFER
                # executables for (current → target) too, so a recovery
                # into this world pays neither compile (DESIGN.md §15)
                self.ctrl.prewarm_transfer(target)
        self.started += started
        return started


# ---------------------------------------------------------------------------
# Per-event bookkeeping
# ---------------------------------------------------------------------------


@dataclass
class EventOutcome(ReuseRecordMixin):
    # reused_layers / resident_layers / skipped_bytes come from the shared
    # ReuseRecordMixin (classified plan IR, DESIGN.md §13)
    index: int
    kind: str  # resize | fail_stop
    time_s: float
    window_s: float
    target: str
    # stream | stop_copy | peer_recover | checkpoint | coalesce | cancel | noop
    decision: str = ""
    # the counterfactual rung the lattice would have picked on the
    # uncompressed transfer estimate — differs from ``decision`` exactly
    # when the compressed wire promoted this event a rung (DESIGN.md §14)
    decision_lossless: str = ""
    outcome: str = ""  # committed | retargeted | fell_back | aborted | coalesced
    gen_id: int = -1
    mode: str = ""  # ReconfigRecord.mode of the commit, when one happened
    est_stream_total_s: float = 0.0
    est_stop_copy_total_s: float = 0.0
    commit_clock_s: float = -1.0
    met_deadline: Optional[bool] = None
    pause_s: float = 0.0
    operating_point: Optional[dict] = None  # tuned data-plane parameters

    def to_dict(self) -> dict:
        # non-finite floats (infinite warning windows) render as "inf" —
        # ``json.dumps(float("inf"))`` emits non-standard ``Infinity``
        d = dict(self.__dict__)
        for k, v in d.items():
            if isinstance(v, float) and not math.isfinite(v):
                d[k] = "inf" if v > 0 else "-inf"
        return d


@dataclass
class _Pending:
    outcome: EventOutcome
    target: Any
    gen_id: int
    deadline: float
    mode: str
    est: ReconfigEstimate


@dataclass
class ScheduleReport:
    outcomes: list[EventOutcome]
    steps: int
    duration_s: float  # virtual trace time covered
    wall_s: float
    goodput: float
    pause_seconds: float

    def count(self, outcome: str) -> int:
        return sum(1 for o in self.outcomes if o.outcome == outcome)

    @property
    def aborted(self) -> int:
        return self.count("aborted")

    def to_dict(self) -> dict:
        return {
            "events": [o.to_dict() for o in self.outcomes],
            "steps": self.steps,
            "duration_s": self.duration_s,
            "wall_s": self.wall_s,
            "goodput": self.goodput,
            "pause_seconds": self.pause_seconds,
            "outcome_counts": {
                k: self.count(k)
                for k in (
                    "committed", "retargeted", "fell_back", "aborted", "coalesced",
                )
            },
        }


# ---------------------------------------------------------------------------
# The event loop
# ---------------------------------------------------------------------------


class ElasticScheduler:
    """Replays an elasticity-event trace against a live controller.

    ``time_scale`` converts wall seconds into virtual trace seconds
    (``clock += dt * time_scale``); estimates are scaled the same way before
    deadline comparisons. ``sync_prepare`` blocks on shadow builds so replay
    is step-deterministic (parity tests / ``--check`` gates); the default
    keeps Prepare fully overlapped with training, as in the paper.
    """

    def __init__(
        self,
        controller,
        time_scale: float = 1.0,
        safety: float = 1.25,
        estimator: Optional[DeadlineEstimator] = None,
        sync_prepare: bool = False,
        mode_override: Optional[str] = None,
        tail_steps: int = 2,
        max_steps: int = 5000,
        on_event: Optional[Callable[[EventOutcome], None]] = None,
        prefetch_k: int = 0,
        prefetch: Optional["PrefetchPolicy"] = None,
    ):
        self.ctrl = controller
        self.time_scale = time_scale
        self.safety = safety
        self.estimator = estimator or DeadlineEstimator(controller)
        self.sync_prepare = sync_prepare
        self.mode_override = mode_override
        self.tail_steps = tail_steps
        self.max_steps = max_steps
        self.on_event = on_event
        # speculative warm-pool prefetch: a fully-configured policy takes
        # precedence (set its max_pp to the trace mapper's!); prefetch_k is
        # the default-config convenience. Either way only when the
        # controller actually carries a pool.
        self.prefetch: Optional[PrefetchPolicy] = prefetch
        if (
            self.prefetch is None
            and prefetch_k > 0
            and getattr(controller, "world_pool", None) is not None
        ):
            self.prefetch = PrefetchPolicy(controller, k=prefetch_k)
        self.clock = 0.0
        self.total_steps = 0
        self.outcomes: list[EventOutcome] = []
        self._pending: Optional[_Pending] = None
        self._seen = len(controller.records)

    # -- clock ----------------------------------------------------------
    def _clocked(self, fn):
        t0 = time.perf_counter()
        out = fn()
        self.clock += (time.perf_counter() - t0) * self.time_scale
        return out

    def _step(self) -> None:
        if self.total_steps >= self.max_steps:
            raise RuntimeError(
                f"scheduler exceeded max_steps={self.max_steps} "
                "(runaway trace or a reconfiguration that never commits)"
            )
        self._clocked(lambda: self.ctrl.train_steps(1))
        self.total_steps += 1
        self._absorb()
        self._enforce_deadline()
        if self.prefetch is not None and (
            self._pending is None
            or getattr(self.ctrl, "reconfig_pending", False)
        ):
            # idle between events: warm the pool for the likely next
            # targets (speculative build threads; never during a real
            # reconfiguration — the controller refuses then). Mid-
            # reconfiguration the tick still runs, but only stream-ahead
            # prewarms the INCOMING world's failover pairs — that window
            # is exactly when those pairs must compile for a window-0
            # event right after the commit to find them warm
            self.prefetch.tick()

    def _advance_to(self, t: float) -> None:
        while self.clock < t:
            self._step()
        self.clock = max(self.clock, t)

    # -- record bookkeeping ---------------------------------------------
    def _absorb(self) -> None:
        """Match freshly-appended ReconfigRecords to the pending event."""
        recs = self.ctrl.records
        while self._seen < len(recs):
            rec = recs[self._seen]
            self._seen += 1
            p = self._pending
            if (
                p is not None
                and rec.gen_id == p.gen_id
                and rec.outcome != "retargeted"
            ):
                o = p.outcome
                o.outcome = rec.outcome
                o.mode = rec.mode
                o.commit_clock_s = self.clock
                o.met_deadline = self.clock <= p.deadline
                o.reused_layers = rec.reused_layers
                o.resident_layers = rec.resident_layers
                o.skipped_bytes = rec.skipped_bytes
                o.resident_cells = rec.resident_cells
                o.wire_bytes = rec.wire_bytes
                o.logical_bytes = rec.logical_bytes
                if rec.operating_point is not None:
                    o.operating_point = rec.operating_point
                o.pause_s = rec.total_pause_s
                self._pending = None

    def _enforce_deadline(self) -> None:
        """Escalate down the lattice when the window stops covering the
        remaining schedule (graceful degradation, paper §4.1)."""
        p = self._pending
        if p is None:
            return
        margin = (
            self.safety
            * (p.est.stop_copy_pause_s + p.est.step_s)
            * self.time_scale
        )
        if p.mode == "stream" and self.clock >= p.deadline - margin:
            if self._clocked(self.ctrl.escalate_commit) is not None:
                self._absorb()
                return
        if self.clock > p.deadline:
            # window missed with the shadow still building: drop down the
            # lattice — peer_recover when coverage holds, else checkpoint
            if p.est.peer_ok or self.ctrl.ckpt_dir:
                self.ctrl.cancel_resize(outcome="aborted")
                self._restore(p.target, p.outcome, save_first=True)
                p.outcome.met_deadline = False
                self._seen = len(self.ctrl.records)
                self._pending = None
            # else: keep trying — the reconfig will land late (met_deadline
            # False) but the run survives; aborting gains nothing

    # -- recovery rungs ---------------------------------------------------
    def _restore(self, target, o: EventOutcome, save_first: bool) -> None:
        """Below-stop-copy rungs for a *warned* event past its window:
        durable save inside the window (belt, when a ckpt_dir exists),
        then recover — the controller streams from peers when they cover
        the state and demotes to the checkpoint restore itself.

        ``save_first`` doubles as the device-health signal: a warned event
        saves inside the window and its devices are fine (warm worlds stay
        valid); an unannounced fail-stop cannot save and its devices are
        suspect (``devices_failed`` purges overlapping pool entries)."""
        if save_first and self.ctrl.ckpt_dir:
            self._clocked(self.ctrl.checkpoint_now)
        self._recover(target, o, devices_failed=not save_first)

    def _recover(
        self,
        target,
        o: EventOutcome,
        devices_failed: bool,
        lost_ranks: tuple = (),
    ) -> None:
        """The peer_recover rung (DESIGN.md §15), checkpoint demoted.

        For a warned event (``devices_failed=False``) the lost set is the
        prefix-allocation complement of the target — the same geometry the
        estimator priced — so the donor stream never reads a rank that is
        about to vanish. The controller internally demotes to the durable
        checkpoint when peers + parity cannot cover the state, and raises
        :class:`RecoveryError` when no rung is left (retired as
        ``aborted``)."""
        if not devices_failed and not lost_ranks:
            cur = self.ctrl.world.parallel.world_size
            lost_ranks = tuple(range(target.world_size, cur))
        try:
            rec = self._clocked(
                lambda: self.ctrl.fail_stop_recover(
                    target,
                    devices_failed=devices_failed,
                    lost_ranks=tuple(lost_ranks),
                )
            )
        except RecoveryError:
            # no surviving replica, no fresh parity, no durable checkpoint:
            # the honest outcome is an abort
            o.decision = o.decision or "peer_recover"
            o.outcome = "aborted"
            return
        o.decision = (
            "peer_recover" if rec.mode == "peer_recover" else "checkpoint"
        )
        o.outcome = rec.outcome
        o.mode = rec.mode
        o.commit_clock_s = self.clock
        o.pause_s = rec.total_pause_s
        self._seen = len(self.ctrl.records)

    # -- event handling ---------------------------------------------------
    def _handle_resize(self, ev: ResizeEvent, o: EventOutcome) -> None:
        target = ev.target
        p = self._pending
        window = max(0.0, ev.deadline_s - self.clock)
        o.window_s = window

        if p is not None and target == p.target:
            # duplicate warning for the in-flight target: coalesce, keeping
            # the tighter deadline
            o.decision, o.outcome = "coalesce", "coalesced"
            p.deadline = min(p.deadline, ev.deadline_s)
            return
        if p is None and target == self.ctrl.world.parallel:
            o.decision, o.outcome = "noop", "coalesced"  # already there
            return
        if p is not None and target == self.ctrl.world.parallel:
            # the newer event returns to the CURRENT config: cancel the
            # in-flight reconfiguration outright (paper §7 stale target)
            p.outcome.outcome = "retargeted"
            self.ctrl.cancel_resize(outcome="retargeted")
            self._seen = len(self.ctrl.records)
            self._pending = None
            o.decision, o.outcome = "cancel", "committed"
            return

        est = self.estimator.estimate(target)
        o.est_stream_total_s = est.stream_total_s
        o.est_stop_copy_total_s = est.stop_copy_total_s
        mode = self.mode_override or choose_mode(
            est, window, self.safety, self.time_scale
        )
        o.decision = mode
        o.decision_lossless = self.mode_override or choose_mode(
            est, window, self.safety, self.time_scale, lossless=True
        )

        # tune the rung's operating point for this (plan, window) pair —
        # measured bandwidth only; a cold estimator yields the fallback
        # constants (source="fallback") and the controller keeps its own
        bw = getattr(self.estimator, "measured_bandwidth", lambda: None)()
        op = tune_operating_point(
            est.wire_bytes,
            est.layers,
            window / self.time_scale if self.time_scale > 0 else window,
            bw,
            step_s=est.step_s,
        )
        o.operating_point = op.to_dict()

        if p is not None:
            # a newer event supersedes the in-flight reconfiguration
            p.outcome.outcome = "retargeted"
            if mode in ("checkpoint", "peer_recover"):
                self.ctrl.cancel_resize(outcome="retargeted")
                self._pending = None
                if mode == "peer_recover":
                    self._recover(target, o, devices_failed=False)
                else:
                    self._restore(target, o, save_first=True)
                return
            gen = self._clocked(
                lambda: self.ctrl.retarget_resize(
                    target, overlap=mode, operating_point=op
                )
            )
        elif mode == "peer_recover":
            # no pre-deadline work needed: the survivors keep the state in
            # memory — recover onto the target now, no disk round-trip
            self._recover(target, o, devices_failed=False)
            return
        elif mode == "checkpoint":
            self._restore(target, o, save_first=True)
            return
        else:
            gen = self._clocked(
                lambda: self.ctrl.request_resize(
                    target, overlap=mode, operating_point=op
                )
            )
        if self.sync_prepare:
            self.ctrl.wait_shadow_ready()
        o.gen_id = gen
        self._seen = len(self.ctrl.records)
        self._pending = _Pending(
            outcome=o, target=target, gen_id=gen,
            deadline=ev.deadline_s, mode=mode, est=est,
        )

    def _handle_failstop(self, ev: FailStopEvent, o: EventOutcome) -> None:
        if self._pending is not None:
            # supersede the in-flight reconfiguration on BOTH sides: the
            # controller must drop its shadow too, or the orphaned build
            # commits later to a target the event stream already abandoned
            self._pending.outcome.outcome = "retargeted"
            self.ctrl.cancel_resize(outcome="retargeted")
            self._seen = len(self.ctrl.records)
            self._pending = None
        target = ev.target
        if target is None:
            target = self._survivor_target(ev)
            if target is None:
                o.outcome = "aborted"  # no feasible surviving topology
                return
        o.target = target.describe()
        # unannounced: no pre-deadline save — source the survivor world's
        # state from peer replicas (DESIGN.md §15); the durable checkpoint
        # is the last-resort rung the controller demotes to on its own
        self._recover(
            target, o, devices_failed=True, lost_ranks=tuple(ev.lost_ranks)
        )

    def _survivor_target(self, ev: FailStopEvent):
        """Largest feasible topology over the surviving devices: the naive
        ``world - lost`` count is usually infeasible (divisibility), so walk
        down until the search finds one."""
        from repro.core.topology_search import best_target

        survivors = max(
            1, self.ctrl.world.parallel.world_size - max(1, len(ev.lost_ranks))
        )
        for world in range(survivors, 0, -1):
            try:
                return best_target(
                    self.ctrl.cfg, world, self.ctrl.global_batch,
                    self.ctrl.seq_len, max_pp=1,
                )
            except ValueError:
                continue
        return None

    def _handle(self, ev) -> None:
        o = EventOutcome(
            index=len(self.outcomes),
            kind=getattr(ev, "kind", "resize"),
            time_s=ev.time_s,
            window_s=getattr(ev, "warning_s", 0.0),
            target=ev.target.describe() if ev.target is not None else "?",
        )
        self.outcomes.append(o)
        if isinstance(ev, FailStopEvent):
            self._handle_failstop(ev, o)
        else:
            self._handle_resize(ev, o)
        self._absorb()
        if self.on_event:
            self.on_event(o)

    # -- entry point ------------------------------------------------------
    def run(self, events: list) -> ScheduleReport:
        wall0 = time.perf_counter()
        for ev in sort_trace(events):
            self._advance_to(ev.time_s)
            self._handle(ev)
        while self._pending is not None:
            self._step()
        for _ in range(self.tail_steps):
            self._clocked(lambda: self.ctrl.train_steps(1))
            self.total_steps += 1
        self._absorb()
        ledger = self.ctrl.ledger
        return ScheduleReport(
            outcomes=self.outcomes,
            steps=self.total_steps,
            duration_s=self.clock,
            wall_s=time.perf_counter() - wall0,
            goodput=ledger.goodput,
            pause_seconds=ledger.pause_seconds,
        )
