"""Deadline-aware elasticity scheduler over a control-plane endpoint
(DESIGN.md §10, §17; paper §2.3 event streams, §4.1 warning windows).

The paper's volatility numbers assume every event lands inside its warning
window; this module is the event loop that makes that true. It used to
call ``LiveRController`` methods directly — it now speaks ONLY the typed
protocol of ``elastic/protocol.py`` against an ``elastic/endpoint.py``
endpoint, so the same loop drives a live controller, a serving
controller, or a calibrated DES model, locally or (eventually) across a
real transport. For each event it

  1. estimates trigger-to-safe time for each rung of the fallback lattice
     (overlapped streaming -> stop-copy -> peer-recovery -> durable
     checkpoint) via ``query_estimate`` (or a driver-side estimator),
  2. picks the highest rung whose estimate (x safety margin) fits the
     warning window,
  3. coalesces duplicate events and retargets the in-flight reconfiguration
     when a newer event supersedes it (``retarget_resize`` adopts the
     already-streamed intersection state so the stream continues instead of
     restarting), and
  4. escalates mid-stream to stop-copy (``escalate_commit``) when the
     remaining window no longer covers the pre-copy schedule.

Trace times run on a *virtual clock*: ``clock += wall_dt * time_scale``
against live endpoints; endpoints that own a simulated clock report it in
``StepResult.clock_s`` and the trace clock follows that instead. Measured
goodput comes from the endpoint's ``query_ledger`` — real pauses, not
modeled ones — which ``benchmarks/bench_goodput.py`` reports next to the
analytic ``sim.liver_sim.volatility_run`` prediction.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.errors import ProtocolError
from repro.core.events import FailStopEvent, ResizeEvent, sort_trace
from repro.core.records import ReuseRecordMixin
from repro.elastic import protocol as p
from repro.elastic.endpoint import (
    DeadlineEstimator,
    Endpoint,
    PrefetchPolicy,
    as_endpoint,
)
from repro.elastic.protocol import ErrorResponse, ReconfigEstimate, RecordView
from repro.reshard.autotune import tune_operating_point


# ---------------------------------------------------------------------------
# The fallback-lattice decision (pure; unit-testable)
# ---------------------------------------------------------------------------


def choose_mode(
    est: ReconfigEstimate,
    window_s: float,
    safety: float = 1.25,
    time_scale: float = 1.0,
    lossless: bool = False,
) -> str:
    """The fallback lattice: highest rung whose estimate fits the window.

    overlap ("stream") completes slowest but pauses least; stop-copy
    completes right after Prepare at the price of one long pause;
    peer_recover (DESIGN.md §15) needs nothing inside the window at all —
    the survivors retain the state in device memory past the deadline and
    the donor stream runs after it — so like checkpoint it always *fits*,
    but it is only *available* when the survivor set covers the state
    (``est.peer_ok``); the checkpoint rung (durable save, restart on the
    target) is the unconditional last resort beneath it.

    ``lossless=True`` re-ranks the lattice on the uncompressed transfer
    estimates — the counterfactual decision the scheduler reports so the
    benchmark can show which events the compressed wire promoted a rung.
    """
    stream_s = est.stream_total_lossless_s if lossless else est.stream_total_s
    stop_s = (
        est.stop_copy_total_lossless_s if lossless else est.stop_copy_total_s
    )
    if stream_s * safety * time_scale <= window_s:
        return "stream"
    if stop_s * safety * time_scale <= window_s:
        return "stop_copy"
    if est.peer_ok:
        return "peer_recover"
    return "checkpoint"


# ---------------------------------------------------------------------------
# Per-event bookkeeping
# ---------------------------------------------------------------------------


@dataclass
class EventOutcome(ReuseRecordMixin):
    # reused_layers / resident_layers / skipped_bytes come from the shared
    # ReuseRecordMixin (classified plan IR, DESIGN.md §13)
    index: int
    kind: str  # resize | fail_stop
    time_s: float
    window_s: float
    target: str
    # stream | stop_copy | peer_recover | checkpoint | coalesce | cancel | noop
    decision: str = ""
    # the counterfactual rung the lattice would have picked on the
    # uncompressed transfer estimate — differs from ``decision`` exactly
    # when the compressed wire promoted this event a rung (DESIGN.md §14)
    decision_lossless: str = ""
    outcome: str = ""  # committed | retargeted | fell_back | aborted | coalesced
    gen_id: int = -1
    mode: str = ""  # ReconfigRecord.mode of the commit, when one happened
    est_stream_total_s: float = 0.0
    est_stop_copy_total_s: float = 0.0
    commit_clock_s: float = -1.0
    met_deadline: Optional[bool] = None
    pause_s: float = 0.0
    operating_point: Optional[dict] = None  # tuned data-plane parameters

    def to_dict(self) -> dict:
        # non-finite floats (infinite warning windows) render as "inf" —
        # ``json.dumps(float("inf"))`` emits non-standard ``Infinity``
        d = dict(self.__dict__)
        for k, v in d.items():
            if isinstance(v, float) and not math.isfinite(v):
                d[k] = "inf" if v > 0 else "-inf"
        return d


@dataclass
class _Pending:
    outcome: EventOutcome
    target: Any
    gen_id: int
    deadline: float
    mode: str
    est: ReconfigEstimate


@dataclass
class ScheduleReport:
    outcomes: list[EventOutcome]
    steps: int
    duration_s: float  # virtual trace time covered
    wall_s: float
    goodput: float
    pause_seconds: float

    def count(self, outcome: str) -> int:
        return sum(1 for o in self.outcomes if o.outcome == outcome)

    @property
    def aborted(self) -> int:
        return self.count("aborted")

    def to_dict(self) -> dict:
        return {
            "events": [o.to_dict() for o in self.outcomes],
            "steps": self.steps,
            "duration_s": self.duration_s,
            "wall_s": self.wall_s,
            "goodput": self.goodput,
            "pause_seconds": self.pause_seconds,
            "outcome_counts": {
                k: self.count(k)
                for k in (
                    "committed", "retargeted", "fell_back", "aborted", "coalesced",
                )
            },
        }


# ---------------------------------------------------------------------------
# The event loop
# ---------------------------------------------------------------------------


class ElasticScheduler:
    """Replays an elasticity-event trace against a control-plane endpoint.

    Accepts either an :class:`~repro.elastic.endpoint.Endpoint` or a bare
    controller (auto-wrapped in a :class:`ControllerEndpoint`). Every
    interaction with the job is a protocol message — this class holds no
    reference to the controller and never touches its attributes, which
    is what lets the fleet arbiter swap in serialized transports and
    simulated jobs.

    ``time_scale`` converts wall seconds into virtual trace seconds
    (``clock += dt * time_scale``); estimates are scaled the same way before
    deadline comparisons. ``sync_prepare`` blocks on shadow builds so replay
    is step-deterministic (parity tests / ``--check`` gates); the default
    keeps Prepare fully overlapped with training, as in the paper.
    ``estimator`` keeps rung decisions driver-side (a calibrated
    :class:`DeadlineEstimator` or a test stub); without one the scheduler
    asks the endpoint via ``query_estimate``.
    """

    def __init__(
        self,
        controller,
        time_scale: float = 1.0,
        safety: float = 1.25,
        estimator: Optional[DeadlineEstimator] = None,
        sync_prepare: bool = False,
        mode_override: Optional[str] = None,
        tail_steps: int = 2,
        max_steps: int = 5000,
        on_event: Optional[Callable[[EventOutcome], None]] = None,
        prefetch_k: int = 0,
        prefetch: Optional[PrefetchPolicy] = None,
    ):
        self.endpoint: Endpoint = as_endpoint(
            controller, prefetch=prefetch, prefetch_k=prefetch_k
        )
        self.time_scale = time_scale
        self.safety = safety
        self.estimator = estimator
        self.sync_prepare = sync_prepare
        self.mode_override = mode_override
        self.tail_steps = tail_steps
        self.max_steps = max_steps
        self.on_event = on_event
        # speculative warm-pool prefetch runs endpoint-side; the scheduler
        # only decides WHEN to tick (idle steps / mid-reconfig stream-ahead)
        self._prefetch_enabled = (
            getattr(self.endpoint, "prefetch", None) is not None
        )
        self.clock = 0.0
        self.total_steps = 0
        self.outcomes: list[EventOutcome] = []
        self._pending: Optional[_Pending] = None
        self._seen = self._status().records

    # -- protocol plumbing ----------------------------------------------
    def _send(self, cmd, allow_error: bool = False):
        resp = self.endpoint.handle(cmd)
        if isinstance(resp, ErrorResponse) and not allow_error:
            raise ProtocolError(
                f"{type(cmd).__name__} -> {resp.kind}: {resp.message}"
            )
        return resp

    def _status(self) -> p.StatusResponse:
        return self._send(p.QueryStatus())

    def _estimate(self, target) -> ReconfigEstimate:
        if self.estimator is not None:
            return self.estimator.estimate(target)
        return self._send(p.QueryEstimate(target=target)).estimate

    @property
    def prefetch(self):
        """The endpoint-side prefetch policy (bench/report convenience)."""
        return getattr(self.endpoint, "prefetch", None)

    # -- clock ----------------------------------------------------------
    def _clocked(self, fn):
        t0 = time.perf_counter()
        out = fn()
        self.clock += (time.perf_counter() - t0) * self.time_scale
        return out

    def _step(self) -> None:
        if self.total_steps >= self.max_steps:
            raise RuntimeError(
                f"scheduler exceeded max_steps={self.max_steps} "
                "(runaway trace or a reconfiguration that never commits)"
            )
        t0 = time.perf_counter()
        resp = self._send(p.TrainSteps(n=1))
        if resp.clock_s >= 0.0:
            # the endpoint owns a (simulated) clock: trace time follows it
            self.clock = max(self.clock, resp.clock_s)
        else:
            self.clock += (time.perf_counter() - t0) * self.time_scale
        self.total_steps += 1
        self._absorb()
        self._enforce_deadline()
        if self._prefetch_enabled and (
            self._pending is None or self._status().reconfig_pending
        ):
            # idle between events: warm the pool for the likely next
            # targets (speculative build threads; never during a real
            # reconfiguration — the controller refuses then). Mid-
            # reconfiguration the tick still runs, but only stream-ahead
            # prewarms the INCOMING world's failover pairs — that window
            # is exactly when those pairs must compile for a window-0
            # event right after the commit to find them warm
            self._send(p.PrefetchTick())

    def _advance_to(self, t: float) -> None:
        while self.clock < t:
            self._step()
        self.clock = max(self.clock, t)

    # -- record bookkeeping ---------------------------------------------
    def _absorb(self) -> None:
        """Match freshly-appended reconfig records to the pending event."""
        resp = self._send(p.QueryRecords(since=self._seen))
        self._seen = resp.total
        for rec in resp.records:
            pend = self._pending
            if (
                pend is not None
                and rec.gen_id == pend.gen_id
                and rec.outcome != "retargeted"
            ):
                o = pend.outcome
                o.outcome = rec.outcome
                o.mode = rec.mode
                o.commit_clock_s = self.clock
                o.met_deadline = self.clock <= pend.deadline
                o.reused_layers = rec.reused_layers
                o.resident_layers = rec.resident_layers
                o.skipped_bytes = rec.skipped_bytes
                o.resident_cells = rec.resident_cells
                o.wire_bytes = rec.wire_bytes
                o.logical_bytes = rec.logical_bytes
                if rec.operating_point is not None:
                    o.operating_point = rec.operating_point
                o.pause_s = rec.total_pause_s
                self._pending = None

    def _skip_records(self) -> None:
        """Fast-forward the absorb cursor past records the scheduler has
        already accounted for through a direct command's response."""
        self._seen = self._status().records

    def _enforce_deadline(self) -> None:
        """Escalate down the lattice when the window stops covering the
        remaining schedule (graceful degradation, paper §4.1)."""
        pend = self._pending
        if pend is None:
            return
        margin = (
            self.safety
            * (pend.est.stop_copy_pause_s + pend.est.step_s)
            * self.time_scale
        )
        if pend.mode == "stream" and self.clock >= pend.deadline - margin:
            resp = self._clocked(lambda: self._send(p.EscalateCommit()))
            if resp.escalated:
                self._absorb()
                return
        if self.clock > pend.deadline:
            # window missed with the shadow still building: drop down the
            # lattice — peer_recover when coverage holds, else checkpoint
            if pend.est.peer_ok or self._status().durable:
                self._send(p.CancelResize(outcome="aborted"))
                self._restore(pend.target, pend.outcome, save_first=True)
                pend.outcome.met_deadline = False
                self._skip_records()
                self._pending = None
            # else: keep trying — the reconfig will land late (met_deadline
            # False) but the run survives; aborting gains nothing

    # -- recovery rungs ---------------------------------------------------
    def _restore(self, target, o: EventOutcome, save_first: bool) -> None:
        """Below-stop-copy rungs for a *warned* event past its window:
        durable save inside the window (belt, when a ckpt_dir exists),
        then recover — the endpoint streams from peers when they cover
        the state and demotes to the checkpoint restore itself.

        ``save_first`` doubles as the device-health signal: a warned event
        saves inside the window and its devices are fine (warm worlds stay
        valid); an unannounced fail-stop cannot save and its devices are
        suspect (``devices_failed`` purges overlapping pool entries)."""
        if save_first and self._status().durable:
            self._clocked(lambda: self._send(p.CheckpointNow()))
        self._recover(target, o, devices_failed=not save_first)

    def _recover(
        self,
        target,
        o: EventOutcome,
        devices_failed: bool,
        lost_ranks: tuple = (),
    ) -> None:
        """The peer_recover rung (DESIGN.md §15), checkpoint demoted.

        For a warned event (``devices_failed=False``) the lost set is the
        prefix-allocation complement of the target — the same geometry the
        estimator priced — so the donor stream never reads a rank that is
        about to vanish. The endpoint internally demotes to the durable
        checkpoint when peers + parity cannot cover the state, and answers
        ``ErrorResponse("recovery")`` when no rung is left (retired as
        ``aborted``)."""
        if not devices_failed and not lost_ranks:
            cur = self._status().world_size
            lost_ranks = tuple(range(target.world_size, cur))
        resp = self._clocked(
            lambda: self._send(
                p.FailStopRecover(
                    target=target,
                    devices_failed=devices_failed,
                    lost_ranks=tuple(lost_ranks),
                ),
                allow_error=True,
            )
        )
        if isinstance(resp, ErrorResponse):
            if resp.kind != "recovery":
                raise ProtocolError(
                    f"FailStopRecover -> {resp.kind}: {resp.message}"
                )
            # no surviving replica, no fresh parity, no durable checkpoint:
            # the honest outcome is an abort
            o.decision = o.decision or "peer_recover"
            o.outcome = "aborted"
            return
        rec: RecordView = resp.record
        o.decision = (
            "peer_recover" if rec.mode == "peer_recover" else "checkpoint"
        )
        o.outcome = rec.outcome
        o.mode = rec.mode
        o.commit_clock_s = self.clock
        o.pause_s = rec.total_pause_s
        self._skip_records()

    # -- event handling ---------------------------------------------------
    def _handle_resize(self, ev: ResizeEvent, o: EventOutcome) -> None:
        target = ev.target
        pend = self._pending
        window = max(0.0, ev.deadline_s - self.clock)
        o.window_s = window
        current = self._status().parallel

        if pend is not None and target == pend.target:
            # duplicate warning for the in-flight target: coalesce, keeping
            # the tighter deadline
            o.decision, o.outcome = "coalesce", "coalesced"
            pend.deadline = min(pend.deadline, ev.deadline_s)
            return
        if pend is None and target == current:
            o.decision, o.outcome = "noop", "coalesced"  # already there
            return
        if pend is not None and target == current:
            # the newer event returns to the CURRENT config: cancel the
            # in-flight reconfiguration outright (paper §7 stale target)
            pend.outcome.outcome = "retargeted"
            self._send(p.CancelResize(outcome="retargeted"))
            self._skip_records()
            self._pending = None
            o.decision, o.outcome = "cancel", "committed"
            return

        est = self._estimate(target)
        o.est_stream_total_s = est.stream_total_s
        o.est_stop_copy_total_s = est.stop_copy_total_s
        mode = self.mode_override or choose_mode(
            est, window, self.safety, self.time_scale
        )
        o.decision = mode
        o.decision_lossless = self.mode_override or choose_mode(
            est, window, self.safety, self.time_scale, lossless=True
        )

        # tune the rung's operating point for this (plan, window) pair —
        # measured bandwidth only; a cold estimator yields the fallback
        # constants (source="fallback") and the controller keeps its own
        if self.estimator is not None:
            bw = getattr(self.estimator, "measured_bandwidth", lambda: None)()
        else:
            bw = est.measured_bw or None
        op = tune_operating_point(
            est.wire_bytes,
            est.layers,
            window / self.time_scale if self.time_scale > 0 else window,
            bw,
            step_s=est.step_s,
        )
        o.operating_point = op.to_dict()

        if pend is not None:
            # a newer event supersedes the in-flight reconfiguration
            pend.outcome.outcome = "retargeted"
            if mode in ("checkpoint", "peer_recover"):
                self._send(p.CancelResize(outcome="retargeted"))
                self._pending = None
                if mode == "peer_recover":
                    self._recover(target, o, devices_failed=False)
                else:
                    self._restore(target, o, save_first=True)
                return
            gen = self._clocked(
                lambda: self._send(
                    p.RetargetResize(
                        target=target, overlap=mode,
                        operating_point=op.to_dict(),
                    )
                )
            ).gen_id
        elif mode == "peer_recover":
            # no pre-deadline work needed: the survivors keep the state in
            # memory — recover onto the target now, no disk round-trip
            self._recover(target, o, devices_failed=False)
            return
        elif mode == "checkpoint":
            self._restore(target, o, save_first=True)
            return
        else:
            gen = self._clocked(
                lambda: self._send(
                    p.RequestResize(
                        target=target, overlap=mode,
                        operating_point=op.to_dict(),
                    )
                )
            ).gen_id
        if self.sync_prepare:
            self._send(p.WaitShadowReady())
        o.gen_id = gen
        self._skip_records()
        self._pending = _Pending(
            outcome=o, target=target, gen_id=gen,
            deadline=ev.deadline_s, mode=mode, est=est,
        )

    def _handle_failstop(self, ev: FailStopEvent, o: EventOutcome) -> None:
        if self._pending is not None:
            # supersede the in-flight reconfiguration on BOTH sides: the
            # controller must drop its shadow too, or the orphaned build
            # commits later to a target the event stream already abandoned
            self._pending.outcome.outcome = "retargeted"
            self._send(p.CancelResize(outcome="retargeted"))
            self._skip_records()
            self._pending = None
        target = ev.target
        if target is None:
            target = self._send(
                p.QuerySurvivorTarget(lost_ranks=tuple(ev.lost_ranks))
            ).target
            if target is None:
                o.outcome = "aborted"  # no feasible surviving topology
                return
        o.target = target.describe()
        # unannounced: no pre-deadline save — source the survivor world's
        # state from peer replicas (DESIGN.md §15); the durable checkpoint
        # is the last-resort rung the endpoint demotes to on its own
        self._recover(
            target, o, devices_failed=True, lost_ranks=tuple(ev.lost_ranks)
        )

    def _handle(self, ev) -> None:
        o = EventOutcome(
            index=len(self.outcomes),
            kind=getattr(ev, "kind", "resize"),
            time_s=ev.time_s,
            window_s=getattr(ev, "warning_s", 0.0),
            target=ev.target.describe() if ev.target is not None else "?",
        )
        self.outcomes.append(o)
        if isinstance(ev, FailStopEvent):
            self._handle_failstop(ev, o)
        else:
            self._handle_resize(ev, o)
        self._absorb()
        if self.on_event:
            self.on_event(o)

    # -- entry point ------------------------------------------------------
    def run(self, events: list) -> ScheduleReport:
        wall0 = time.perf_counter()
        for ev in sort_trace(events):
            self._advance_to(ev.time_s)
            self._handle(ev)
        while self._pending is not None:
            self._step()
        for _ in range(self.tail_steps):
            self._clocked(lambda: self._send(p.TrainSteps(n=1)))
            self.total_steps += 1
        self._absorb()
        ledger = self._send(p.QueryLedger())
        return ScheduleReport(
            outcomes=self.outcomes,
            steps=self.total_steps,
            duration_s=self.clock,
            wall_s=time.perf_counter() - wall0,
            goodput=ledger.goodput,
            pause_seconds=ledger.pause_seconds,
        )
