"""Serializable control-plane protocol (DESIGN.md §17).

Every interaction between a driver (``ElasticScheduler``, the fleet
arbiter) and a controller-like object is a typed Command answered by a
typed Response, both plain dataclasses that round-trip through JSON
bit-identically::

    encode(msg) -> dict -> json.dumps -> json.loads -> decode -> msg

The scheduler used to call ``LiveRController`` methods directly; moving
the boundary onto this wire format is what lets one driver address a
live controller, a serving controller, or a calibrated DES model
(``elastic/endpoint.py``) interchangeably — and is the prerequisite for
real multi-host deployment, where these dicts become RPC payloads.

Wire format
-----------
Each message encodes to a JSON object carrying the schema version and a
registered type tag::

    {"v": 1, "type": "request_resize", "target": {"dp": 2, ...}, ...}

Versioning rule: *additive* changes (a new message type, a new field
with a default) keep ``PROTOCOL_VERSION``; decoders ignore unknown
fields and apply defaults for missing ones, so old messages stay
readable. Any change that alters the meaning or encoding of an existing
field bumps the version, and the golden transcript
(``tests/golden/protocol_v<N>.jsonl``) is frozen per version. Decoding a
message from a *newer* major version raises :class:`ProtocolError`.

Non-JSON scalars follow repo convention: non-finite floats encode as the
strings ``"inf"`` / ``"-inf"`` / ``"nan"``. ``ParallelConfig`` encodes
as its axis dict and decodes back to the real frozen dataclass so
equality survives the wire. Tuples decode back to tuples (JSON arrays
are otherwise ambiguous), keyed off the declared field annotations.

Regenerate the golden transcript after an additive change with::

    PYTHONPATH=src python -m repro.elastic.protocol tests/golden/protocol_v1.jsonl
"""

from __future__ import annotations

import dataclasses
import json
import math
import sys
import typing
from dataclasses import dataclass, fields
from typing import Any, Optional, Union

from repro.configs.base import ParallelConfig
from repro.core.errors import ProtocolError
from repro.core.events import FailStopEvent, ResizeEvent

PROTOCOL_VERSION = 1

# type tag -> message class, and the reverse (for encode)
_REGISTRY: dict[str, type] = {}
_TYPE_OF: dict[type, str] = {}


def register(type_name: str, cls: Optional[type] = None):
    """Register ``cls`` under ``type_name``. Usable as a decorator
    (``@register("ack")``) or directly for classes defined elsewhere
    (``register("resize_event", ResizeEvent)``)."""

    def _do(c: type) -> type:
        if type_name in _REGISTRY:
            raise ValueError(f"duplicate protocol type {type_name!r}")
        _REGISTRY[type_name] = c
        _TYPE_OF[c] = type_name
        return c

    return _do(cls) if cls is not None else _do


# ---------------------------------------------------------------------------
# Encode / decode
# ---------------------------------------------------------------------------


def _enc(v: Any) -> Any:
    if isinstance(v, ParallelConfig):
        return {"dp": v.dp, "pp": v.pp, "tp": v.tp, "ep": v.ep}
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {f.name: _enc(getattr(v, f.name)) for f in fields(v)}
    if isinstance(v, (list, tuple)):
        return [_enc(x) for x in v]
    if isinstance(v, float) and not math.isfinite(v):
        return "inf" if v > 0 else ("-inf" if v < 0 else "nan")
    if isinstance(v, dict):
        return {k: _enc(x) for k, x in v.items()}
    return v


def encode(msg: Any) -> dict:
    """Message dataclass -> JSON-ready dict (with version + type tag)."""
    tag = _TYPE_OF.get(type(msg))
    if tag is None:
        raise ProtocolError(f"unregistered message type {type(msg).__name__}")
    out: dict = {"v": PROTOCOL_VERSION, "type": tag}
    for f in fields(msg):
        out[f.name] = _enc(getattr(msg, f.name))
    return out


def _dec(v: Any, hint: Any) -> Any:
    origin = typing.get_origin(hint)
    if origin is Union:
        args = [a for a in typing.get_args(hint) if a is not type(None)]
        if v is None:
            return None
        return _dec(v, args[0]) if len(args) == 1 else v
    if v is None:
        return None
    if hint is ParallelConfig:
        return ParallelConfig(**{k: int(v[k]) for k in ("dp", "pp", "tp", "ep")})
    if isinstance(hint, type) and dataclasses.is_dataclass(hint):
        hints = typing.get_type_hints(hint)
        kw = {
            f.name: _dec(v[f.name], hints[f.name])
            for f in fields(hint)
            if f.name in v
        }
        return hint(**kw)
    if origin in (tuple, list):
        args = typing.get_args(hint)
        elem = args[0] if args else Any
        return tuple(_dec(x, elem) for x in v)
    if hint is float:
        if isinstance(v, str):
            return float(v)  # "inf" / "-inf" / "nan"
        return float(v)
    if hint is int:
        return int(v)
    return v


def decode(obj: dict) -> Any:
    """JSON dict -> message dataclass. Unknown fields are ignored
    (forward compatibility); missing fields take dataclass defaults."""
    if not isinstance(obj, dict) or "type" not in obj:
        raise ProtocolError(f"not a protocol message: {obj!r}")
    v = obj.get("v", 0)
    if not isinstance(v, int) or v > PROTOCOL_VERSION:
        raise ProtocolError(
            f"message version {v!r} newer than supported {PROTOCOL_VERSION}"
        )
    cls = _REGISTRY.get(obj["type"])
    if cls is None:
        raise ProtocolError(f"unknown message type {obj['type']!r}")
    hints = typing.get_type_hints(cls)
    kw = {}
    for f in fields(cls):
        if f.name in obj:
            kw[f.name] = _dec(obj[f.name], hints[f.name])
        elif (
            f.default is dataclasses.MISSING
            and f.default_factory is dataclasses.MISSING
        ):
            raise ProtocolError(
                f"{obj['type']}: missing required field {f.name!r}"
            )
    try:
        return cls(**kw)
    except (TypeError, ValueError) as e:
        raise ProtocolError(f"{obj['type']}: {e}") from e


def dumps(msg: Any) -> str:
    """Canonical wire text: sorted keys, no whitespace — the form the
    golden transcript freezes."""
    return json.dumps(encode(msg), sort_keys=True, separators=(",", ":"))


def loads(text: str) -> Any:
    try:
        obj = json.loads(text)
    except json.JSONDecodeError as e:
        raise ProtocolError(f"malformed wire text: {e}") from None
    if not isinstance(obj, dict):
        raise ProtocolError(f"wire text must be a JSON object, got {type(obj).__name__}")
    return decode(obj)


# ---------------------------------------------------------------------------
# Shared payloads (nested in messages; not independently tagged)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ReconfigEstimate:
    """Trigger-to-safe time estimates for one candidate reconfiguration.

    All in real seconds; the scheduler converts with its ``time_scale``
    before comparing to a (virtual-time) warning window.
    """

    prepare_s: float  # shadow build: mesh + lower + compile
    precopy_s: float  # streaming rounds riding iteration boundaries
    stream_pause_s: float  # commit pause of the overlapped path
    stop_copy_pause_s: float  # whole transfer inside one pause
    plan_bytes: int
    rounds: int
    step_s: float
    # prepare_s is the WARM estimate: the controller's pool holds a ready
    # world for the target, so Prepare skips lower+compile
    warm: bool = False
    # wire pricing (DESIGN.md §14): the pause estimates above are priced on
    # wire_bytes (what crosses the interconnect under the controller's
    # WirePolicy); lossless_transfer_s is what the same plan would cost
    # uncompressed, so the scheduler can report which rung the event would
    # have gotten without compression
    wire_bytes: int = 0
    layers: int = 0
    lossless_transfer_s: float = 0.0
    # peer_recover rung (DESIGN.md §15): True when the survivor set (plus
    # fresh parity) covers the state, so an in-memory donor stream can
    # replace the checkpoint round-trip; peer_pause_s prices that stream
    # (warm/cold prepare + donor bytes at measured bandwidth, lossless —
    # the recovery stream never compresses)
    peer_ok: bool = False
    peer_bytes: int = 0
    peer_pause_s: float = 0.0
    # measured transfer bandwidth behind the estimate (0.0 = no history
    # yet); carried on the wire so a remote driver can tune the rung's
    # operating point without reaching into the endpoint's estimator
    measured_bw: float = 0.0

    @property
    def stream_total_s(self) -> float:
        """Trigger -> committed via overlapped streaming."""
        return self.prepare_s + self.precopy_s + self.stream_pause_s

    @property
    def stop_copy_total_s(self) -> float:
        """Trigger -> committed via stop-copy (no boundary rounds)."""
        return self.prepare_s + self.stop_copy_pause_s

    @property
    def stream_total_lossless_s(self) -> float:
        """stream_total_s had the plan moved uncompressed."""
        return self.prepare_s + self.precopy_s + self.lossless_transfer_s

    @property
    def stop_copy_total_lossless_s(self) -> float:
        """stop_copy_total_s had the plan moved uncompressed."""
        return self.prepare_s + self.lossless_transfer_s


@dataclass(frozen=True)
class RecordView:
    """The wire projection of a ``ReconfigRecord`` / ``ServeRecord`` —
    exactly the fields the scheduler's absorb loop, the benchmarks and
    the fleet arbiter consume. Endpoints keep the full record private;
    drivers never see controller internals."""

    gen_id: int
    src: str = ""
    dst: str = ""
    mode: str = "live"
    outcome: str = "committed"
    prepare_s: float = 0.0
    total_pause_s: float = 0.0
    reused_layers: int = 0
    resident_layers: int = 0
    resident_cells: int = 0
    skipped_bytes: int = 0
    wire_bytes: int = 0
    logical_bytes: int = 0
    warm_hit: bool = False
    prepare_source: str = "cold"
    operating_point: Optional[dict] = None

    @classmethod
    def from_record(cls, rec: Any) -> "RecordView":
        op = getattr(rec, "operating_point", None)
        if op is not None and not isinstance(op, dict):
            op = op.to_dict()
        return cls(
            gen_id=int(getattr(rec, "gen_id", 0)),
            src=str(getattr(rec, "src", "")),
            dst=str(getattr(rec, "dst", "")),
            mode=str(getattr(rec, "mode", "live")),
            outcome=str(getattr(rec, "outcome", "committed")),
            prepare_s=float(getattr(rec, "prepare_s", 0.0)),
            total_pause_s=float(
                getattr(rec, "total_pause_s", getattr(rec, "pause_s", 0.0))
            ),
            reused_layers=int(getattr(rec, "reused_layers", 0)),
            resident_layers=int(getattr(rec, "resident_layers", 0)),
            resident_cells=int(getattr(rec, "resident_cells", 0)),
            skipped_bytes=int(getattr(rec, "skipped_bytes", 0)),
            wire_bytes=int(getattr(rec, "wire_bytes", 0)),
            logical_bytes=int(getattr(rec, "logical_bytes", 0)),
            warm_hit=bool(getattr(rec, "warm_hit", False)),
            prepare_source=str(getattr(rec, "prepare_source", "cold")),
            operating_point=op,
        )


# ---------------------------------------------------------------------------
# Commands (driver -> endpoint)
# ---------------------------------------------------------------------------


@register("train_steps")
@dataclass(frozen=True)
class TrainSteps:
    n: int = 1


@register("request_resize")
@dataclass(frozen=True)
class RequestResize:
    target: ParallelConfig
    overlap: Optional[str] = None  # "stream" | "stop_copy" | None
    # a tuned OperatingPoint's to_dict() (reshard/autotune.py); kept a
    # plain dict on the wire so the schema doesn't chase tuner fields
    operating_point: Optional[dict] = None


@register("retarget_resize")
@dataclass(frozen=True)
class RetargetResize:
    target: ParallelConfig
    overlap: Optional[str] = None
    operating_point: Optional[dict] = None


@register("escalate_commit")
@dataclass(frozen=True)
class EscalateCommit:
    pass


@register("cancel_resize")
@dataclass(frozen=True)
class CancelResize:
    outcome: Optional[str] = None


@register("fail_stop_recover")
@dataclass(frozen=True)
class FailStopRecover:
    target: ParallelConfig
    devices_failed: bool = True
    lost_ranks: tuple[int, ...] = ()


@register("checkpoint_now")
@dataclass(frozen=True)
class CheckpointNow:
    pass


@register("prefetch_world")
@dataclass(frozen=True)
class PrefetchWorld:
    target: ParallelConfig


@register("prefetch_tick")
@dataclass(frozen=True)
class PrefetchTick:
    pass


@register("wait_shadow_ready")
@dataclass(frozen=True)
class WaitShadowReady:
    timeout: Optional[float] = None


@register("query_status")
@dataclass(frozen=True)
class QueryStatus:
    pass


@register("query_records")
@dataclass(frozen=True)
class QueryRecords:
    since: int = 0  # record index; the response returns records[since:]


@register("query_estimate")
@dataclass(frozen=True)
class QueryEstimate:
    target: ParallelConfig


@register("query_ledger")
@dataclass(frozen=True)
class QueryLedger:
    pass


@register("query_survivor_target")
@dataclass(frozen=True)
class QuerySurvivorTarget:
    lost_ranks: tuple[int, ...] = ()


# ---------------------------------------------------------------------------
# Responses (endpoint -> driver)
# ---------------------------------------------------------------------------


@register("ack")
@dataclass(frozen=True)
class Ack:
    ok: bool = True
    detail: str = ""


@register("step_result")
@dataclass(frozen=True)
class StepResult:
    steps: int = 0
    # endpoints that own a virtual clock (SimEndpoint) report it here so
    # the driver's trace clock can follow simulated time; live endpoints
    # return -1.0 and the driver falls back to scaled wall time
    clock_s: float = -1.0


@register("resize_started")
@dataclass(frozen=True)
class ResizeStarted:
    gen_id: int


@register("escalate_result")
@dataclass(frozen=True)
class EscalateResult:
    escalated: bool
    record: Optional[RecordView] = None


@register("recover_result")
@dataclass(frozen=True)
class RecoverResult:
    record: RecordView


@register("prefetch_result")
@dataclass(frozen=True)
class PrefetchResult:
    started: int = 0


@register("status")
@dataclass(frozen=True)
class StatusResponse:
    parallel: ParallelConfig
    world_size: int
    step: int = 0
    reconfig_pending: bool = False
    durable: bool = False  # a checkpoint directory backs the last rung
    records: int = 0  # record count (drivers use it to resync absorb)
    kind: str = "train"  # "train" | "serve" | "sim"


@register("records")
@dataclass(frozen=True)
class RecordsResponse:
    records: tuple[RecordView, ...] = ()
    total: int = 0


@register("estimate")
@dataclass(frozen=True)
class EstimateResponse:
    estimate: ReconfigEstimate


@register("ledger")
@dataclass(frozen=True)
class LedgerResponse:
    goodput: float = 0.0
    pause_seconds: float = 0.0
    train_gpu_seconds: float = 0.0
    steps: int = 0
    samples: float = 0.0


@register("target")
@dataclass(frozen=True)
class TargetResponse:
    target: Optional[ParallelConfig] = None


@register("error")
@dataclass(frozen=True)
class ErrorResponse:
    kind: str  # "recovery" | "unsupported" | "invalid" | "internal"
    message: str = ""


# Events (arbiter -> driver): the existing core dataclasses go on the
# wire unchanged — registering them here keeps one codec for the whole
# control plane.
register("resize_event", ResizeEvent)
register("fail_stop_event", FailStopEvent)


COMMANDS = (
    TrainSteps, RequestResize, RetargetResize, EscalateCommit, CancelResize,
    FailStopRecover, CheckpointNow, PrefetchWorld, PrefetchTick,
    WaitShadowReady, QueryStatus, QueryRecords, QueryEstimate, QueryLedger,
    QuerySurvivorTarget,
)
RESPONSES = (
    Ack, StepResult, ResizeStarted, EscalateResult, RecoverResult,
    PrefetchResult, StatusResponse, RecordsResponse, EstimateResponse,
    LedgerResponse, TargetResponse, ErrorResponse,
)
EVENTS = (ResizeEvent, FailStopEvent)


# ---------------------------------------------------------------------------
# Golden transcript (tests/golden/protocol_v1.jsonl)
# ---------------------------------------------------------------------------


def golden_messages() -> list:
    """One representative instance per registered type, deterministic,
    exercising the tricky encodings: nested records, tuples, Optionals,
    non-finite floats. The committed golden file freezes ``dumps`` of
    each; tests/test_protocol.py diffs against it byte-for-byte."""
    tgt = ParallelConfig(dp=2, pp=1, tp=2)
    rec = RecordView(
        gen_id=3, src="dp4xpp1xtp1", dst="dp2xpp1xtp2", mode="live_overlap",
        outcome="committed", prepare_s=1.25, total_pause_s=0.125,
        reused_layers=4, resident_layers=2, resident_cells=9,
        skipped_bytes=1 << 20, wire_bytes=2048, logical_bytes=4096,
        warm_hit=True, prepare_source="pool",
        operating_point={"stream_k": 4, "chunk_bytes": 1 << 16,
                         "staging_bytes": 1 << 20, "source": "tuned"},
    )
    est = ReconfigEstimate(
        prepare_s=20.0, precopy_s=1.5, stream_pause_s=0.25,
        stop_copy_pause_s=2.5, plan_bytes=1 << 24, rounds=3, step_s=0.25,
        warm=False, wire_bytes=1 << 23, layers=12, lossless_transfer_s=5.0,
        peer_ok=True, peer_bytes=1 << 22, peer_pause_s=0.75,
        measured_bw=2.5e9,
    )
    return [
        TrainSteps(n=4),
        RequestResize(target=tgt, overlap="stream",
                      operating_point={"stream_k": 8, "chunk_bytes": 65536,
                                       "staging_bytes": 1 << 21,
                                       "source": "tuned"}),
        RetargetResize(target=ParallelConfig(dp=1, tp=2), overlap="stop_copy"),
        EscalateCommit(),
        CancelResize(outcome="skipped"),
        FailStopRecover(target=ParallelConfig(dp=2), devices_failed=True,
                        lost_ranks=(2, 3)),
        CheckpointNow(),
        PrefetchWorld(target=tgt),
        PrefetchTick(),
        WaitShadowReady(timeout=30.0),
        QueryStatus(),
        QueryRecords(since=2),
        QueryEstimate(target=tgt),
        QueryLedger(),
        QuerySurvivorTarget(lost_ranks=(6, 7)),
        Ack(ok=True, detail="checkpointed"),
        StepResult(steps=1, clock_s=12.5),
        ResizeStarted(gen_id=7),
        EscalateResult(escalated=True, record=rec),
        RecoverResult(record=rec),
        PrefetchResult(started=2),
        StatusResponse(parallel=tgt, world_size=4, step=120,
                       reconfig_pending=True, durable=True, records=5,
                       kind="train"),
        RecordsResponse(records=(rec,), total=4),
        EstimateResponse(estimate=est),
        LedgerResponse(goodput=0.9375, pause_seconds=12.5,
                       train_gpu_seconds=4000.0, steps=800, samples=204800.0),
        TargetResponse(target=ParallelConfig(dp=2, tp=1)),
        TargetResponse(target=None),
        ErrorResponse(kind="recovery", message="survivors do not cover state"),
        ResizeEvent(time_s=60.0, target=tgt, warning_s=120.0),
        ResizeEvent(time_s=90.0, target=ParallelConfig(dp=4),
                    warning_s=float("inf")),
        FailStopEvent(time_s=180.0, lost_ranks=(2, 3),
                      target=ParallelConfig(dp=1, tp=2)),
    ]


def write_golden(path: str) -> None:
    with open(path, "w") as f:
        for msg in golden_messages():
            f.write(dumps(msg) + "\n")


if __name__ == "__main__":
    write_golden(sys.argv[1])
