"""Fault-injection harness for peer recovery (DESIGN.md §15).

Recovery that only works between steps is not recovery. This module kills
device subsets at each phase of the controller's reconfiguration lifecycle
— at an idle iteration boundary, mid-stream (an overlap session with
layers still to pre-copy), and mid-commit (the split-step switch armed for
the next step) — and drives ``fail_stop_recover`` from exactly that state,
so the tests and ``benchmarks/bench_faults.py`` can prove the recovery
path holds everywhere, not just in the easy case.

The cluster here is emulated (host devices), so "killing" rank r means:
every byte r exclusively held must be reconstructable without reading it.
The harness enforces that structurally rather than by trusting the
transfer: it asserts the executed plan's remote tasks never name a dead
rank as source (the survivor-constrained planner guarantees this by
construction; the assertion catches regressions in that construction).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ParallelConfig
from repro.core.errors import RecoveryError
from repro.core.reshard import plan_state_transfer
from repro.elastic.redundancy import survivors_for

__all__ = ["PHASES", "FaultInjector", "InjectionReport", "controller_phase"]

# lifecycle phases a fault can land in, orthogonal to the recovery scheme
PHASES = ("idle", "mid_stream", "mid_commit")


def controller_phase(ctrl) -> str:
    """Classify the controller's reconfiguration phase at a step boundary.

    ``mid_commit``: the split-step switch is armed — the NEXT train step
    would commit the generation. ``mid_stream``: an overlap session is
    live with pre-copy layers outstanding. ``idle``: neither (a shadow
    may still be building; its loss is covered by the idle case since no
    state has moved yet).
    """
    if getattr(ctrl, "_commit_armed", False):
        return "mid_commit"
    session = getattr(ctrl, "_session", None)
    if session is not None and not session.done_precopy:
        return "mid_stream"
    return "idle"


@dataclass
class InjectionReport:
    phase: str  # phase the fault actually landed in
    lost_ranks: tuple[int, ...]
    step_before: int
    step_after: int
    mode: str  # peer_recover | fallback
    outcome: str  # committed | fell_back
    donors: int
    parity_bytes: int
    pause_s: float
    demoted: bool  # True when the checkpoint rung had to serve


@dataclass
class FaultInjector:
    """Drive a controller to a lifecycle phase, then kill devices there.

    ``ctrl`` is a live :class:`~repro.core.controller.LiveRController`;
    the injector owns the stepping loop so the fault lands between a step
    and its boundary poll — the same cut an external failure detector
    would observe.
    """

    ctrl: object
    reports: list[InjectionReport] = field(default_factory=list)

    def run_until(self, phase: str, max_steps: int = 64) -> bool:
        """Train one step at a time until the controller sits in ``phase``.

        Reaching ``mid_stream``/``mid_commit`` requires the caller to have
        started a resize (``begin_resize``) first; returns False when the
        phase never shows up within ``max_steps`` (e.g. the stream
        finished too fast — retry with a smaller ``stream_k``).
        """
        assert phase in PHASES, phase
        for _ in range(max_steps):
            if controller_phase(self.ctrl) == phase:
                return True
            self.ctrl.train_steps(1)
        return controller_phase(self.ctrl) == phase

    def kill(
        self,
        target: ParallelConfig,
        lost_ranks: tuple[int, ...] = (),
        expect_phase: Optional[str] = None,
    ) -> InjectionReport:
        """Fail-stop the ``lost_ranks`` device subset right now.

        Asserts the survivor-constrained recovery plan never sources a
        dead rank, then runs the controller's recovery from whatever
        lifecycle state it is in. Raises :class:`RecoveryError` through
        unchanged when no rung can serve.
        """
        phase = controller_phase(self.ctrl)
        if expect_phase is not None:
            assert phase == expect_phase, (
                f"fault landed in phase {phase!r}, wanted {expect_phase!r}"
            )
        src = self.ctrl.world.parallel
        survivors = survivors_for(
            src, lost_ranks, target=target, devices_failed=True
        )
        dead = frozenset(range(src.world_size)) - survivors
        _, plan = plan_state_transfer(
            self.ctrl.cfg, src, target,
            source_policy=self.ctrl.source_policy, allowed_src=survivors,
        )
        leaks = [t for t in plan.tasks if t.kind != "lost" and t.src_rank in dead]
        assert not leaks, (
            f"survivor-constrained plan reads dead ranks: "
            f"{[(t.tensor, t.src_rank) for t in leaks[:5]]}"
        )

        step_before = self.ctrl.step
        rec = self.ctrl.fail_stop_recover(
            target, devices_failed=True, lost_ranks=tuple(lost_ranks)
        )
        report = InjectionReport(
            phase=phase,
            lost_ranks=tuple(sorted(dead)),
            step_before=step_before,
            step_after=self.ctrl.step,
            mode=rec.mode,
            outcome=rec.outcome,
            donors=getattr(rec, "donors", 0),
            parity_bytes=getattr(rec, "parity_bytes", 0),
            pause_s=rec.total_pause_s,
            demoted=rec.mode != "peer_recover",
        )
        self.reports.append(report)
        return report

    def inject(
        self,
        phase: str,
        target: ParallelConfig,
        lost_ranks: tuple[int, ...] = (),
        resize_target: Optional[ParallelConfig] = None,
        max_steps: int = 64,
    ) -> InjectionReport:
        """Reach ``phase`` (starting a resize toward ``resize_target`` when
        one is needed to create stream/commit activity), then kill."""
        if phase in ("mid_stream", "mid_commit") and resize_target is not None:
            if not getattr(self.ctrl, "reconfig_pending", False):
                self.ctrl.request_resize(resize_target, overlap="stream")
            # deterministic phase entry: the shadow build is asynchronous,
            # so without this wait the stepping loop below races the cold
            # compile and may never observe the streaming window
            self.ctrl.wait_shadow_ready()
        if not self.run_until(phase, max_steps=max_steps):
            raise RecoveryError(
                f"could not drive the controller into phase {phase!r} "
                f"within {max_steps} steps"
            )
        return self.kill(target, lost_ranks, expect_phase=phase)
