"""Control-plane endpoints: what a driver's protocol messages land on
(DESIGN.md §17).

An :class:`Endpoint` is the server side of ``elastic/protocol.py`` — it
owns a controller-like object plus the controller-facing helpers
(:class:`DeadlineEstimator`, :class:`PrefetchPolicy`, both moved here
from ``scheduler.py``: they read controller internals, so they belong
behind the protocol boundary, not in the driver). Adapters ship for
every controller species:

* :class:`ControllerEndpoint` — the live training controller
  (``LiveRController``, or any duck-typed fake with the same verbs);
* :class:`ServeEndpoint` — the elastic serving controller
  (``LiveServeController``), answering the status/record/resize subset;
* :class:`SimEndpoint` — no devices at all: answers the full protocol
  from the calibrated ``sim/cluster.py`` model on the ``sim/des.py``
  virtual clock, which is what lets the fleet arbiter drive 100 jobs in
  milliseconds;
* :class:`WireEndpoint` — a transparent wrapper that forces every
  command *and* response through ``encode → JSON text → decode``, so a
  test or bench running through it has proven the whole conversation is
  serializable (the local stand-in for a real RPC transport).

The adapter contract: ``handle(cmd)`` always returns a protocol
response, mapping :class:`RecoveryError` to ``ErrorResponse("recovery")``
and unsupported verbs to ``ErrorResponse("unsupported")``; any other
exception is a bug and propagates.
"""

from __future__ import annotations

import math
from typing import Any, Optional

from repro.configs.base import ParallelConfig
from repro.core.downtime import GoodputLedger
from repro.core.errors import RecoveryError
from repro.elastic import protocol as p
from repro.elastic.protocol import (
    Ack,
    EscalateResult,
    EstimateResponse,
    ErrorResponse,
    LedgerResponse,
    PrefetchResult,
    ReconfigEstimate,
    RecordView,
    RecordsResponse,
    RecoverResult,
    ResizeStarted,
    StatusResponse,
    StepResult,
    TargetResponse,
)
from repro.reshard.autotune import OperatingPoint


def _median(xs: list) -> Optional[float]:
    xs = sorted(x for x in xs if x > 0)
    return xs[len(xs) // 2] if xs else None


# ---------------------------------------------------------------------------
# Controller-side helpers (moved from scheduler.py — they read controller
# internals, which drivers may no longer do)
# ---------------------------------------------------------------------------


class DeadlineEstimator:
    """prepare+stream estimates from plan metadata and reconfig history.

    Bytes come from the same ``plan_state_transfer`` machinery that fills
    the shadow world's ``plan_bundle`` (a ready bundle for the right target
    is used as-is); seconds come from the recent ``ReconfigRecord``s —
    median prepare time and effective transfer bandwidth — falling back to
    the constructor defaults until history exists.
    """

    def __init__(
        self,
        controller,
        default_prepare_s: float = 20.0,
        default_warm_prepare_s: float = 1.0,
        default_bw_bytes_s: float = 1e9,
        default_step_s: float = 0.25,
        history: int = 8,
    ):
        self.ctrl = controller
        self.default_prepare_s = default_prepare_s
        self.default_warm_prepare_s = default_warm_prepare_s
        self.default_bw = default_bw_bytes_s
        self.default_step_s = default_step_s
        self.history = history

    # -- history --------------------------------------------------------
    def _recent(self, warm: Optional[bool] = None) -> list:
        # every record whose Prepare actually completed is a valid sample,
        # not just committed ones: after a retarget-heavy stretch the
        # committed subset can be empty and a committed-only filter made
        # the estimator silently fall back to its defaults. ``fell_back``
        # on a live mode means an escalated commit (prepare finished);
        # ``retargeted`` records count only when their prepare finished
        # before supersession (prepare_s > 0 — mid-prepare retargets
        # carry no timing).
        recs = [
            r
            for r in self.ctrl.records
            if r.mode in ("live", "live_overlap")
            and (r.outcome in ("committed", "fell_back") or r.prepare_s > 0)
        ]
        if warm is not None:
            if warm:
                recs = [r for r in recs if getattr(r, "warm_hit", False)]
            else:
                # a speculative join measures neither a warm Prepare (the
                # compile ran) nor a cold one (only the residual wait was
                # timed) — sampling it as cold would drag the cold median
                # toward zero and mis-rank the lattice for true cold events
                recs = [
                    r
                    for r in recs
                    if not getattr(r, "warm_hit", False)
                    and getattr(r, "prepare_source", "cold")
                    != "speculative_join"
                ]
        return recs[-self.history :]

    def prepare_estimate(self, warm: bool = False) -> float:
        """Median prepare time over recent records of the requested kind:
        warm (pool hit — lower+compile skipped) and cold prepares differ by
        orders of magnitude, so one blended median would make the lattice
        reject the overlap rung exactly when a warm world makes it cheap."""
        m = _median([r.prepare_s for r in self._recent(warm=warm)])
        if m is not None:
            return m
        if warm:
            # no warm history yet: a pool hit skips lower+compile, leaving
            # planning + bookkeeping — bounded above by the cold estimate
            return min(self.prepare_estimate(warm=False),
                       self.default_warm_prepare_s)
        # cold start: the gen-0 world's own build timings are the best proxy
        t = self.ctrl.world.timings
        seed = sum(t.get(k, 0.0) for k in ("mesh_s", "lower_s", "compile_s"))
        return seed or self.default_prepare_s

    def measured_bandwidth(self) -> Optional[float]:
        """Median transfer bandwidth over recent records, or ``None`` with
        no history yet (the operating-point tuner treats None as "fall back
        to the hand-set constants").

        With a wire policy on the controller, bandwidth is measured in
        PHYSICAL wire bytes per second so that pricing ``est.wire_bytes``
        and the lossless counterfactual against it stay on one scale;
        lossless controllers keep the historical moved-bytes measure."""
        compressed = getattr(self.ctrl, "wire_policy", None) is not None
        bws = []
        for r in self._recent():
            moved = r.moved_bytes
            if compressed:
                moved = getattr(r, "wire_bytes", 0) or r.moved_bytes
            secs = r.transfer_s + r.resync_s + r.precopy_s
            if moved > 0 and secs > 0:
                bws.append(moved / secs)
        return _median(bws)

    def bandwidth_estimate(self) -> float:
        return self.measured_bandwidth() or self.default_bw

    def step_estimate(self) -> float:
        return _median(list(self.ctrl.iteration_times)[-16:]) or self.default_step_s

    # -- the estimate ---------------------------------------------------
    def _price_plan(self, plan) -> tuple[int, int, int]:
        """(logical bytes, wire bytes, streaming layers) of a plan.

        Priced on the classified plan IR (DESIGN.md §13): bytes are REMOTE
        only — resident cells never move and local relayouts never cross a
        wire — and fully-resident layers need no pre-copy rounds. This is
        what lets a tp-preserving resize fit the overlap rung inside a
        warning window its full-copy byte count would have blown. Wire
        bytes price the same remote tasks under the controller's WirePolicy
        (DESIGN.md §14); equal to logical bytes when lossless."""
        from repro.reshard.wire import wire_nbytes

        policy = getattr(self.ctrl, "wire_policy", None)
        logical = plan.network_bytes
        if policy is None:
            wire = logical
        else:
            wire = sum(
                wire_nbytes(policy, t)
                for t in plan.tasks
                if getattr(t, "kind", "remote") == "remote"
            )
        return logical, wire, len(plan.layers()) - len(plan.resident_layers())

    def _plan_for(self, target) -> tuple[int, int, int]:
        """(logical bytes, wire bytes, layers) for current-world -> target."""
        b = getattr(self.ctrl, "_builder", None)
        if b is not None and b.ready and not b.abandoned:
            handle = b.result()
            bundle = handle.plan_bundle
            if (
                handle.parallel == target
                and bundle is not None
                and bundle[0] == self.ctrl.world.parallel
            ):
                return self._price_plan(bundle[2])
        from repro.core.reshard import plan_state_transfer

        _, plan = plan_state_transfer(
            self.ctrl.cfg, self.ctrl.world.parallel, target,
            source_policy=self.ctrl.source_policy,
        )
        return self._price_plan(plan)

    def _pool_warm(self, target) -> bool:
        """True when the controller's warm pool holds a ready world for
        ``target`` (Prepare will skip lower+compile)."""
        pool = getattr(self.ctrl, "world_pool", None)
        if pool is None or not hasattr(self.ctrl, "pool_key"):
            return False
        return pool.contains(self.ctrl.pool_key(target))

    def estimate(self, target) -> ReconfigEstimate:
        plan_bytes, wire_bytes, layers = self._plan_for(target)
        bw = self.bandwidth_estimate()
        step_s = self.step_estimate()
        rounds = math.ceil(layers / max(1, self.ctrl.stream_k))
        # the rungs are priced on what actually crosses the wire under the
        # controller's WirePolicy; the lossless figure is kept alongside so
        # the decision can be compared to its uncompressed counterfactual
        transfer_s = wire_bytes / bw
        warm = self._pool_warm(target)
        # peer_recover rung pricing (DESIGN.md §15): coverage from the
        # controller's survivor-constrained plan (fail-stop geometry — the
        # ranks beyond the target prefix die), donor bytes at measured
        # bandwidth, lossless (the recovery stream never compresses).
        # Duck-typed controllers without peer recovery price it
        # unavailable and keep the checkpoint rung.
        peer_ok, peer_bytes = False, 0
        cov = getattr(self.ctrl, "peer_coverage", None)
        if cov is not None:
            peer_ok, peer_bytes = cov(target)
        return ReconfigEstimate(
            prepare_s=self.prepare_estimate(warm=warm),
            warm=warm,
            # one pre-copy round per iteration boundary, each hiding its
            # bytes under a training step (dispatch rides the boundary)
            precopy_s=rounds * step_s,
            # dense-optimizer worst case: every layer is dirty at commit,
            # so the commit pause re-moves the plan (overlap.py's honest
            # limit) — minus nothing we can promise in advance
            stream_pause_s=transfer_s,
            stop_copy_pause_s=transfer_s,
            plan_bytes=plan_bytes,
            rounds=rounds,
            step_s=step_s,
            wire_bytes=wire_bytes,
            layers=layers,
            lossless_transfer_s=plan_bytes / bw,
            peer_ok=peer_ok,
            peer_bytes=peer_bytes,
            peer_pause_s=self.prepare_estimate(warm=warm) + peer_bytes / bw,
            measured_bw=self.measured_bandwidth() or 0.0,
        )


class PrefetchPolicy:
    """Fills the controller's warm world pool while the event loop is idle.

    Each ``tick`` (called by the scheduler on steps with no pending event)
    asks the topology search for the likely next targets — the failover
    standby (:func:`failover_target`, the prefix-survivor world a
    fail-stop would recover into, DESIGN.md §15) first, then the best
    feasible configurations at the walk-down/walk-up neighbor device
    counts of the current world (:func:`likely_next_targets`) — and starts
    speculative builds via ``controller.prefetch_world``. Targets already
    pooled get their transfer executables pre-compiled instead
    (``controller.prewarm_transfer``), so a recovery into a warm world
    pays neither the Prepare nor the first-pair reshard compiles. The
    controller enforces the guardrails: never while a real reconfiguration
    is in flight, at most ``max_spec_builds`` concurrent compiles, skip
    targets already pooled or building. Candidate enumeration is
    re-planned per tick because the current world (and hence its
    neighbors) changes with every commit; the search itself is
    metadata-only and cheap.
    """

    def __init__(
        self,
        controller,
        k: int = 2,
        factors: tuple[float, ...] = (0.5, 2.0),
        max_pp: int = 8,
    ):
        self.ctrl = controller
        self.k = k
        self.factors = factors
        # must cover the pp range of the event stream's own targets (e.g.
        # events_from_trace's max_pp) or a prefetched pp=1 world can never
        # match a pp>1 event's pool key — wasted builds that evict genuinely
        # useful entries. Pass the same bound you give the trace mapper.
        self.max_pp = max_pp
        self.started = 0
        # candidates only change when the active world does (a commit);
        # cache them so idle ticks don't re-run the topology search
        self._cands_for = None
        self._cands: list = []

    def candidates(self) -> list:
        from repro.core.topology_search import (
            failover_target,
            likely_next_targets,
        )

        ctrl = self.ctrl
        cands = likely_next_targets(
            ctrl.cfg,
            ctrl.world.parallel,
            len(ctrl.devices),
            ctrl.global_batch,
            ctrl.seq_len,
            k=self.k,
            factors=self.factors,
            max_pp=self.max_pp,
        )
        # failover standbys (DESIGN.md §15): the prefix-survivor worlds an
        # unannounced fail-stop would recover into, chained one level (a
        # failure can take more than one replica group). Keeping them warm
        # ahead of the walk-down/walk-up guesses bounds the fail-stop
        # pause to the transfer itself, never a cold Prepare — except a
        # world_size-1 standby, which protects only against losing all but
        # one device: it queues BEHIND the walk candidates so it cannot
        # hog the single speculative-build slot right before a walk-up.
        front: list = []
        back: list = []
        cur = ctrl.world.parallel
        for _ in range(2):
            cur = failover_target(
                ctrl.cfg, cur, ctrl.global_batch, max_pp=self.max_pp
            )
            if cur is None or cur == ctrl.world.parallel:
                break
            (front if cur.world_size > 1 else back).append(cur)
        seen = set(front) | set(back)
        return front + [c for c in cands if c not in seen] + back

    def tick(self) -> int:
        """Start speculative builds for the current candidates; returns
        how many were started (0 when pooled/building/busy)."""
        if getattr(self.ctrl, "reconfig_pending", False):
            # builds would be refused mid-resize, but the INCOMING world's
            # failover pairs can (and should) warm now: a window-0 event
            # right after the commit pays any cold transfer compile inside
            # its pause, and the post-commit gap is shorter than a compile
            getattr(self.ctrl, "prewarm_failover_ahead", lambda: 0)()
            return 0
        current = self.ctrl.world.parallel
        # warm transfer pairs into already-pooled worlds FIRST: a window-0
        # recovery pays any cold transfer compile inside its pause, while
        # a standby world build overlaps training — the prewarm is
        # pause-critical, the build is not. (pool_key index 1 is the
        # ParallelConfig; keys built for another device fingerprint
        # peek-miss inside prewarm_transfer)
        pool = getattr(self.ctrl, "world_pool", None)
        if pool is not None:
            # only non-growing pairs: the zero-warning consumers of these
            # executables are fail-stops, shrinks and same-size
            # retopologies — grows come with warning windows and stream,
            # so warming them here would spend the compile budget the
            # standby build needs. Nearest-size first: a same-size
            # retopology has zero capacity slack and is the likeliest
            # window-0 target, deeper-shrink pairs only matter after
            # deeper failures (prewarms run one at a time, so order is
            # priority)
            keys = sorted(
                (
                    k
                    for k in pool.keys()
                    if k[1] != current
                    and k[1].world_size <= current.world_size
                ),
                key=lambda k: current.world_size - k[1].world_size,
            )
            for key in keys:
                self.ctrl.prewarm_transfer(key[1])
        # while a prewarm is compiling, hold off on starting new cold
        # builds — two concurrent XLA compiles contend for the same host
        # cores and both slow down, and only the prewarm is on the
        # recovery-pause path
        thread = getattr(self.ctrl, "_prewarm_thread", None)
        if thread is not None and thread.is_alive():
            return 0
        if current != self._cands_for:
            self._cands_for = current
            self._cands = self.candidates()
        started = 0
        for target in self._cands:
            if self.ctrl.prefetch_world(target):
                started += 1
            else:
                # already pooled (or building): warm the TRANSFER
                # executables for (current → target) too, so a recovery
                # into this world pays neither compile (DESIGN.md §15)
                self.ctrl.prewarm_transfer(target)
        self.started += started
        return started


# ---------------------------------------------------------------------------
# The endpoint contract
# ---------------------------------------------------------------------------


class Endpoint:
    """Dispatches protocol commands to ``_on_<type-tag>`` methods.

    Subclasses implement the verbs they support; the rest answer
    ``ErrorResponse("unsupported")`` so a driver can probe capabilities
    without try/except. :class:`RecoveryError` maps to
    ``ErrorResponse("recovery")`` — the one failure the scheduler
    handles as a normal outcome (``aborted``) rather than a crash.
    """

    kind = "generic"

    def handle(self, cmd: Any) -> Any:
        tag = p._TYPE_OF.get(type(cmd))
        if tag is None:
            return ErrorResponse(
                kind="invalid", message=f"not a command: {type(cmd).__name__}"
            )
        fn = getattr(self, "_on_" + tag, None)
        if fn is None:
            return ErrorResponse(kind="unsupported", message=tag)
        try:
            return fn(cmd)
        except RecoveryError as e:
            return ErrorResponse(kind="recovery", message=str(e))


class ControllerEndpoint(Endpoint):
    """``LiveRController`` (or any duck-typed training controller) behind
    the protocol. Owns the server-side estimator and prefetch policy so
    `query_estimate` / `prefetch_tick` stay one round-trip."""

    kind = "train"

    def __init__(
        self,
        controller,
        estimator: Optional[DeadlineEstimator] = None,
        prefetch: Optional[PrefetchPolicy] = None,
        prefetch_k: int = 0,
    ):
        self.ctrl = controller
        self.estimator = estimator or DeadlineEstimator(controller)
        self.prefetch = prefetch
        if (
            self.prefetch is None
            and prefetch_k > 0
            and getattr(controller, "world_pool", None) is not None
        ):
            self.prefetch = PrefetchPolicy(controller, k=prefetch_k)

    # -- verbs ----------------------------------------------------------
    def _on_train_steps(self, cmd: p.TrainSteps) -> StepResult:
        self.ctrl.train_steps(cmd.n)
        return StepResult(steps=cmd.n, clock_s=-1.0)

    @staticmethod
    def _op(cmd) -> Optional[OperatingPoint]:
        return (
            None
            if cmd.operating_point is None
            else OperatingPoint(**cmd.operating_point)
        )

    def _on_request_resize(self, cmd: p.RequestResize) -> ResizeStarted:
        gen = self.ctrl.request_resize(
            cmd.target, overlap=cmd.overlap, operating_point=self._op(cmd)
        )
        return ResizeStarted(gen_id=int(gen if gen is not None else -1))

    def _on_retarget_resize(self, cmd: p.RetargetResize) -> ResizeStarted:
        gen = self.ctrl.retarget_resize(
            cmd.target, overlap=cmd.overlap, operating_point=self._op(cmd)
        )
        return ResizeStarted(gen_id=int(gen if gen is not None else -1))

    def _on_escalate_commit(self, cmd: p.EscalateCommit) -> EscalateResult:
        rec = self.ctrl.escalate_commit()
        return EscalateResult(
            escalated=rec is not None,
            record=None if rec is None else RecordView.from_record(rec),
        )

    def _on_cancel_resize(self, cmd: p.CancelResize) -> Ack:
        self.ctrl.cancel_resize(outcome=cmd.outcome)
        return Ack(ok=True)

    def _on_fail_stop_recover(self, cmd: p.FailStopRecover) -> RecoverResult:
        rec = self.ctrl.fail_stop_recover(
            cmd.target,
            devices_failed=cmd.devices_failed,
            lost_ranks=tuple(cmd.lost_ranks),
        )
        return RecoverResult(record=RecordView.from_record(rec))

    def _on_checkpoint_now(self, cmd: p.CheckpointNow) -> Ack:
        self.ctrl.checkpoint_now()
        return Ack(ok=True)

    def _on_prefetch_world(self, cmd: p.PrefetchWorld) -> PrefetchResult:
        return PrefetchResult(
            started=int(bool(self.ctrl.prefetch_world(cmd.target)))
        )

    def _on_prefetch_tick(self, cmd: p.PrefetchTick) -> PrefetchResult:
        if self.prefetch is None:
            return PrefetchResult(started=0)
        return PrefetchResult(started=self.prefetch.tick())

    def _on_wait_shadow_ready(self, cmd: p.WaitShadowReady) -> Ack:
        self.ctrl.wait_shadow_ready(
            **({} if cmd.timeout is None else {"timeout": cmd.timeout})
        )
        return Ack(ok=True)

    # -- queries --------------------------------------------------------
    def _on_query_status(self, cmd: p.QueryStatus) -> StatusResponse:
        ctrl = self.ctrl
        par = ctrl.world.parallel
        return StatusResponse(
            parallel=par,
            world_size=par.world_size,
            step=int(getattr(ctrl, "step", 0)),
            reconfig_pending=bool(getattr(ctrl, "reconfig_pending", False)),
            durable=bool(getattr(ctrl, "ckpt_dir", None)),
            records=len(ctrl.records),
            kind=self.kind,
        )

    def _on_query_records(self, cmd: p.QueryRecords) -> RecordsResponse:
        recs = self.ctrl.records
        return RecordsResponse(
            records=tuple(
                RecordView.from_record(r) for r in recs[cmd.since :]
            ),
            total=len(recs),
        )

    def _on_query_estimate(self, cmd: p.QueryEstimate) -> EstimateResponse:
        return EstimateResponse(estimate=self.estimator.estimate(cmd.target))

    def _on_query_ledger(self, cmd: p.QueryLedger) -> LedgerResponse:
        ctrl = self.ctrl
        ledger = ctrl.ledger
        steps = int(getattr(ctrl, "step", 0))
        return LedgerResponse(
            goodput=ledger.goodput,
            pause_seconds=ledger.pause_seconds,
            train_gpu_seconds=ledger.gpu_seconds("train"),
            steps=steps,
            samples=float(steps * getattr(ctrl, "global_batch", 0)),
        )

    def _on_query_survivor_target(
        self, cmd: p.QuerySurvivorTarget
    ) -> TargetResponse:
        """Largest feasible topology over the surviving devices: the naive
        ``world - lost`` count is usually infeasible (divisibility), so
        walk down until the search finds one (same geometry the scheduler
        used to compute in-process)."""
        ctrl = self.ctrl
        cfg = getattr(ctrl, "cfg", None)
        if cfg is None:
            return TargetResponse(target=None)
        from repro.core.topology_search import best_target

        survivors = max(
            1,
            ctrl.world.parallel.world_size - max(1, len(cmd.lost_ranks)),
        )
        for world in range(survivors, 0, -1):
            try:
                return TargetResponse(
                    target=best_target(
                        cfg, world, ctrl.global_batch, ctrl.seq_len, max_pp=1
                    )
                )
            except ValueError:
                continue
        return TargetResponse(target=None)


class ServeEndpoint(Endpoint):
    """``LiveServeController`` behind the same protocol: the fleet
    arbiter addresses training and serving jobs uniformly. Serving has no
    train loop or fallback lattice — the decode loop owns commit timing —
    so this adapter answers the resize/status/record subset and reports
    the rest unsupported."""

    kind = "serve"

    def __init__(self, controller):
        self.ctrl = controller

    def _on_request_resize(self, cmd: p.RequestResize) -> ResizeStarted:
        self.ctrl.request_resize(cmd.target)
        return ResizeStarted(gen_id=int(self.ctrl.gen_id + 1))

    # a newer target simply supersedes the pending one (the serve
    # controller discards internally on the next request)
    def _on_retarget_resize(self, cmd: p.RetargetResize) -> ResizeStarted:
        self.ctrl.request_resize(cmd.target)
        return ResizeStarted(gen_id=int(self.ctrl.gen_id + 1))

    def _on_cancel_resize(self, cmd: p.CancelResize) -> Ack:
        self.ctrl._discard_pending()
        return Ack(ok=True)

    def _on_query_status(self, cmd: p.QueryStatus) -> StatusResponse:
        par = self.ctrl.active.parallel
        return StatusResponse(
            parallel=par,
            world_size=par.world_size,
            step=int(self.ctrl.gen_id),
            reconfig_pending=bool(self.ctrl.resize_pending),
            durable=False,
            records=len(self.ctrl.records),
            kind=self.kind,
        )

    def _on_query_records(self, cmd: p.QueryRecords) -> RecordsResponse:
        recs = self.ctrl.records
        return RecordsResponse(
            records=tuple(
                RecordView.from_record(r) for r in recs[cmd.since :]
            ),
            total=len(recs),
        )


class SimEndpoint(Endpoint):
    """A whole job as a calibrated closed-form model on the DES clock.

    Answers the full training protocol with zero devices: training
    progress accrues lazily — any command first syncs the ledger from the
    last-touched virtual time to ``sim.now`` (train vs pause intervals,
    samples at the calibrated step time) — so a 100-job fleet costs one
    O(1) update per command, not per step. Reconfigurations follow the
    cluster model: ``prepare_s`` of shadow build ahead of an atomic
    commit whose pause is priced like ``sim/liver_sim.py`` (drain +
    remote transfer + switch for stop-copy; dirty-window re-sync + switch
    for the overlapped rung).

    With no ``sim`` argument the endpoint owns a private
    :class:`~repro.sim.des.Simulator` and ``train_steps`` advances it —
    an ``ElasticScheduler`` can drive a SimEndpoint directly, its trace
    clock following the returned ``StepResult.clock_s``. With a shared
    ``sim`` (the fleet arbiter's), time is advanced by the owner and
    ``train_steps`` only syncs.
    """

    kind = "sim"

    def __init__(
        self,
        name: str = "sim-job",
        params: float = 1.4e9,
        global_batch: int = 256,
        parallel: Optional[ParallelConfig] = None,
        cluster=None,
        sim=None,
        move_fraction: float = 0.5,
        layers: int = 24,
        stream_k: int = 4,
    ):
        from repro.sim.cluster import PAPER_TESTBED
        from repro.sim.des import Simulator

        self.name = name
        self.params = float(params)
        self.global_batch = int(global_batch)
        self.parallel = parallel or ParallelConfig(dp=8)
        self.cluster = cluster or PAPER_TESTBED
        self._owns_clock = sim is None
        self.sim = sim or Simulator()
        self.move_fraction = move_fraction
        self.layers = layers
        self.stream_k = stream_k
        self.ledger = GoodputLedger()
        self.records: list[RecordView] = []
        self._gen = 0
        self._t = self.sim.now  # ledger accrued up to here
        self._pause_until = self.sim.now
        self._pause_world = self.parallel.world_size
        self._pending: Optional[dict] = None
        self.step_count = 0.0
        self.samples = 0.0

    # -- calibrated model ------------------------------------------------
    def _step_time(self, world: int) -> float:
        from repro.roofline.analysis import analytic_step_time

        return analytic_step_time(self.params, world, self.cluster)

    def _moved_bytes(self) -> float:
        from repro.sim.cluster import model_state_bytes

        return model_state_bytes(self.params) * self.move_fraction

    def _pause_for(self, mode: str, world: int) -> float:
        c = self.cluster
        moved = self._moved_bytes()
        if mode == "stream":
            # overlapped rung: pre-copy rounds ride iteration boundaries;
            # the commit pause re-syncs the dirty window (~10% of the
            # plan) and swaps metadata
            return c.transfer_s(0.1 * moved, world) + c.switch_s
        # stop-copy (and the peer-recovery stream): the whole transfer
        # lands inside one pause after the drain
        return c.drain_s + c.transfer_s(moved, world) + c.switch_s

    # -- lazy time accrual ----------------------------------------------
    def _accrue(self, upto: float) -> None:
        t = self._t
        if upto <= t:
            return
        if self._pause_until > t:
            pe = min(self._pause_until, upto)
            self.ledger.record(t, pe, "pause", self._pause_world)
            t = pe
        if upto > t:
            w = self.parallel.world_size
            self.ledger.record(t, upto, "train", w)
            st = self._step_time(w)
            self.step_count += (upto - t) / st
            self.samples += (upto - t) / st * self.global_batch
        self._t = upto

    def _sync(self) -> None:
        now = self.sim.now
        pend = self._pending
        if pend is not None and pend["ready_at"] <= now:
            self._accrue(pend["ready_at"])
            self._commit(pend, outcome="committed")
        self._accrue(now)

    def _commit(self, pend: dict, outcome: str, pause: Optional[float] = None,
                mode: Optional[str] = None) -> RecordView:
        self._pending = None
        src, dst = self.parallel, pend["target"]
        world = max(src.world_size, dst.world_size)
        m = mode or pend["mode"]
        if pause is None:
            pause = self._pause_for(m, world)
        now = self._t
        self._pause_until = max(self._pause_until, now) + pause
        self._pause_world = dst.world_size
        self.parallel = dst
        rec = RecordView(
            gen_id=pend["gen"],
            src=src.describe(),
            dst=dst.describe(),
            mode="live_overlap" if m == "stream" else "live",
            outcome=outcome,
            prepare_s=pend["prepare_s"],
            total_pause_s=pause,
        )
        self.records.append(rec)
        return rec

    def _retire_pending(self, outcome: str) -> None:
        if self._pending is None:
            return
        pend, self._pending = self._pending, None
        self.records.append(
            RecordView(
                gen_id=pend["gen"],
                src=self.parallel.describe(),
                dst=pend["target"].describe(),
                mode="live_overlap" if pend["mode"] == "stream" else "live",
                outcome=outcome,
                prepare_s=0.0,
                total_pause_s=0.0,
            )
        )

    def _begin(self, target: ParallelConfig, overlap: Optional[str]) -> int:
        self._sync()
        self._gen += 1
        world = max(self.parallel.world_size, target.world_size)
        prepare = self.cluster.prepare_s(world)
        self._pending = {
            "gen": self._gen,
            "target": target,
            "mode": overlap or "stream",
            "t0": self.sim.now,
            "prepare_s": prepare,
            "ready_at": self.sim.now + prepare,
        }
        return self._gen

    # -- verbs ----------------------------------------------------------
    def _on_train_steps(self, cmd: p.TrainSteps) -> StepResult:
        if self._owns_clock:
            st = self._step_time(self.parallel.world_size)
            self.sim.run(until=self.sim.now + cmd.n * st)
        self._sync()
        return StepResult(steps=cmd.n, clock_s=self.sim.now)

    def _on_request_resize(self, cmd: p.RequestResize) -> ResizeStarted:
        self._retire_pending("retargeted")
        return ResizeStarted(gen_id=self._begin(cmd.target, cmd.overlap))

    def _on_retarget_resize(self, cmd: p.RetargetResize) -> ResizeStarted:
        self._retire_pending("retargeted")
        return ResizeStarted(gen_id=self._begin(cmd.target, cmd.overlap))

    def _on_escalate_commit(self, cmd: p.EscalateCommit) -> EscalateResult:
        self._sync()
        if self._pending is None:
            return EscalateResult(escalated=False)
        # an early escalation pays the un-overlapped remainder of the
        # prepare inside the pause, then the full stop-copy transfer
        pend = self._pending
        remaining = max(0.0, pend["ready_at"] - self.sim.now)
        world = max(self.parallel.world_size, pend["target"].world_size)
        pause = remaining + self._pause_for("stop_copy", world)
        rec = self._commit(pend, outcome="fell_back", pause=pause,
                           mode="stop_copy")
        return EscalateResult(escalated=True, record=rec)

    def _on_cancel_resize(self, cmd: p.CancelResize) -> Ack:
        self._sync()
        self._retire_pending(cmd.outcome or "canceled")
        return Ack(ok=True)

    def _on_fail_stop_recover(self, cmd: p.FailStopRecover) -> RecoverResult:
        self._sync()
        self._retire_pending("retargeted")
        self._gen += 1
        src, dst = self.parallel, cmd.target
        # peers stream the survivor shards: transfer at the DST world's
        # aggregate bandwidth (the survivors), plus drain + switch
        pause = self._pause_for("stop_copy", dst.world_size)
        now = self._t
        self._pause_until = max(self._pause_until, now) + pause
        self._pause_world = dst.world_size
        self.parallel = dst
        rec = RecordView(
            gen_id=self._gen,
            src=src.describe(),
            dst=dst.describe(),
            mode="peer_recover",
            outcome="committed",
            total_pause_s=pause,
        )
        self.records.append(rec)
        return RecoverResult(record=rec)

    def _on_checkpoint_now(self, cmd: p.CheckpointNow) -> Ack:
        from repro.sim.cluster import model_state_bytes

        self._sync()
        w = self.parallel.world_size
        bw = self.cluster.storage_bw_gbps_per_gpu * 1e9 / 8 * w
        pause = model_state_bytes(self.params, with_optimizer=True) / bw
        self._pause_until = max(self._pause_until, self._t) + pause
        self._pause_world = w
        return Ack(ok=True, detail="checkpointed")

    def _on_prefetch_world(self, cmd: p.PrefetchWorld) -> PrefetchResult:
        return PrefetchResult(started=0)  # warm pool not modeled

    def _on_prefetch_tick(self, cmd: p.PrefetchTick) -> PrefetchResult:
        return PrefetchResult(started=0)

    def _on_wait_shadow_ready(self, cmd: p.WaitShadowReady) -> Ack:
        if self._owns_clock and self._pending is not None:
            self.sim.run(until=max(self.sim.now, self._pending["ready_at"]))
            self._sync()
        return Ack(ok=True)

    # -- queries --------------------------------------------------------
    def _on_query_status(self, cmd: p.QueryStatus) -> StatusResponse:
        self._sync()
        return StatusResponse(
            parallel=self.parallel,
            world_size=self.parallel.world_size,
            step=int(self.step_count),
            reconfig_pending=self._pending is not None,
            durable=True,
            records=len(self.records),
            kind=self.kind,
        )

    def _on_query_records(self, cmd: p.QueryRecords) -> RecordsResponse:
        self._sync()
        return RecordsResponse(
            records=tuple(self.records[cmd.since :]),
            total=len(self.records),
        )

    def _on_query_estimate(self, cmd: p.QueryEstimate) -> EstimateResponse:
        self._sync()
        c = self.cluster
        world = max(self.parallel.world_size, cmd.target.world_size)
        moved = self._moved_bytes()
        step_s = self._step_time(self.parallel.world_size)
        rounds = math.ceil(self.layers / max(1, self.stream_k))
        transfer = c.transfer_s(moved, world)
        return EstimateResponse(
            estimate=ReconfigEstimate(
                prepare_s=c.prepare_s(world),
                precopy_s=rounds * step_s,
                stream_pause_s=self._pause_for("stream", world),
                stop_copy_pause_s=self._pause_for("stop_copy", world),
                plan_bytes=int(moved),
                rounds=rounds,
                step_s=step_s,
                wire_bytes=int(moved),
                layers=self.layers,
                lossless_transfer_s=transfer,
                peer_ok=True,
                peer_bytes=int(moved),
                peer_pause_s=self._pause_for("stop_copy",
                                             cmd.target.world_size),
                measured_bw=c.interconnect_gbps_per_gpu * 1e9 / 8 * world,
            )
        )

    def _on_query_ledger(self, cmd: p.QueryLedger) -> LedgerResponse:
        self._sync()
        return LedgerResponse(
            goodput=self.ledger.goodput,
            pause_seconds=self.ledger.pause_seconds,
            train_gpu_seconds=self.ledger.gpu_seconds("train"),
            steps=int(self.step_count),
            samples=self.samples,
        )

    def _on_query_survivor_target(
        self, cmd: p.QuerySurvivorTarget
    ) -> TargetResponse:
        survivors = max(
            1, self.parallel.world_size - max(1, len(cmd.lost_ranks))
        )
        return TargetResponse(target=ParallelConfig(dp=survivors))


class WireEndpoint(Endpoint):
    """Round-trips every command AND response through the JSON wire
    format before/after the inner endpoint sees them. Functionally a
    no-op — which is the point: a driver that works through a
    WireEndpoint has proven its whole conversation serializes, making
    this the local stand-in for a real RPC transport. Tests and the
    fleet bench run through it by default."""

    def __init__(self, inner: Endpoint):
        self.inner = inner
        self.kind = inner.kind
        self.commands = 0
        self.bytes_tx = 0
        self.bytes_rx = 0

    @property
    def prefetch(self):
        # surfaced for drivers/benches that report prefetch stats; the
        # policy itself still lives (and runs) endpoint-side
        return getattr(self.inner, "prefetch", None)

    def handle(self, cmd: Any) -> Any:
        wire = p.dumps(cmd)
        self.commands += 1
        self.bytes_tx += len(wire)
        resp = self.inner.handle(p.loads(wire))
        wire_back = p.dumps(resp)
        self.bytes_rx += len(wire_back)
        return p.loads(wire_back)


def as_endpoint(obj: Any, **kw) -> Endpoint:
    """Coerce a controller-like object to an endpoint: endpoints pass
    through (kw must be empty then), everything else wraps in a
    :class:`ControllerEndpoint`."""
    if isinstance(obj, Endpoint):
        if kw and any(v for v in kw.values()):
            raise ValueError(
                "estimator/prefetch config belongs to the endpoint; "
                "configure the endpoint you pass in"
            )
        return obj
    return ControllerEndpoint(obj, **kw)
