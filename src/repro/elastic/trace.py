"""Trace -> typed elasticity events (paper §6.4/§6.5 volatility regimes).

``repro.sim.volatility`` emits abstract ``(t, world[, kind, warning])``
rows; the live scheduler needs :class:`ResizeEvent`/:class:`FailStopEvent`
with concrete ``ParallelConfig`` targets. The topology choice is delegated
to ``core/topology_search`` — exactly the external-search integration the
paper defers (§2.3(D)).
"""

from __future__ import annotations

import math
from typing import Iterable, Sequence

from repro.configs.base import ModelConfig
from repro.core.errors import TraceError
from repro.core.events import FailStopEvent, ResizeEvent

# the kinds the scheduler understands (core/events.py): every warned
# shape change replays as a ResizeEvent, unannounced losses as FailStopEvent
VALID_KINDS = ("resize", "scale_out", "scale_in", "preempt", "fail_stop")


def _validate_row(i: int, row: Sequence) -> None:
    """Typed errors at load time (TraceError, core/errors.py): a malformed
    row used to surface mid-replay as an opaque topology-search or heap
    error — long after the bad generator wrote it."""
    if len(row) < 2:
        raise TraceError(f"trace row {i}: need at least (t, world), got {row!r}")
    t, world = row[0], row[1]
    if not isinstance(t, (int, float)) or not math.isfinite(float(t)) or t < 0:
        raise TraceError(f"trace row {i}: bad timestamp {t!r}")
    if not isinstance(world, (int, float)) or int(world) != world or world <= 0:
        raise TraceError(f"trace row {i}: world must be a positive int, got {world!r}")
    if len(row) > 2 and row[2] not in VALID_KINDS:
        raise TraceError(
            f"trace row {i}: unknown event kind {row[2]!r} "
            f"(expected one of {VALID_KINDS})"
        )
    if len(row) > 3:
        w = row[3]
        # inf is fine (an unhurried resize); negative or NaN is not
        if not isinstance(w, (int, float)) or math.isnan(float(w)) or w < 0:
            raise TraceError(f"trace row {i}: bad warning window {w!r}")
    if len(row) > 4:
        if row[2] != "fail_stop":
            raise TraceError(
                f"trace row {i}: lost_ranks only valid on fail_stop rows"
            )
        try:
            lost = [int(r) for r in row[4]]
        except (TypeError, ValueError):
            raise TraceError(f"trace row {i}: bad lost_ranks {row[4]!r}") from None
        if any(r < 0 for r in lost):
            raise TraceError(f"trace row {i}: negative rank in {row[4]!r}")


def events_from_trace(
    trace: Iterable[Sequence],
    cfg: ModelConfig,
    global_batch: int,
    seq_len: int,
    compress: float = 1.0,
    default_warning_s: float = 120.0,
    max_pp: int = 8,
) -> list:
    """Convert trace rows into scheduler events.

    Rows are ``(t, world)`` (the sim's classic shape), ``(t, world, kind)``,
    ``(t, world, kind, warning_s)`` or ``(t, world, kind, warning_s,
    lost_ranks)`` with ``kind in {"resize", "fail_stop"}`` — the optional
    fifth element (an iterable of rank ids, fail-stop rows only) pins WHICH
    devices died, for fault-injection traces that need the peer-recovery
    donor geometry to be deterministic. ``compress`` divides every time and
    warning window so a multi-hour trace replays against the live
    controller in seconds (a 24 h / 47-event trace at ``compress=3600``
    fires an event roughly every half-minute of wall clock).

    Malformed rows raise :class:`~repro.core.errors.TraceError` up front —
    unknown kind, non-positive world, negative/NaN warning, bad lost set.
    """
    from repro.core.topology_search import best_target

    assert compress > 0, compress
    events = []
    target_cache: dict[int, object] = {}
    for i, row in enumerate(trace):
        _validate_row(i, row)
        t, world = float(row[0]), int(row[1])
        kind = row[2] if len(row) > 2 else "resize"
        warning = float(row[3]) if len(row) > 3 else default_warning_s
        if world not in target_cache:
            target_cache[world] = best_target(
                cfg, world, global_batch, seq_len, max_pp=max_pp
            )
        target = target_cache[world]
        if kind == "fail_stop":
            lost = tuple(int(r) for r in row[4]) if len(row) > 4 else ()
            events.append(
                FailStopEvent(
                    time_s=t / compress, target=target, lost_ranks=lost
                )
            )
        else:
            events.append(
                ResizeEvent(
                    time_s=t / compress,
                    target=target,
                    warning_s=warning / compress,
                    kind=kind,
                )
            )
    return events
