"""Trace -> typed elasticity events (paper §6.4/§6.5 volatility regimes).

``repro.sim.volatility`` emits abstract ``(t, world[, kind, warning])``
rows; the live scheduler needs :class:`ResizeEvent`/:class:`FailStopEvent`
with concrete ``ParallelConfig`` targets. The topology choice is delegated
to ``core/topology_search`` — exactly the external-search integration the
paper defers (§2.3(D)).
"""

from __future__ import annotations

from typing import Iterable, Sequence

from repro.configs.base import ModelConfig
from repro.core.events import FailStopEvent, ResizeEvent


def events_from_trace(
    trace: Iterable[Sequence],
    cfg: ModelConfig,
    global_batch: int,
    seq_len: int,
    compress: float = 1.0,
    default_warning_s: float = 120.0,
    max_pp: int = 8,
) -> list:
    """Convert trace rows into scheduler events.

    Rows are ``(t, world)`` (the sim's classic shape), ``(t, world, kind)``,
    ``(t, world, kind, warning_s)`` or ``(t, world, kind, warning_s,
    lost_ranks)`` with ``kind in {"resize", "fail_stop"}`` — the optional
    fifth element (an iterable of rank ids, fail-stop rows only) pins WHICH
    devices died, for fault-injection traces that need the peer-recovery
    donor geometry to be deterministic. ``compress`` divides every time and
    warning window so a multi-hour trace replays against the live
    controller in seconds (a 24 h / 47-event trace at ``compress=3600``
    fires an event roughly every half-minute of wall clock).
    """
    from repro.core.topology_search import best_target

    assert compress > 0, compress
    events = []
    target_cache: dict[int, object] = {}
    for row in trace:
        t, world = float(row[0]), int(row[1])
        kind = row[2] if len(row) > 2 else "resize"
        warning = float(row[3]) if len(row) > 3 else default_warning_s
        if world not in target_cache:
            target_cache[world] = best_target(
                cfg, world, global_batch, seq_len, max_pp=max_pp
            )
        target = target_cache[world]
        if kind == "fail_stop":
            lost = tuple(int(r) for r in row[4]) if len(row) > 4 else ()
            events.append(
                FailStopEvent(
                    time_s=t / compress, target=target, lost_ranks=lost
                )
            )
        else:
            events.append(
                ResizeEvent(
                    time_s=t / compress,
                    target=target,
                    warning_s=warning / compress,
                    kind=kind,
                )
            )
    return events
